"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Covers `gaussian.margins`, `gaussian.gaussian_row`, and
`merge_score.merge_scores` against `ref.*` with fixed cases plus
hypothesis sweeps over shapes, bandwidths and coefficient signs.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import gaussian, merge_score, ref

RNG = np.random.default_rng(0)


def mk_budget(b_pad, d, live, scale=1.0, seed=0, mixed_signs=True):
    rng = np.random.default_rng(seed)
    X = (rng.standard_normal((b_pad, d)) * scale).astype(np.float32)
    a = rng.standard_normal(b_pad).astype(np.float32)
    if not mixed_signs:
        a = np.abs(a)
    mask = np.zeros(b_pad, dtype=np.float32)
    mask[:live] = 1.0
    X[live:] = 0.0
    a[live:] = 0.0
    return X, a, mask


# ---------------------------------------------------------------- margins


@pytest.mark.parametrize("b_pad,live", [(128, 128), (128, 37), (256, 200)])
@pytest.mark.parametrize("d", [4, 32])
@pytest.mark.parametrize("nb", [1, 5])
def test_margins_matches_ref(b_pad, live, d, nb):
    X, a, mask = mk_budget(b_pad, d, live)
    Xb = RNG.standard_normal((nb, d)).astype(np.float32)
    gamma = 0.25
    got = gaussian.margins(Xb, X, a, mask, jnp.array([gamma], jnp.float32))
    want = ref.margins(Xb, X, a, mask, gamma)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_margins_masked_lanes_do_not_contribute():
    X, a, mask = mk_budget(128, 8, 64)
    # Poison the padding region: masked lanes must still contribute zero.
    X[64:] = 100.0
    a[64:] = 1e6
    Xb = RNG.standard_normal((3, 8)).astype(np.float32)
    got = gaussian.margins(Xb, X, a, mask, jnp.array([0.5], jnp.float32))
    want = ref.margins(Xb, X[:64], a[:64], mask[:64], 0.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 48),
    nb=st.integers(1, 8),
    live=st.integers(1, 128),
    gamma=st.floats(1e-3, 8.0),
    scale=st.floats(0.1, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_margins_hypothesis(d, nb, live, gamma, scale, seed):
    X, a, mask = mk_budget(128, d, live, scale=scale, seed=seed)
    rng = np.random.default_rng(seed + 1)
    Xb = (rng.standard_normal((nb, d)) * scale).astype(np.float32)
    got = gaussian.margins(Xb, X, a, mask, jnp.array([gamma], jnp.float32))
    want = ref.margins(Xb, X, a, mask, gamma)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_margins_zero_gamma_sums_alphas():
    # gamma=0 -> k==1 everywhere -> margin = sum of live alphas.
    X, a, mask = mk_budget(128, 4, 50)
    Xb = np.zeros((2, 4), dtype=np.float32)
    got = gaussian.margins(Xb, X, a, mask, jnp.array([0.0], jnp.float32))
    np.testing.assert_allclose(got, np.full(2, (a * mask).sum()), rtol=1e-5)


# ------------------------------------------------------------ kernel row


@pytest.mark.parametrize("b_pad", [128, 384])
def test_gaussian_row_matches_ref(b_pad):
    X, _, _ = mk_budget(b_pad, 16, b_pad)
    x = RNG.standard_normal(16).astype(np.float32)
    got = gaussian.gaussian_row(x, X, jnp.array([1.5], jnp.float32))
    want = ref.gaussian_row(x, X, 1.5)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_gaussian_row_self_distance_is_one():
    X, _, _ = mk_budget(128, 8, 128)
    got = gaussian.gaussian_row(X[7], X, jnp.array([2.0], jnp.float32))
    assert abs(float(got[7]) - 1.0) < 1e-6


# ----------------------------------------------------------- merge score


def scores_both(X, a, mask, i, gamma):
    x_i = X[i]
    a_i = a[i]
    m = mask.copy()
    m[i] = 0.0  # callers exclude the candidate's own lane
    got = merge_score.merge_scores(
        x_i, np.array([a_i], np.float32), X, a, m,
        jnp.array([gamma], jnp.float32),
    )
    want = ref.merge_scores(x_i, a_i, X, a, m, gamma)
    return got, want


# Per-output tolerances: the golden-section optimum is *flat* in h, so h
# and a_z carry inherent slop when two implementations take different
# float rounding paths; wd (the quantity merges are ranked by) is
# second-order flat and d2 is plain arithmetic — both stay tight.
TOLS = {
    "wd": dict(rtol=2e-3, atol=1e-4),
    "h": dict(rtol=1.0, atol=2e-2),
    "a_z": dict(rtol=2e-2, atol=2e-3),
    "d2": dict(rtol=1e-5, atol=1e-6),
}


@pytest.mark.parametrize("b_pad,live", [(128, 128), (128, 60), (256, 130)])
@pytest.mark.parametrize("gamma", [0.05, 0.5, 4.0])
def test_merge_scores_matches_ref(b_pad, live, gamma):
    X, a, mask = mk_budget(b_pad, 12, live, seed=3)
    got, want = scores_both(X, a, mask, 0, gamma)
    for g, w, name in zip(got, want, ["wd", "h", "a_z", "d2"]):
        np.testing.assert_allclose(g, w, err_msg=name, **TOLS[name])


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(1, 32),
    live=st.integers(2, 128),
    gamma=st.floats(1e-2, 8.0),
    seed=st.integers(0, 2**31 - 1),
    mixed=st.booleans(),
)
def test_merge_scores_hypothesis(d, live, gamma, seed, mixed):
    X, a, mask = mk_budget(128, d, live, seed=seed, mixed_signs=mixed)
    got, want = scores_both(X, a, mask, 0, gamma)
    for g, w, name in zip(got, want, ["wd", "h", "a_z", "d2"]):
        np.testing.assert_allclose(g, w, err_msg=name, **TOLS[name])


def test_merge_scores_masked_lanes_are_inf():
    X, a, mask = mk_budget(128, 6, 40)
    (wd, _, _, _), _ = scores_both(X, a, mask, 2, 0.7)
    wd = np.asarray(wd)
    assert np.all(wd[40:] >= ref.WD_INF * 0.99)
    assert np.all(wd[:40][np.arange(40) != 2] < ref.WD_INF * 0.99)


def test_merge_scores_wd_nonnegative_and_ordered():
    """WD is a squared norm: >= 0 (up to float eps); closer points with
    similar alphas should merge more cheaply than distant ones."""
    d = 8
    X, a, mask = mk_budget(128, d, 100, seed=11, mixed_signs=False)
    (wd, h, a_z, d2), _ = scores_both(X, a, mask, 5, 1.0)
    wd = np.asarray(wd)[:100]
    assert np.all(wd > -1e-4)
    # identical point at distance 0 (if any lane happens to coincide): skip;
    # instead check the global trend: min-wd partner is among the near ones.
    live_idx = [j for j in range(100) if j != 5]
    best = min(live_idx, key=lambda j: wd[j])
    d2v = np.asarray(d2)
    assert d2v[best] <= np.median(d2v[live_idx]) * 1.5


def test_merge_identical_points_zero_degradation():
    """Merging a point with an exact copy must cost ~nothing (h in [0,1],
    a_z = a_i + a_j, wd ~ 0)."""
    d = 8
    X, a, mask = mk_budget(128, d, 50, seed=4, mixed_signs=False)
    X[1] = X[0]
    (wd, h, a_z, _), _ = scores_both(X, a, mask, 0, 2.0)
    assert float(wd[1]) < 1e-5
    np.testing.assert_allclose(float(a_z[1]), a[0] + a[1], rtol=1e-5)


def test_merge_scores_h_interval_by_sign():
    X, a, mask = mk_budget(128, 5, 80, seed=9)
    a = np.abs(a).astype(np.float32)
    a[10:20] *= -1.0  # opposite-sign block
    (wd, h, a_z, _), _ = scores_both(X, a, mask, 0, 0.8)
    h = np.asarray(h)
    same = np.arange(1, 80)[np.asarray(a[1:80]) * a[0] >= 0]
    mixed = np.arange(1, 80)[np.asarray(a[1:80]) * a[0] < 0]
    assert np.all((h[same] >= -1e-6) & (h[same] <= 1 + 1e-6))
    assert np.all((h[mixed] <= 1e-6) | (h[mixed] >= 1 - 1e-6))


def test_golden_section_beats_endpoints():
    """|g(h*)| must be >= |g| at both interval endpoints (same-sign case:
    endpoints are 'keep x_j' / 'keep x_i')."""
    c = np.linspace(0.01, 10.0, 64).astype(np.float32)
    a_i = np.float32(0.3)
    a_j = np.linspace(0.1, 2.0, 64).astype(np.float32)
    h, a_z, gabs = ref.golden_merge(a_i, a_j, c)
    g0 = np.abs(ref.merge_pair_objective(0.0, a_i, a_j, c))
    g1 = np.abs(ref.merge_pair_objective(1.0, a_i, a_j, c))
    assert np.all(np.asarray(gabs) >= np.maximum(g0, g1) - 1e-5)

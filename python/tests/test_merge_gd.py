"""MM-GD (Alg. 2) oracle tests: invariants + comparison with cascades.

MM-GD has no separate Pallas implementation (tiny (M,d) tile — see
kernels/__init__), so these tests pin down its *mathematical* behaviour:
monotone improvement, degradation bounds, and agreement with the binary
merge in the M=2 case.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile import model


def mk_set(m_live, d, seed=0, spread=0.5, positive=True):
    rng = np.random.default_rng(seed)
    center = rng.standard_normal(d).astype(np.float32)
    X = (center + spread * rng.standard_normal((model.M_PAD, d))).astype(
        np.float32
    )
    a = rng.uniform(0.1, 1.0, model.M_PAD).astype(np.float32)
    if not positive:
        a *= rng.choice([-1.0, 1.0], model.M_PAD).astype(np.float32)
    mm = np.zeros(model.M_PAD, dtype=np.float32)
    mm[:m_live] = 1.0
    X[m_live:] = 0.0
    a[m_live:] = 0.0
    return X, a, mm


def norm2_of_set(X, a, mm, gamma):
    am = a * mm
    diff = X[:, None, :] - X[None, :, :]
    K = np.exp(-gamma * np.sum(diff**2, axis=2))
    return float(am @ K @ am)


@pytest.mark.parametrize("m", [2, 3, 5, 10])
@pytest.mark.parametrize("gamma", [0.1, 1.0])
def test_merge_gd_degradation_bounds(m, gamma):
    X, a, mm = mk_set(m, 6, seed=m)
    z, a_z, wd = ref.merge_gd(X, a, mm, gamma)
    n2 = norm2_of_set(X, a, mm, gamma)
    # 0 <= wd <= ||sum a_i phi(x_i)||^2 (a_z = 0 achieves the upper bound).
    assert -1e-4 <= float(wd) <= n2 + 1e-4


def test_merge_gd_single_point_is_exact():
    X, a, mm = mk_set(1, 4, seed=7)
    z, a_z, wd = ref.merge_gd(X, a, mm, 1.0)
    np.testing.assert_allclose(np.asarray(z), X[0], atol=1e-4)
    np.testing.assert_allclose(float(a_z), a[0], rtol=1e-4)
    assert float(wd) < 1e-6


def test_merge_gd_identical_points_exact():
    X, a, mm = mk_set(4, 5, seed=3)
    X[:4] = X[0]
    z, a_z, wd = ref.merge_gd(X, a, mm, 2.0)
    np.testing.assert_allclose(np.asarray(z), X[0], atol=1e-3)
    np.testing.assert_allclose(float(a_z), a[:4].sum(), rtol=1e-3)
    assert float(wd) < 1e-5


def test_merge_gd_beats_or_matches_centroid_seed():
    """GD must not end worse than its own initialization."""
    X, a, mm = mk_set(6, 8, seed=5, spread=1.0)
    gamma = 0.5
    z, a_z, wd = ref.merge_gd(X, a, mm, gamma)
    am = a * mm
    z0 = (X * am[:, None]).sum(0) / am.sum()
    g0 = float(np.sum(am * np.exp(-gamma * np.sum((X - z0) ** 2, axis=1))))
    n2 = norm2_of_set(X, a, mm, gamma)
    wd0 = n2 - g0 * g0
    assert float(wd) <= wd0 + 1e-5


def test_merge_gd_m2_close_to_golden_section():
    """For M=2 the GD merge must approximately match the golden-section
    optimum (paper: 'differences are minor', Table 1)."""
    rng = np.random.default_rng(12)
    for trial in range(5):
        d = 4
        x0 = rng.standard_normal(d).astype(np.float32)
        x1 = (x0 + 0.6 * rng.standard_normal(d)).astype(np.float32)
        a0, a1 = rng.uniform(0.2, 1.0, 2).astype(np.float32)
        gamma = 1.0
        X = np.zeros((model.M_PAD, d), np.float32)
        a = np.zeros(model.M_PAD, np.float32)
        mm = np.zeros(model.M_PAD, np.float32)
        X[0], X[1] = x0, x1
        a[0], a[1] = a0, a1
        mm[:2] = 1.0
        _, _, wd_gd = ref.merge_gd(X, a, mm, gamma)
        c = gamma * float(np.sum((x0 - x1) ** 2))
        _, _, gabs = ref.golden_merge(a0, a1, np.float32(c))
        k01 = np.exp(-c)
        wd_gs = a0**2 + a1**2 + 2 * a0 * a1 * k01 - float(gabs) ** 2
        assert float(wd_gd) <= wd_gs * 1.05 + 1e-5


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 11),
    d=st.integers(1, 16),
    gamma=st.floats(0.05, 4.0),
    seed=st.integers(0, 2**31 - 1),
    positive=st.booleans(),
)
def test_merge_gd_hypothesis_invariants(m, d, gamma, seed, positive):
    X, a, mm = mk_set(m, d, seed=seed, positive=positive)
    z, a_z, wd = ref.merge_gd(X, a, mm, gamma)
    assert np.all(np.isfinite(np.asarray(z)))
    assert np.isfinite(float(a_z)) and np.isfinite(float(wd))
    n2 = norm2_of_set(X, a, mm, gamma)
    assert -1e-3 <= float(wd) <= n2 + 1e-3


def test_entry_points_shapes():
    """model.* entry points return the shapes the manifest promises."""
    import jax.numpy as jnp

    b, d, nb = 128, 32, 4
    X = np.zeros((b, d), np.float32)
    al = np.zeros(b, np.float32)
    mk = np.zeros(b, np.float32)
    g = jnp.array([1.0], jnp.float32)
    (mg,) = model.margins_entry(X, al, mk, np.zeros((nb, d), np.float32), g)
    assert mg.shape == (nb,)
    wd, h, az, d2 = model.merge_scores_entry(
        X, al, mk, np.zeros(d, np.float32), jnp.array([0.5], jnp.float32), g
    )
    assert wd.shape == h.shape == az.shape == d2.shape == (b,)
    Xm = np.zeros((model.M_PAD, d), np.float32)
    z, az1, wd1 = model.merge_gd_entry(
        Xm, np.zeros(model.M_PAD, np.float32),
        np.zeros(model.M_PAD, np.float32), g
    )
    assert z.shape == (d,) and az1.shape == (1,) and wd1.shape == (1,)

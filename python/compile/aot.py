"""AOT lowering driver: jax entry points -> artifacts/*.hlo.txt + manifest.

Interchange format is **HLO text**, NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The HLO *text* parser reassigns ids, so text round-trips
cleanly (see /opt/xla-example/README.md).

One artifact per (entry point, padded-shape variant).  The manifest
(``artifacts/manifest.json``) records every artifact's argument shapes so
the rust runtime (``rust/src/runtime``) can pick the smallest fitting
variant and marshal literals without re-deriving shape logic.

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Padded budget sizes.  Experiments cap B at 4096 (ADULT's largest paper
# budget is 2500; SKIN fractions are capped — see DESIGN.md §8).
B_PADS = [128, 256, 512, 1024, 2048, 4096]
# Feature-dimension buckets covering the paper's datasets:
#   SKIN d=3, IJCNN d=22 -> 32; PHISHING d=68, ADULT d=123 -> 128; WEB d=300 -> 512.
D_PADS = [32, 128, 512]
# Margin batch variants: nb=1 (per-SGD-step) and nb=256 (evaluation chunks).
NB_PADS = [1, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def variants():
    """Yield (name, entry_fn, arg_specs, meta) for every artifact."""
    for d in D_PADS:
        for b in B_PADS:
            for nb in NB_PADS:
                yield (
                    f"margins_b{b}_d{d}_n{nb}",
                    model.margins_entry,
                    [f32(b, d), f32(b), f32(b), f32(nb, d), f32(1)],
                    {"entry": "margins", "b_pad": b, "d_pad": d, "nb": nb,
                     "outputs": [[nb]]},
                )
            yield (
                f"merge_scores_b{b}_d{d}",
                model.merge_scores_entry,
                [f32(b, d), f32(b), f32(b), f32(d), f32(1), f32(1)],
                {"entry": "merge_scores", "b_pad": b, "d_pad": d,
                 "outputs": [[b], [b], [b], [b]]},
            )
        yield (
            f"merge_gd_m{model.M_PAD}_d{d}",
            model.merge_gd_entry,
            [f32(model.M_PAD, d), f32(model.M_PAD), f32(model.M_PAD), f32(1)],
            {"entry": "merge_gd", "m_pad": model.M_PAD, "d_pad": d,
             "outputs": [[d], [1], [1]]},
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated name prefixes to lower (for quick iteration)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    prefixes = args.only.split(",") if args.only else None
    manifest = {"artifacts": []}
    n = 0
    for name, fn, specs, meta in variants():
        if prefixes and not any(name.startswith(p) for p in prefixes):
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["name"] = name
        entry["file"] = f"{name}.hlo.txt"
        entry["args"] = [list(s.shape) for s in specs]
        manifest["artifacts"].append(entry)
        n += 1
        print(f"[{n:3d}] {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {n} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()

"""L1 kernels for multi-merge BSGD.

* ``gaussian``    — Pallas masked RBF margin / kernel-row kernels.
* ``merge_score`` — Pallas vectorized golden-section merge scoring (the
                    paper's Theta(B*K*G) hot spot).
* ``ref``         — pure-jnp oracles for everything above plus MM-GD.

MM-GD (``ref.merge_gd``) operates on a tiny (M_pad, d) tile; it is kept as
a plain-jnp L2 function — there is no blocking win at that size — and is
lowered to its own artifact by ``compile.aot``.
"""

from . import gaussian, merge_score, ref  # noqa: F401

"""Pure-jnp reference oracles for every L1 kernel.

These are the correctness ground truth: each Pallas kernel in this package
is tested against the function of the same name here via pytest +
hypothesis (``python/tests/``).  The math follows Wang, Crammer, Vucetic,
"Breaking the Curse of Kernelization" (JMLR 2012) and Qaadan & Glasmachers,
"Multi-Merge Budget Maintenance" (2018).

Conventions
-----------
* Gaussian (RBF) kernel: ``k(x, x') = exp(-gamma * ||x - x'||^2)``.
* Support vector matrix ``X_sv`` has shape ``(B_pad, d)``; ``alpha`` has
  shape ``(B_pad,)``; ``mask`` is 1.0 for live SVs and 0.0 for padding.
* Merging two SVs ``(x_i, a_i)`` and ``(x_j, a_j)``: the merged point is
  ``z = h*x_i + (1-h)*x_j``; for any ``z`` the optimal coefficient is the
  projection ``a_z = a_i k(x_i,z) + a_j k(x_j,z)`` (``||phi(z)|| = 1``),
  and the weight degradation is
  ``||Delta||^2 = a_i^2 + a_j^2 + 2 a_i a_j k_ij - a_z^2``.
  Maximizing ``|a_z|`` over ``h`` therefore minimizes the degradation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Golden ratio constant used by golden-section search.
INVPHI = 0.6180339887498949  # 1/phi
GS_ITERS = 30  # fixed iteration count G (paper: "fixed number of G iterations")

# HLO-friendly +inf sentinel for masked weight-degradation lanes.
WD_INF = 3.4e38


def sq_dists(x: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distances ||x - X_j||^2, shape (B,)."""
    diff = X - x[None, :]
    return jnp.sum(diff * diff, axis=1)


def gaussian_row(x: jnp.ndarray, X: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Kernel row k(x, X_j) = exp(-gamma ||x - X_j||^2), shape (B,)."""
    return jnp.exp(-gamma * sq_dists(x, X))


def margins(
    Xb: jnp.ndarray,
    X_sv: jnp.ndarray,
    alpha: jnp.ndarray,
    mask: jnp.ndarray,
    gamma: float,
) -> jnp.ndarray:
    """Decision values f(x) = sum_j alpha_j k(x_j, x) for a batch.

    Xb: (nb, d) query batch; returns (nb,).  Masked lanes contribute 0.
    """
    # (nb, B) squared distance matrix via the expanded form.
    xb2 = jnp.sum(Xb * Xb, axis=1, keepdims=True)  # (nb, 1)
    sv2 = jnp.sum(X_sv * X_sv, axis=1)[None, :]  # (1, B)
    cross = Xb @ X_sv.T  # (nb, B)
    d2 = jnp.maximum(xb2 + sv2 - 2.0 * cross, 0.0)
    K = jnp.exp(-gamma * d2)
    return K @ (alpha * mask)


def _gz(h, a_i, a_j, c):
    """a_z as a function of h: a_i k(x_i,z) + a_j k(x_j,z), c = gamma*d2."""
    return a_i * jnp.exp(-c * (1.0 - h) ** 2) + a_j * jnp.exp(-c * h**2)


def _golden_max_absg(lo, hi, a_i, a_j, c, iters: int = GS_ITERS):
    """Golden-section search maximizing |g(h)| on [lo, hi].

    Vectorized: lo/hi/a_j/c may be arrays of shape (B,).  Returns (h*, |g|*).
    """

    def obj(h):
        return jnp.abs(_gz(h, a_i, a_j, c))

    x1 = hi - INVPHI * (hi - lo)
    x2 = lo + INVPHI * (hi - lo)
    f1 = obj(x1)
    f2 = obj(x2)

    def body(_, state):
        lo, hi, x1, x2, f1, f2 = state
        # If f1 > f2, the max is in [lo, x2]; else in [x1, hi].
        left = f1 > f2
        nlo = jnp.where(left, lo, x1)
        nhi = jnp.where(left, x2, hi)
        nx2 = jnp.where(left, x1, nlo + INVPHI * (nhi - nlo))
        nx1 = jnp.where(left, nhi - INVPHI * (nhi - nlo), x2)
        nf2 = jnp.where(left, f1, obj(nx2))
        nf1 = jnp.where(left, obj(nx1), f2)
        return (nlo, nhi, nx1, nx2, nf1, nf2)

    lo, hi, x1, x2, f1, f2 = jax.lax.fori_loop(
        0, iters, body, (lo, hi, x1, x2, f1, f2)
    )
    h = 0.5 * (lo + hi)
    return h, obj(h)


def merge_pair_objective(h, a_i, a_j, c):
    """Public alias for g(h) used by tests."""
    return _gz(h, a_i, a_j, c)


def golden_merge(a_i, a_j, c, iters: int = GS_ITERS):
    """Optimal (h, a_z, |g(h*)|) for merging one pair.

    Vectorized over trailing array args.  Interval depends on coefficient
    signs (paper sec. 2.3): same sign -> convex combination h in [0,1];
    opposite signs -> h < 0 or h > 1 (search [-1,0] and [1,2], keep best).
    """
    same = a_i * a_j >= 0.0
    h_in, g_in = _golden_max_absg(
        jnp.zeros_like(c), jnp.ones_like(c), a_i, a_j, c, iters
    )
    h_left, g_left = _golden_max_absg(
        -jnp.ones_like(c), jnp.zeros_like(c), a_i, a_j, c, iters
    )
    h_right, g_right = _golden_max_absg(
        jnp.ones_like(c), 2.0 * jnp.ones_like(c), a_i, a_j, c, iters
    )
    out_h = jnp.where(g_left > g_right, h_left, h_right)
    out_g = jnp.maximum(g_left, g_right)
    h = jnp.where(same, h_in, out_h)
    gabs = jnp.where(same, g_in, out_g)
    a_z = _gz(h, a_i, a_j, c)
    return h, a_z, gabs


def merge_scores(
    x_i: jnp.ndarray,
    a_i: jnp.ndarray,
    X_sv: jnp.ndarray,
    alpha: jnp.ndarray,
    mask: jnp.ndarray,
    gamma: float,
    iters: int = GS_ITERS,
):
    """Score merging (x_i, a_i) against every budget SV.

    Returns (wd, h, a_z, d2), each (B,):
      wd  — weight degradation ||Delta||^2 of the optimal binary merge
      h   — optimal interpolation parameter (z = h x_i + (1-h) x_j)
      a_z — optimal merged coefficient
      d2  — squared distance ||x_i - x_j||^2 (reused by callers)
    Masked lanes get wd = WD_INF (huge finite sentinel, HLO-friendly).
    """
    d2 = sq_dists(x_i, X_sv)
    c = gamma * d2
    k_ij = jnp.exp(-c)
    h, a_z, gabs = golden_merge(a_i, alpha, c, iters)
    norm2 = a_i * a_i + alpha * alpha + 2.0 * a_i * alpha * k_ij
    wd = norm2 - gabs * gabs
    wd = jnp.where(mask > 0.5, wd, jnp.float32(WD_INF))
    return wd, h, a_z, d2


def merge_gd(
    X_m: jnp.ndarray,
    a_m: jnp.ndarray,
    mmask: jnp.ndarray,
    gamma: float,
    iters: int = 50,
    lr: float = 0.5,
):
    """MM-GD (Alg. 2): merge M points into one via gradient descent on z.

    X_m: (M_pad, d) points to merge, a_m: (M_pad,) coefficients, mmask
    masks live rows.  Minimizes ||sum_i a_i phi(x_i) - a_z phi(z)||^2,
    equivalently maximizes g(z)^2 with g(z) = sum_i a_i k(x_i, z); a_z is
    the closed-form projection g(z).

    Returns (z, a_z, wd).  Uses a backtracking-flavoured fixed-iteration
    scheme: a step is kept only if it does not decrease |g| (monotone), and
    the step size is halved otherwise — fixed trip count lowers to a clean
    HLO while staying robust.
    """
    am = a_m * mmask
    denom = jnp.sum(am)
    # Weighted centroid seed (paper Alg. 2 init); guard tiny denominators.
    safe = jnp.where(jnp.abs(denom) > 1e-12, denom, 1.0)
    z0 = jnp.sum(X_m * am[:, None], axis=0) / safe
    # Fallback seed for near-cancelling coefficients: |a|-weighted centroid.
    absw = jnp.abs(am)
    z0_abs = jnp.sum(X_m * absw[:, None], axis=0) / jnp.maximum(
        jnp.sum(absw), 1e-12
    )
    z0 = jnp.where(jnp.abs(denom) > 1e-12, z0, z0_abs)

    def g(z):
        return jnp.sum(am * jnp.exp(-gamma * sq_dists(z, X_m)))

    def grad_g(z):
        k = am * jnp.exp(-gamma * sq_dists(z, X_m))  # (M,)
        # d/dz exp(-gamma||z - x||^2) = -2 gamma (z - x) * k
        return -2.0 * gamma * jnp.sum(k[:, None] * (z[None, :] - X_m), axis=0)

    def body(_, state):
        z, step, best = state
        gz = g(z)
        # Ascent direction on |g|: sign(g) * grad g.
        direction = jnp.sign(gz) * grad_g(z)
        z_new = z + step * direction
        g_new = jnp.abs(g(z_new))
        improved = g_new >= best
        z = jnp.where(improved, z_new, z)
        best = jnp.maximum(best, g_new)
        step = jnp.where(improved, step * 1.1, step * 0.5)
        return (z, step, best)

    z, _, _ = jax.lax.fori_loop(
        0, iters, body, (z0, jnp.asarray(lr, dtype=X_m.dtype), jnp.abs(g(z0)))
    )
    a_z = g(z)
    # ||sum a_i phi(x_i)||^2 = a^T K a over the merge set.
    diff = X_m[:, None, :] - X_m[None, :, :]
    K = jnp.exp(-gamma * jnp.sum(diff * diff, axis=2))
    norm2 = am @ K @ am
    wd = norm2 - a_z * a_z
    return z, a_z, wd

"""L1 Pallas kernel: masked Gaussian-kernel margin evaluation.

Computes ``f(x) = sum_j alpha_j * k(x_j, x)`` for a batch of query points
against the (padded) support-vector matrix.  This is the per-step
``O(B*K)`` cost of BSGD (paper sec. 3) and the bulk of evaluation time.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid runs over budget
blocks of ``BLOCK_B`` support vectors; each step keeps a ``(BLOCK_B, d)``
SV tile plus the full query tile resident in VMEM, computes the blocked
cross-term on the MXU (``Xb @ sv_blk.T``) and accumulates the masked
``exp``-weighted matvec on the VPU.  Padding lanes carry ``mask = 0`` and
contribute exactly zero.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret mode lowers to plain
HLO that the rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Budget-dimension tile.  128 matches the TPU lane width.
BLOCK_B = 128


def _margin_kernel(xb_ref, sv_ref, alpha_ref, mask_ref, gamma_ref, o_ref):
    """One grid step: accumulate the contribution of a BLOCK_B SV tile.

    xb_ref:    (nb, d)       query tile (same for all grid steps)
    sv_ref:    (BLOCK_B, d)  SV tile for this step
    alpha_ref: (BLOCK_B,)    coefficients
    mask_ref:  (BLOCK_B,)    1.0 live / 0.0 padding
    gamma_ref: (1,)          RBF bandwidth (runtime input, not baked in)
    o_ref:     (nb,)         accumulated decision values
    """
    # Zero the accumulator on the first grid step only.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = xb_ref[...]  # (nb, d)
    sv = sv_ref[...]  # (BLOCK_B, d)
    gamma = gamma_ref[0]
    # ||x - s||^2 via the expanded form: the cross term is the MXU matmul.
    xb2 = jnp.sum(xb * xb, axis=1, keepdims=True)  # (nb, 1)
    sv2 = jnp.sum(sv * sv, axis=1)[None, :]  # (1, BLOCK_B)
    cross = jnp.dot(xb, sv.T)  # (nb, BLOCK_B) — MXU
    d2 = jnp.maximum(xb2 + sv2 - 2.0 * cross, 0.0)
    k = jnp.exp(-gamma * d2)  # (nb, BLOCK_B) — VPU
    w = alpha_ref[...] * mask_ref[...]  # (BLOCK_B,)
    o_ref[...] += jnp.dot(k, w)


@functools.partial(jax.jit, static_argnames=("interpret",))
def margins(Xb, X_sv, alpha, mask, gamma, *, interpret: bool = True):
    """Pallas-blocked decision values; matches ``ref.margins``.

    Xb: (nb, d); X_sv: (B_pad, d) with B_pad % BLOCK_B == 0; alpha, mask:
    (B_pad,); gamma: (1,) runtime scalar.  Returns (nb,) float32.
    """
    nb, d = Xb.shape
    b_pad = X_sv.shape[0]
    assert b_pad % BLOCK_B == 0, f"B_pad={b_pad} must be a multiple of {BLOCK_B}"
    grid = (b_pad // BLOCK_B,)
    return pl.pallas_call(
        _margin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, d), lambda i: (0, 0)),  # queries: resident
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),  # SV tile walks B
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((nb,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.float32),
        interpret=interpret,
    )(Xb, X_sv, alpha, mask, gamma)


def _kernel_row_kernel(x_ref, sv_ref, gamma_ref, o_ref):
    """Kernel row tile: k(x, sv_j) for one BLOCK_B tile."""
    x = x_ref[...]  # (1, d)
    sv = sv_ref[...]  # (BLOCK_B, d)
    diff = sv - x
    d2 = jnp.sum(diff * diff, axis=1)
    o_ref[...] = jnp.exp(-gamma_ref[0] * d2)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gaussian_row(x, X_sv, gamma, *, interpret: bool = True):
    """Pallas kernel row k(x, X_sv); matches ``ref.gaussian_row``.

    x: (d,); X_sv: (B_pad, d); gamma: (1,).  Returns (B_pad,).
    """
    b_pad, d = X_sv.shape
    assert b_pad % BLOCK_B == 0
    return pl.pallas_call(
        _kernel_row_kernel,
        grid=(b_pad // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b_pad,), jnp.float32),
        interpret=interpret,
    )(x.reshape(1, -1), X_sv, gamma)

"""L1 Pallas kernel: vectorized golden-section merge scoring.

This is the paper's computational bottleneck (sec. 3): when the budget
overflows, the SV with the smallest |alpha| is fixed as the first merge
candidate and *every* other budget SV is scored as a potential merge
partner.  Scoring a pair means running a golden-section search for the
interpolation parameter ``h`` of the merged point ``z = h x_i + (1-h) x_j``
— ``Theta(B*K*G)`` work that accounts for up to ~45-84 % of BSGD training
time.  Multi-merge amortizes it; this kernel *vectorizes* it.

Layout: the grid walks the budget in BLOCK_B-lane tiles; each lane runs an
independent golden-section search (G sequential ``fori_loop`` iterations of
pure VPU math: 2 ``exp`` per interval per iteration).  The sign-dependent
search interval (same-sign coefficients -> h in [0,1]; mixed sign ->
[-1,0] or [1,2], see paper sec. 2.3) is handled by running all three
intervals and selecting per-lane — branch-free, so every lane stays in
lock-step on the vector unit.

Outputs per lane j:
  wd   — weight degradation ||Delta||^2 of merging (x_i, x_j)
  h    — optimal interpolation parameter
  a_z  — optimal merged coefficient
  d2   — ||x_i - x_j||^2 (reused by callers, e.g. cascade merges)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_B = 128
INVPHI = ref.INVPHI
GS_ITERS = ref.GS_ITERS
WD_INF = ref.WD_INF


def _golden_tile(lo, hi, a_i, a_j, c, iters):
    """Golden-section max of |g(h)| over a BLOCK_B tile, branch-free."""

    def gz(h):
        # Keep the arithmetic association identical to ref._gz so the two
        # implementations take bit-identical golden-section branches.
        return a_i * jnp.exp(-c * (1.0 - h) ** 2) + a_j * jnp.exp(-c * h**2)

    def obj(h):
        return jnp.abs(gz(h))

    x1 = hi - INVPHI * (hi - lo)
    x2 = lo + INVPHI * (hi - lo)

    def body(_, state):
        lo, hi, x1, x2, f1, f2 = state
        left = f1 > f2
        nlo = jnp.where(left, lo, x1)
        nhi = jnp.where(left, x2, hi)
        nx2 = jnp.where(left, x1, nlo + INVPHI * (nhi - nlo))
        nx1 = jnp.where(left, nhi - INVPHI * (nhi - nlo), x2)
        nf2 = jnp.where(left, f1, obj(nx2))
        nf1 = jnp.where(left, obj(nx1), f2)
        return (nlo, nhi, nx1, nx2, nf1, nf2)

    lo, hi, x1, x2, f1, f2 = jax.lax.fori_loop(
        0, iters, body, (lo, hi, x1, x2, obj(x1), obj(x2))
    )
    h = 0.5 * (lo + hi)
    return h, obj(h)


def _merge_score_kernel(
    xi_ref, ai_ref, sv_ref, alpha_ref, mask_ref, gamma_ref,
    wd_ref, h_ref, az_ref, d2_ref, *, iters: int,
):
    """One grid step: score a BLOCK_B tile of merge partners against x_i."""
    xi = xi_ref[...]  # (1, d)
    sv = sv_ref[...]  # (BLOCK_B, d)
    a_i = ai_ref[0]
    gamma = gamma_ref[0]
    alpha = alpha_ref[...]
    mask = mask_ref[...]

    diff = sv - xi
    d2 = jnp.sum(diff * diff, axis=1)  # (BLOCK_B,)
    c = gamma * d2
    k_ij = jnp.exp(-c)

    zeros = jnp.zeros_like(c)
    ones = jnp.ones_like(c)
    # Three sign-dependent intervals, evaluated for every lane (branch-free).
    h_in, g_in = _golden_tile(zeros, ones, a_i, alpha, c, iters)
    h_lf, g_lf = _golden_tile(-ones, zeros, a_i, alpha, c, iters)
    h_rt, g_rt = _golden_tile(ones, 2.0 * ones, a_i, alpha, c, iters)

    same = a_i * alpha >= 0.0
    h_out = jnp.where(g_lf > g_rt, h_lf, h_rt)
    g_out = jnp.maximum(g_lf, g_rt)
    h = jnp.where(same, h_in, h_out)
    gabs = jnp.where(same, g_in, g_out)

    a_z = a_i * jnp.exp(-c * (1.0 - h) ** 2) + alpha * jnp.exp(-c * h**2)
    norm2 = a_i * a_i + alpha * alpha + 2.0 * a_i * alpha * k_ij
    wd = norm2 - gabs * gabs

    wd_ref[...] = jnp.where(mask > 0.5, wd, jnp.float32(WD_INF))
    h_ref[...] = h
    az_ref[...] = a_z
    d2_ref[...] = d2


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def merge_scores(
    x_i, a_i, X_sv, alpha, mask, gamma, *, iters: int = GS_ITERS,
    interpret: bool = True,
):
    """Pallas-blocked pairwise merge scoring; matches ``ref.merge_scores``.

    x_i: (d,) first merge candidate; a_i: (1,) its coefficient;
    X_sv: (B_pad, d); alpha, mask: (B_pad,); gamma: (1,).
    Returns (wd, h, a_z, d2), each (B_pad,) float32.

    NOTE the caller must mask out lane ``i`` itself (set mask[i] = 0), as
    the kernel has no notion of the candidate's own index.
    """
    b_pad, d = X_sv.shape
    assert b_pad % BLOCK_B == 0, f"B_pad={b_pad} must be a multiple of {BLOCK_B}"
    grid = (b_pad // BLOCK_B,)
    vec = lambda: pl.BlockSpec((BLOCK_B,), lambda i: (i,))
    out_shape = [jax.ShapeDtypeStruct((b_pad,), jnp.float32) for _ in range(4)]
    kern = functools.partial(_merge_score_kernel, iters=iters)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),  # x_i resident
            pl.BlockSpec((1,), lambda i: (0,)),  # a_i
            pl.BlockSpec((BLOCK_B, d), lambda i: (i, 0)),  # SV tile
            vec(),  # alpha
            vec(),  # mask
            pl.BlockSpec((1,), lambda i: (0,)),  # gamma
        ],
        out_specs=[vec(), vec(), vec(), vec()],
        out_shape=out_shape,
        interpret=interpret,
    )(x_i.reshape(1, -1), a_i, X_sv, alpha, mask, gamma)

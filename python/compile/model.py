"""L2: the jax compute-graph entry points lowered to AOT artifacts.

Each function here is a pure, fixed-shape jax function that the rust
coordinator executes through PJRT at training/serving time.  They compose
the L1 Pallas kernels (``kernels.gaussian``, ``kernels.merge_score``) plus
the MM-GD merge (``kernels.ref.merge_gd`` — tiny (M,d) tile, plain jnp).

Shape conventions (everything padded to fixed sizes, masked):
  X_sv  : (B_pad, d_pad) f32   support-vector matrix
  alpha : (B_pad,)      f32    coefficients; 0 on padding lanes
  mask  : (B_pad,)      f32    1.0 live / 0.0 padding
  Xb    : (nb, d_pad)   f32    query batch
  gamma : (1,)          f32    RBF bandwidth — runtime input so one
                               artifact serves every hyperparameter setting
Zero-padded feature columns contribute 0 to every squared distance, so
d-padding is exact, not approximate.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import gaussian, merge_score, ref

# MM-GD merge-set pad: supports M up to 16 (paper sweeps M in 2..11).
M_PAD = 16
# MM-GD fixed iteration/step parameters (see kernels/ref.py merge_gd).
GD_ITERS = 50
GD_LR = 0.5


def margins_entry(X_sv, alpha, mask, Xb, gamma):
    """Decision values f(x_b) for a batch — the O(B*K) per-step cost."""
    return (gaussian.margins(Xb, X_sv, alpha, mask, gamma),)


def merge_scores_entry(X_sv, alpha, mask, x_i, a_i, gamma):
    """Pairwise weight-degradation scores of x_i vs the whole budget.

    The caller zeroes ``mask`` at x_i's own lane.  Returns
    (wd, h, a_z, d2), each (B_pad,).
    """
    return merge_score.merge_scores(x_i, a_i, X_sv, alpha, mask, gamma)


def merge_gd_entry(X_m, a_m, mmask, gamma):
    """MM-GD (Alg. 2): merge up to M_PAD points into one.

    Returns (z, a_z, wd) with z: (d_pad,), a_z/wd: scalar-shaped (1,).
    """
    z, a_z, wd = ref.merge_gd(X_m, a_m, mmask, gamma[0], iters=GD_ITERS, lr=GD_LR)
    return (z, jnp.reshape(a_z, (1,)), jnp.reshape(wd, (1,)))


ENTRY_POINTS = {
    "margins": margins_entry,
    "merge_scores": merge_scores_entry,
    "merge_gd": merge_gd_entry,
}

#!/usr/bin/env bash
# Profile-guided-optimization build recipe for the mmbsgd binary.
#
# Usage:
#   bench/run_pgo.sh [--dry-run] [TARGET_DIR]
#
# Phases:
#   1. build with -Cprofile-generate (instrumented binary)
#   2. run representative training workloads (the tile-engine and
#      merge-scoring hot paths the benches measure) to collect profiles
#   3. merge raw profiles with llvm-profdata
#   4. rebuild with -Cprofile-use
#
# --dry-run prints every command without executing anything — the CI
# smoke for this recipe (the full PGO cycle needs two release builds
# and is a local/perf-lab workflow, not a per-PR one).
#
# llvm-profdata discovery: LLVM_PROFDATA env var, a rustup-distributed
# llvm-tools copy, or PATH.
set -euo pipefail

cd "$(dirname "$0")/.."

DRY=0
if [ "${1:-}" = "--dry-run" ]; then
    DRY=1
    shift
fi
PGO_DIR="${1:-/tmp/mmbsgd-pgo}"

run() {
    echo "+ $*"
    if [ "$DRY" -eq 0 ]; then
        "$@"
    fi
}

find_profdata() {
    if [ -n "${LLVM_PROFDATA:-}" ]; then
        echo "$LLVM_PROFDATA"
        return
    fi
    local sysroot tool
    if sysroot="$(rustc --print sysroot 2>/dev/null)"; then
        tool="$(find "$sysroot" -name llvm-profdata -type f 2>/dev/null | head -n1)"
        if [ -n "$tool" ]; then
            echo "$tool"
            return
        fi
    fi
    echo llvm-profdata
}

PROFDATA="$(find_profdata)"
echo "[pgo] profile dir: $PGO_DIR"
echo "[pgo] llvm-profdata: $PROFDATA"

run rm -rf "$PGO_DIR"
run mkdir -p "$PGO_DIR"

# Phase 1: instrumented build.
run env RUSTFLAGS="-Cprofile-generate=$PGO_DIR" \
    cargo build --release --manifest-path rust/Cargo.toml

BIN=rust/target/release/mmbsgd

# Phase 2: representative workloads.  Two synthetic-twin trainings
# cover the SGD margin loop, the tile engine, merge scoring (LUT and
# exact), and maintenance; the evaluate pass covers batched serving
# margins.  Small budgets keep the whole phase under a minute.
run "$BIN" train --dataset ijcnn --scale 0.05 --budget 128 --mergees 4 \
    --epochs 1 --seed 7 --threads 2 --quiet --save /tmp/mmbsgd-pgo-model.txt
run "$BIN" train --dataset adult --scale 0.05 --budget 64 --mergees 2 \
    --merge-score-mode exact --epochs 1 --seed 8 --threads 1 --quiet
run "$BIN" train --dataset ijcnn --scale 0.05 --budget 128 --mergees 4 \
    --epochs 1 --seed 7 --threads 2 --exp-mode vector --quiet
run "$BIN" evaluate --model /tmp/mmbsgd-pgo-model.txt --dataset ijcnn \
    --scale 0.05 --threads 2

# Phase 3: merge raw profiles.
run "$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"

# Phase 4: optimized build.
run env RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" \
    cargo build --release --manifest-path rust/Cargo.toml

echo "[pgo] done: $BIN built with profile-use"
echo "[pgo] compare: cargo bench --bench hot_paths, then scripts/perf_compare.sh"

//! Minimal, dependency-free drop-in for the subset of the `anyhow` API
//! this workspace uses: [`Error`], [`Result`], the [`Context`] trait
//! (`.context(..)` / `.with_context(..)` on `Result` and `Option`), and
//! the [`anyhow!`] / [`bail!`] macros.  Vendored so the workspace builds
//! fully offline (the container image carries no crates.io cache).
//!
//! Semantics mirror the real crate where it matters here:
//! * `{e}` displays the outermost message, `{e:#}` the whole context
//!   chain joined by `": "`, `{e:?}` a multi-line report with causes;
//! * `?` converts any `std::error::Error + Send + Sync + 'static` via
//!   the blanket `From` impl (which is also why [`Error`] itself must
//!   not implement `std::error::Error` — the reflexive `From` would
//!   conflict, exactly as in the real crate).

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: a stack of human-readable frames, the
/// outermost context first and the root cause last.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    /// Push an outer context frame.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The root-cause message (innermost frame).
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut frames = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            frames.push(cause.to_string());
            source = cause.source();
        }
        Error { frames }
    }
}

mod private {
    /// Seals [`super::Context`] to the impls below.
    pub trait Sealed {}
    impl<T, E> Sealed for std::result::Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

/// Attach context to a fallible value, converting the error to [`Error`].
pub trait Context<T>: private::Sealed {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, context: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        self.map_err(|e| e.into().context(context()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, context: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(context()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<f64> {
            let v: f64 = "not-a-number".parse()?;
            Ok(v)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.root_cause(), "no such file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
    }
}

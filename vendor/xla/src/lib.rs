//! Offline stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The container image carries no XLA native toolchain, so this crate
//! reproduces exactly the API surface `mmbsgd::runtime::xla_backend`
//! touches — `PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`,
//! `Literal`, `HloModuleProto`, `XlaComputation` — with every runtime
//! entry point failing cleanly: `PjRtClient::cpu()` returns an error,
//! so `XlaBackend::new` propagates it and callers degrade the same way
//! they do when the AOT artifacts are missing.
//!
//! To run the real PJRT path, point the workspace's `xla` path
//! dependency at the actual crate (github.com/LaurentMazare/xla-rs);
//! the signatures below are drop-in compatible with the 0.1.x API this
//! project was written against.

use std::path::Path;

/// Stub error; carries a message and supports `{:?}` like the real
/// crate's error enum.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla stub: PJRT is not available in this offline build \
         (vendor/xla is an API stub; link the real xla-rs crate)"
            .to_string(),
    ))
}

/// Host-side literal (stub: shape-less byte-free placeholder).
#[derive(Clone, Debug, Default)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal::default()
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: Clone + Default>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable()
    }
}

/// Computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Device-side buffer (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client (stub: construction always fails, which is the designed
/// degradation point — `XlaBackend::new` surfaces the error).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }

    #[test]
    fn literal_builders_are_total() {
        // vec1 itself must not fail (it is called before any execution),
        // but every device-facing method errors.
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}

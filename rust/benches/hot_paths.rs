//! Hot-path micro benches: the Θ(B·K) margin, the Θ(B·K·G) merge-scoring
//! pass (native vs XLA artifact), merge executors, and the
//! maintenance-strategy ablation (merge vs projection crossover).
//!
//! Run: `cargo bench --bench hot_paths [-- <filter>]`

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, enabled, group};

use mmbsgd::budget::golden::{self, GS_ITERS};
use mmbsgd::budget::{MaintenanceKind, Maintainer, MergeExec, MultiMerge, Projection};
use mmbsgd::data::DenseMatrix;
use mmbsgd::model::SvStore;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{ArtifactRegistry, Backend, NativeBackend, XlaBackend};

/// Store with *calibrated* geometry: coordinates scaled so that the
/// median pairwise γ·d² ≈ 5 — the regime real tuned RBF-SVMs (and our
/// synthetic twins) live in.  Raw standard-normal points would put every
/// pair past the far-pair cutoff and make the benches unrealistically
/// flattering to the exp-skip optimizations.
fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
    let gamma = 0.5;
    let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
    let mut rng = Xoshiro256::new(seed);
    let mut s = SvStore::new(d);
    for _ in 0..b {
        let x: Vec<f32> = (0..d)
            .map(|_| (scale * rng.next_gaussian()) as f32)
            .collect();
        s.push(&x, 0.1 + rng.next_f64());
    }
    s
}

fn main() {
    let gamma = 0.5;

    if enabled("margin") {
        group("margin1 (per-SGD-step cost, native)");
        for &(b, d) in &[(128usize, 32usize), (512, 128), (2048, 128)] {
            let svs = random_store(b, d, 1);
            let q: Vec<f32> = vec![0.1; d];
            let mut be = NativeBackend::new();
            bench(&format!("margin1/native/B{b}/d{d}"), 200, || {
                be.margin1(&svs, gamma, &q)
            });
        }
    }

    if enabled("merge_scores") {
        group("merge_scores (the paper's Θ(B·K·G) bottleneck)");
        for &(b, d) in &[(128usize, 32usize), (512, 128), (2048, 128)] {
            let svs = random_store(b, d, 2);
            let i = svs.min_abs_alpha().unwrap();
            let mut nat = NativeBackend::new();
            bench(&format!("merge_scores/native/B{b}/d{d}"), 300, || {
                nat.merge_scores(&svs, gamma, i)
            });
            if let Ok(mut x) = XlaBackend::new(&ArtifactRegistry::default_dir()) {
                // compile outside the timed region
                let _ = x.merge_scores(&svs, gamma, i);
                bench(&format!("merge_scores/xla/B{b}/d{d}"), 300, || {
                    x.merge_scores(&svs, gamma, i)
                });
            }
        }
    }

    if enabled("golden") {
        group("binary merge (scalar golden section, G=30)");
        bench("golden/merge_pair_params", 100, || {
            golden::merge_pair_params(0.3, 0.7, 1.7, GS_ITERS)
        });
        let x_i: Vec<f32> = (0..128).map(|i| i as f32 * 0.01).collect();
        let x_j: Vec<f32> = (0..128).map(|i| i as f32 * 0.011).collect();
        bench("golden/merge_pair/d128", 100, || {
            golden::merge_pair(&x_i, 0.3, &x_j, 0.7, gamma, GS_ITERS)
        });
    }

    if enabled("merge_gd") {
        group("MM-GD merge executor");
        let mut rng = Xoshiro256::new(3);
        for &m in &[3usize, 5, 10] {
            let pts_owned: Vec<(Vec<f32>, f64)> = (0..m)
                .map(|_| {
                    let p: Vec<f32> = (0..32).map(|_| rng.next_gaussian() as f32).collect();
                    (p, 0.5)
                })
                .collect();
            let pts: Vec<(&[f32], f64)> =
                pts_owned.iter().map(|(p, a)| (p.as_slice(), *a)).collect();
            let mut nat = NativeBackend::new();
            bench(&format!("merge_gd/native/M{m}/d32"), 200, || {
                nat.merge_gd(&pts, gamma)
            });
            if let Ok(mut x) = XlaBackend::new(&ArtifactRegistry::default_dir()) {
                let _ = x.merge_gd(&pts, gamma);
                bench(&format!("merge_gd/xla/M{m}/d32"), 200, || {
                    x.merge_gd(&pts, gamma)
                });
            }
        }
    }

    if enabled("maintenance") {
        group("one maintenance event: multi-merge vs projection (ablation)");
        for &b in &[64usize, 256, 512] {
            let mut be = NativeBackend::new();
            bench(&format!("maintain/merge2/B{b}"), 300, || {
                let mut svs = random_store(b + 1, 32, 4);
                MultiMerge::new(2, MergeExec::Cascade).maintain(&mut svs, gamma, b, &mut be)
            });
            bench(&format!("maintain/merge5/B{b}"), 300, || {
                let mut svs = random_store(b + 1, 32, 4);
                MultiMerge::new(5, MergeExec::Cascade).maintain(&mut svs, gamma, b, &mut be)
            });
            bench(&format!("maintain/projection/B{b}"), 300, || {
                let mut svs = random_store(b + 1, 32, 4);
                Projection::default().maintain(&mut svs, gamma, b, &mut be)
            });
        }
    }

    if enabled("eval") {
        group("batched evaluation (native vs xla artifact)");
        let svs = random_store(512, 128, 5);
        let mut rng = Xoshiro256::new(6);
        let rows: Vec<Vec<f32>> = (0..256)
            .map(|_| (0..128).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let q = DenseMatrix::from_rows(rows);
        let mut nat = NativeBackend::new();
        bench("eval/native/B512/d128/n256", 300, || nat.margins(&svs, gamma, &q));
        if let Ok(mut x) = XlaBackend::new(&ArtifactRegistry::default_dir()) {
            let _ = x.margins(&svs, gamma, &q);
            bench("eval/xla/B512/d128/n256", 300, || x.margins(&svs, gamma, &q));
        }
    }

    // Keep MaintenanceKind linked in (ablation completeness).
    let _ = MaintenanceKind::parse("merge:3");
}

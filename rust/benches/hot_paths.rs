//! Hot-path micro benches: the Θ(B·K) margin (norm-cached vs the seed's
//! difference-form loop), the blocked kernel-tile engine (scalar rows
//! vs tiled vs threaded batch margins, per-candidate vs batch merge
//! scoring), the merge-scoring pass (LUT vs exact golden section vs XLA
//! artifact), merge executors, the maintenance-strategy ablation
//! (merge vs projection crossover), and the fleet data plane (artifact
//! load+verify latency, ring-sharded 2-replica fan-out vs 1).
//!
//! Run: `cargo bench --bench hot_paths [-- <filter>]`
//!
//! Always writes `BENCH_hotpaths.json` (all runs + derived speedups) —
//! the machine-readable evidence for EXPERIMENTS.md §Perf.

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, emit_json, enabled, group, recorded_median};

use mmbsgd::budget::golden::{self, GS_ITERS};
use mmbsgd::budget::{MaintenanceKind, Maintainer, MergeExec, MergeLut, MultiMerge, Projection};
use mmbsgd::data::DenseMatrix;
use mmbsgd::kernel::{simd, sq_dist, sq_dist_cached, sq_norm, EXP_NEG_CUTOFF};
use mmbsgd::model::{SvStore, SvmModel};
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::pool::partition;
use mmbsgd::runtime::{
    margin1_native, tile, ArtifactRegistry, Backend, NativeBackend, TileBounds, WorkerPool,
    XlaBackend,
};
use mmbsgd::serve::{BatchEngine, ModelRegistry, Predictor, ShedPolicy};

/// Worker count for the threaded tile-engine cases ("N" in the
/// 1-vs-N-thread acceptance ratios).  CI runs the bench smoke with
/// `MMBSGD_BENCH_THREADS=2` to exercise the pool under the workflow.
/// Clamped to >= 2: the 1-thread case already runs as `tiled-t1`, and
/// reusing that name would record a duplicate bench and a self-ratio.
fn bench_threads() -> usize {
    std::env::var("MMBSGD_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(2)
}

/// Store with *calibrated* geometry: coordinates scaled so that the
/// median pairwise γ·d² ≈ 5 — the regime real tuned RBF-SVMs (and our
/// synthetic twins) live in.  Raw standard-normal points would put every
/// pair past the far-pair cutoff and make the benches unrealistically
/// flattering to the exp-skip optimizations.
fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
    let gamma = 0.5;
    let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
    let mut rng = Xoshiro256::new(seed);
    let mut s = SvStore::new(d);
    for _ in 0..b {
        let x: Vec<f32> = (0..d)
            .map(|_| (scale * rng.next_gaussian()) as f32)
            .collect();
        s.push(&x, 0.1 + rng.next_f64());
    }
    s
}

/// The seed's margin loop: difference-form squared distance per SV (no
/// norm cache) — kept verbatim as the before/after baseline.
fn margin1_seed_loop(svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
    let mut f = 0.0;
    for j in 0..svs.len() {
        let e = gamma * sq_dist(svs.point(j), x);
        if e < EXP_NEG_CUTOFF {
            f += svs.alpha(j) * (-e).exp();
        }
    }
    f
}

/// The PR-3 margin inner loop: norm-cached per-pair distance with the
/// `exp` call inlined behind the skip branch — the before side of the
/// `speedup/exp_batched_vs_inline` ratio (the after side is today's
/// `margin1_native`: block-kernel dots + one stripped exp pass).
fn margin1_inline_exp(svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
    let n_q = sq_norm(x);
    let mut f = 0.0;
    for j in 0..svs.len() {
        let d2 = sq_dist_cached(svs.point(j), svs.norm2(j), x, n_q);
        let e = gamma * d2;
        if e < EXP_NEG_CUTOFF {
            f += svs.alpha(j) * (-e).exp();
        }
    }
    f
}

fn main() {
    let gamma = 0.5;

    if enabled("margin") {
        group("margin1 (per-SGD-step cost): norm-cached vs seed loop");
        for &(b, d) in &[(128usize, 32usize), (512, 128), (2048, 128)] {
            let svs = random_store(b, d, 1);
            let q: Vec<f32> = vec![0.1; d];
            let mut be = NativeBackend::new();
            bench(&format!("margin1/native/B{b}/d{d}"), 200, || {
                be.margin1(&svs, gamma, &q)
            });
            bench(&format!("margin1/seed-loop/B{b}/d{d}"), 200, || {
                margin1_seed_loop(&svs, gamma, &q)
            });
        }
    }

    if enabled("tiles") {
        let nt = bench_threads();
        group("blocked margins (tile engine): scalar rows vs tiled vs threaded");
        for &(b, d, n) in &[(128usize, 32usize, 64usize), (512, 128, 256), (2048, 128, 256)] {
            let svs = random_store(b, d, 7);
            let mut rng = Xoshiro256::new(8);
            let scale = (5.0 / (0.5 * 2.0 * d as f64)).sqrt();
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| (scale * rng.next_gaussian()) as f32).collect())
                .collect();
            let q = DenseMatrix::from_rows(rows);
            // the pre-tile path: one scalar margin loop per query row
            bench(&format!("margins/scalar-rows/B{b}/d{d}/n{n}"), 300, || {
                (0..q.rows())
                    .map(|r| margin1_native(&svs, gamma, q.row(r)))
                    .collect::<Vec<f64>>()
            });
            let mut t1 = NativeBackend::new();
            bench(&format!("margins/tiled-t1/B{b}/d{d}/n{n}"), 300, || {
                t1.margins(&svs, gamma, &q)
            });
            let mut tn = NativeBackend::new();
            tn.set_threads(nt);
            bench(&format!("margins/tiled-t{nt}/B{b}/d{d}/n{n}"), 300, || {
                tn.margins(&svs, gamma, &q)
            });
        }

        group("merge_scores_batch: k per-event rescans vs one tiled pass");
        for &(b, d, k) in &[(128usize, 32usize, 8usize), (512, 128, 8), (2048, 128, 8)] {
            let svs = random_store(b, d, 9);
            let cands: Vec<usize> = (0..k).map(|c| c * (b / k)).collect();
            let mut be = NativeBackend::new();
            bench(&format!("merge_batch/per-event/B{b}/d{d}/k{k}"), 300, || {
                cands
                    .iter()
                    .map(|&i| be.merge_scores(&svs, gamma, i))
                    .collect::<Vec<_>>()
            });
            let mut b1 = NativeBackend::new();
            bench(&format!("merge_batch/tiled-t1/B{b}/d{d}/k{k}"), 300, || {
                b1.merge_scores_batch(&svs, gamma, &cands)
            });
            let mut bn = NativeBackend::new();
            bn.set_threads(nt);
            bench(&format!("merge_batch/tiled-t{nt}/B{b}/d{d}/k{k}"), 300, || {
                bn.merge_scores_batch(&svs, gamma, &cands)
            });
        }
    }

    if enabled("simd") {
        group("SIMD substrate: runtime-dispatched dot vs forced-scalar reference");
        for &d in &[32usize, 128, 300] {
            let mut rng = Xoshiro256::new(41);
            let q: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
            // 256 rows keep the working set L2-resident at every d, so
            // the ratio measures arithmetic, not DRAM.
            let n_rows = 256usize;
            let rows: Vec<f32> = (0..n_rows * d)
                .map(|_| rng.next_gaussian() as f32)
                .collect();
            bench(&format!("simd/dot-dispatch/d{d}"), 200, || {
                let mut s = 0.0;
                for r in 0..n_rows {
                    s += simd::dot(&q, &rows[r * d..(r + 1) * d]);
                }
                s
            });
            bench(&format!("simd/dot-block/d{d}"), 200, || {
                let mut out = vec![0.0f64; n_rows];
                simd::dot_block(&q, &rows, d, &mut out);
                out[0]
            });
            bench(&format!("simd/dot-scalar/d{d}"), 200, || {
                let mut s = 0.0;
                for r in 0..n_rows {
                    s += simd::dot_scalar(&q, &rows[r * d..(r + 1) * d]);
                }
                s
            });
        }
    }

    if enabled("pool") {
        let nt = bench_threads();
        group("pool dispatch: persistent parked workers vs per-call scoped spawn");
        for &(b, d, n) in &[(512usize, 64usize, 64usize), (512, 64, 128), (2048, 128, 256)] {
            let svs = random_store(b, d, 17);
            let bounds = TileBounds::of(&svs);
            let mut rng = Xoshiro256::new(18);
            let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| (scale * rng.next_gaussian()) as f32).collect())
                .collect();
            let q = DenseMatrix::from_rows(rows.clone());
            let pool = WorkerPool::new(nt);
            let mut out = vec![0.0f64; n];
            bench(&format!("pool/persistent-t{nt}/B{b}/d{d}/n{n}"), 300, || {
                tile::margins_bounded_into(&svs, gamma, &q, &bounds, &pool, &mut out);
                out[0]
            });
            // The scoped baseline replays the PR-3 design faithfully:
            // the same fixed partition (TILE_Q row chunks), one fresh
            // scoped thread per non-first chunk per pass, join on scope
            // exit.  Chunk matrices are prebuilt so both sides time
            // dispatch + compute, not packing.
            let ranges = partition(n, nt, 32);
            let chunk_qs: Vec<DenseMatrix> = ranges
                .iter()
                .map(|r| DenseMatrix::from_rows(rows[r.start..r.end].to_vec()))
                .collect();
            let single = WorkerPool::single();
            let mut out2 = vec![0.0f64; n];
            bench(&format!("pool/scoped-t{nt}/B{b}/d{d}/n{n}"), 300, || {
                let mut parts: Vec<&mut [f64]> = Vec::with_capacity(ranges.len());
                let mut rest = out2.as_mut_slice();
                for r in &ranges {
                    let (head, tail) = rest.split_at_mut(r.end - r.start);
                    parts.push(head);
                    rest = tail;
                }
                let (svs, bounds, single) = (&svs, &bounds, &single);
                std::thread::scope(|s| {
                    let mut work = chunk_qs.iter().zip(parts);
                    let mine = work.next();
                    for (qc, oc) in work {
                        s.spawn(move || {
                            tile::margins_bounded_into(svs, gamma, qc, bounds, single, oc)
                        });
                    }
                    if let Some((qc, oc)) = mine {
                        tile::margins_bounded_into(svs, gamma, qc, bounds, single, oc);
                    }
                });
            });
        }
    }

    if enabled("exp_batch") {
        group("inner-loop restructuring: block dots + batched exp vs per-pair inline");
        for &(b, d) in &[(512usize, 32usize), (2048, 64), (4096, 128)] {
            let svs = random_store(b, d, 23);
            let mut rng = Xoshiro256::new(24);
            let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
            let q: Vec<f32> = (0..d)
                .map(|_| (scale * rng.next_gaussian()) as f32)
                .collect();
            bench(&format!("exp_batch/batched/B{b}/d{d}"), 200, || {
                margin1_native(&svs, gamma, &q)
            });
            bench(&format!("exp_batch/inline/B{b}/d{d}"), 200, || {
                margin1_inline_exp(&svs, gamma, &q)
            });
        }
    }

    if enabled("exp_vector") {
        group("exponent substrate: vectorized polynomial vs libm exp");
        let mut rng = Xoshiro256::new(31);
        for &n in &[32usize, 128, 512] {
            // arguments span the whole live range [0, EXP_NEG_CUTOFF):
            // everything past the cutoff is branch-skipped before the
            // exp on both sides, so it never reaches either evaluator
            let args: Vec<f64> = (0..n).map(|_| rng.next_f64() * EXP_NEG_CUTOFF).collect();
            bench(&format!("exp_vector/libm/n{n}"), 200, || {
                let mut s = 0.0;
                for &e in &args {
                    s += (-e).exp();
                }
                s
            });
            let mut out = vec![0.0f64; n];
            bench(&format!("exp_vector/vector/n{n}"), 200, || {
                simd::exp_neg_block(&args, &mut out);
                out.iter().sum::<f64>()
            });
        }
    }

    if enabled("merge_scores") {
        group("merge_scores (the paper's Θ(B·K·G) bottleneck): lut vs exact");
        // Build the table outside every timed region.
        let _ = MergeLut::global();
        for &(b, d) in &[(128usize, 32usize), (512, 128), (2048, 128)] {
            let svs = random_store(b, d, 2);
            let i = svs.min_abs_alpha().unwrap();
            let mut exact = NativeBackend::exact();
            bench(&format!("merge_scores/native-exact/B{b}/d{d}"), 300, || {
                exact.merge_scores(&svs, gamma, i)
            });
            let mut lut = NativeBackend::new();
            bench(&format!("merge_scores/native-lut/B{b}/d{d}"), 300, || {
                lut.merge_scores(&svs, gamma, i)
            });
            if let Ok(mut x) = XlaBackend::new(&ArtifactRegistry::default_dir()) {
                // compile outside the timed region
                let _ = x.merge_scores(&svs, gamma, i);
                bench(&format!("merge_scores/xla/B{b}/d{d}"), 300, || {
                    x.merge_scores(&svs, gamma, i)
                });
            }
        }
    }

    if enabled("golden") {
        group("binary merge scoring: scalar golden section (G=30) vs LUT");
        bench("golden/merge_pair_params", 100, || {
            golden::merge_pair_params(0.3, 0.7, 1.7, GS_ITERS)
        });
        let lut = MergeLut::global();
        bench("golden/merge_pair_params_lut", 100, || {
            lut.merge_pair_params(0.3, 0.7, 1.7)
        });
        let x_i: Vec<f32> = (0..128).map(|i| i as f32 * 0.01).collect();
        let x_j: Vec<f32> = (0..128).map(|i| i as f32 * 0.011).collect();
        bench("golden/merge_pair/d128", 100, || {
            golden::merge_pair(&x_i, 0.3, &x_j, 0.7, gamma, GS_ITERS)
        });
    }

    if enabled("merge_gd") {
        group("MM-GD merge executor");
        let mut rng = Xoshiro256::new(3);
        for &m in &[3usize, 5, 10] {
            let pts_owned: Vec<(Vec<f32>, f64)> = (0..m)
                .map(|_| {
                    let p: Vec<f32> = (0..32).map(|_| rng.next_gaussian() as f32).collect();
                    (p, 0.5)
                })
                .collect();
            let pts: Vec<(&[f32], f64)> =
                pts_owned.iter().map(|(p, a)| (p.as_slice(), *a)).collect();
            let mut nat = NativeBackend::new();
            bench(&format!("merge_gd/native/M{m}/d32"), 200, || {
                nat.merge_gd(&pts, gamma)
            });
            if let Ok(mut x) = XlaBackend::new(&ArtifactRegistry::default_dir()) {
                let _ = x.merge_gd(&pts, gamma);
                bench(&format!("merge_gd/xla/M{m}/d32"), 200, || {
                    x.merge_gd(&pts, gamma)
                });
            }
        }
    }

    if enabled("serve") {
        group("serving: sequential decision1 vs micro-batched registry pass");
        for &(b, d, n) in &[(128usize, 32usize, 64usize), (512, 128, 256), (2048, 128, 256)] {
            let mut model = SvmModel::new(d, gamma);
            model.svs = random_store(b, d, 13);
            model.bias = 0.1;
            let mut rng = Xoshiro256::new(14);
            let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
            let rows: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..d).map(|_| (scale * rng.next_gaussian()) as f32).collect())
                .collect();
            let q = DenseMatrix::from_rows(rows);
            // one request at a time through the single-query path
            let mut single = Predictor::native(model.clone()).unwrap();
            bench(&format!("serve/single/B{b}/d{d}/n{n}"), 300, || {
                (0..q.rows())
                    .map(|r| single.decision1(q.row(r)).unwrap())
                    .collect::<Vec<f64>>()
            });
            // the same n requests coalesced by the micro-batcher
            // (including its per-request routing + queueing overhead)
            let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 1);
            reg.insert("m", model).unwrap();
            let mut eng = BatchEngine::new(n.max(1), 4 * n.max(1), ShedPolicy::Reject);
            bench(&format!("serve/batched/B{b}/d{d}/n{n}"), 300, || {
                for r in 0..q.rows() {
                    eng.submit(&reg, None, q.row(r).to_vec()).unwrap();
                }
                eng.flush(&mut reg)
            });
        }
    }

    if enabled("maintenance") {
        group("one maintenance event: multi-merge vs projection (ablation)");
        for &b in &[64usize, 256, 512] {
            let mut be = NativeBackend::new();
            bench(&format!("maintain/merge2/B{b}"), 300, || {
                let mut svs = random_store(b + 1, 32, 4);
                MultiMerge::new(2, MergeExec::Cascade).maintain(&mut svs, gamma, b, &mut be)
            });
            bench(&format!("maintain/merge5/B{b}"), 300, || {
                let mut svs = random_store(b + 1, 32, 4);
                MultiMerge::new(5, MergeExec::Cascade).maintain(&mut svs, gamma, b, &mut be)
            });
            bench(&format!("maintain/projection/B{b}"), 300, || {
                let mut svs = random_store(b + 1, 32, 4);
                Projection::default().maintain(&mut svs, gamma, b, &mut be)
            });
        }
    }

    if enabled("fleet") {
        use std::io::{BufRead, BufReader, Write};
        use std::net::{SocketAddr, TcpListener, TcpStream};
        use std::time::Duration;

        use mmbsgd::fleet::{Artifact, Controller, Provenance, ReplicaState, Ring};
        use mmbsgd::serve::{serve_fleet, ServeOptions};

        group("fleet: artifact load+verify, ring-sharded replica fan-out");
        let scratch =
            std::env::temp_dir().join(format!("mmbsgd_bench_fleet_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&scratch);
        std::fs::create_dir_all(&scratch).unwrap();

        // Disk → trusted model, the whole gauntlet: durable-footer
        // check, manifest parse, section checksum, model parse +
        // manifest cross-validation.
        let mut packaged = SvmModel::new(128, gamma);
        packaged.svs = random_store(256, 128, 27);
        packaged.bias = 0.1;
        let path = scratch.join("bench.artifact");
        Artifact::wrap("bench", 1, &packaged, Provenance::default(), "lut", "auto")
            .unwrap()
            .save(&path)
            .unwrap();
        bench("fleet/artifact-load-verify/B256/d128", 200, || {
            let a = Artifact::load(&path).unwrap();
            a.validate_model().unwrap().svs.len()
        });

        // Data-plane fan-out: the same n keyed decisions pipelined down
        // one connection to a single replica vs ring-sharded across two
        // replicas on overlapped connections.  Each replica's engine
        // thread is sequential, so two replicas are two engines — the
        // ratio is the capacity argument for replication itself.
        let (b, d, n) = (512usize, 128usize, 64usize);
        let mut model = SvmModel::new(d, gamma);
        model.svs = random_store(b, d, 28);
        model.bias = 0.05;
        let art = Artifact::wrap("bench", 1, &model, Provenance::default(), "lut", "auto").unwrap();
        let mut rng = Xoshiro256::new(29);
        let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
        let lines: Vec<String> = (0..n)
            .map(|k| {
                let row: Vec<String> = (0..d)
                    .map(|_| ((scale * rng.next_gaussian()) as f32).to_string())
                    .collect();
                format!("decision key=req-{k} {}\n", row.join(" "))
            })
            .collect();

        let bindp = || {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let a = l.local_addr().unwrap();
            (l, a)
        };
        let (l0, a0) = bindp();
        let (l1, a1) = bindp();
        let (dir0, dir1) = (scratch.join("rep0"), scratch.join("rep1"));
        let eps = vec![a0.to_string(), a1.to_string()];
        std::thread::scope(|s| {
            let serve_one = |l: TcpListener, dir: std::path::PathBuf| {
                move || {
                    let mut rep = ReplicaState::new(&dir).unwrap();
                    let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
                    serve_fleet(l, reg, &ServeOptions::default(), &mut rep).unwrap();
                }
            };
            s.spawn(serve_one(l0, dir0));
            s.spawn(serve_one(l1, dir1));
            let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
            for o in ctl.push(&art, true) {
                assert_eq!(o.result, Ok(1), "replica {} refused the bench artifact", o.endpoint);
            }

            let connect = |addr: SocketAddr| {
                let sx = TcpStream::connect(addr).unwrap();
                sx.set_nodelay(true).ok();
                (sx.try_clone().unwrap(), BufReader::new(sx))
            };

            // all n keys down one pipelined connection to replica 0
            let (mut w, mut r) = connect(a0);
            let all: String = lines.concat();
            bench(&format!("fleet/routed-1replica/B{b}/d{d}/n{n}"), 300, || {
                w.write_all(all.as_bytes()).unwrap();
                w.flush().unwrap();
                let mut reply = String::new();
                for _ in 0..n {
                    reply.clear();
                    r.read_line(&mut reply).unwrap();
                }
                reply.len()
            });

            // the same keys sharded by the router's ring (same seed and
            // vnode count the live router defaults would use)
            let ring = Ring::new(eps.clone(), 42, 64);
            let mut batches = vec![String::new(); 2];
            let mut counts = vec![0usize; 2];
            for (k, line) in lines.iter().enumerate() {
                let shard = ring.shard_of(format!("req-{k}").as_bytes()).unwrap();
                batches[shard].push_str(line);
                counts[shard] += 1;
            }
            let addrs = [a0, a1];
            let mut conns: Vec<(TcpStream, BufReader<TcpStream>, String, usize)> = (0..2)
                .filter(|&i| counts[i] > 0)
                .map(|i| {
                    let (w, r) = connect(addrs[i]);
                    (w, r, std::mem::take(&mut batches[i]), counts[i])
                })
                .collect();
            bench(&format!("fleet/routed-2replicas/B{b}/d{d}/n{n}"), 300, || {
                std::thread::scope(|s2| {
                    for c in conns.iter_mut() {
                        let (w, r, batch, cnt) = (&mut c.0, &mut c.1, &c.2, c.3);
                        s2.spawn(move || {
                            w.write_all(batch.as_bytes()).unwrap();
                            w.flush().unwrap();
                            let mut reply = String::new();
                            for _ in 0..cnt {
                                reply.clear();
                                r.read_line(&mut reply).unwrap();
                            }
                        });
                    }
                });
            });

            for addr in addrs {
                let (mut w, mut r) = connect(addr);
                w.write_all(b"shutdown\n").unwrap();
                w.flush().unwrap();
                let mut reply = String::new();
                r.read_line(&mut reply).unwrap();
            }
        });
        let _ = std::fs::remove_dir_all(&scratch);
    }

    if enabled("router") {
        use std::io::{BufRead, BufReader, Write};
        use std::net::{TcpListener, TcpStream};
        use std::time::Duration;

        use mmbsgd::fleet::{
            run_router, Artifact, Controller, Provenance, ReplicaState, RouterOptions,
        };
        use mmbsgd::serve::{serve_fleet, ServeOptions};

        group("router: serial single-link forwarding vs pooled concurrent workers");
        let scratch =
            std::env::temp_dir().join(format!("mmbsgd_bench_router_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&scratch);
        std::fs::create_dir_all(&scratch).unwrap();

        // The ISSUE 10 acceptance shape: a 2-replica fleet behind the
        // router, 4 concurrent clients each pipelining keyed decisions.
        // Serial = one link per replica and one forward in flight
        // (threads=1, pool=1); pooled = per-connection workers over a
        // 2-link pool.  Same ring seed, so both runs shard identically
        // — the ratio isolates the concurrency model.
        let (b, d, n, c) = (512usize, 128usize, 64usize, 4usize);
        let mut model = SvmModel::new(d, gamma);
        model.svs = random_store(b, d, 31);
        model.bias = 0.05;
        let art = Artifact::wrap("bench", 1, &model, Provenance::default(), "lut", "auto").unwrap();
        let mut rng = Xoshiro256::new(32);
        let scale = (5.0 / (gamma * 2.0 * d as f64)).sqrt();
        let lines: Vec<String> = (0..n)
            .map(|k| {
                let row: Vec<String> = (0..d)
                    .map(|_| ((scale * rng.next_gaussian()) as f32).to_string())
                    .collect();
                format!("decision key=req-{k} {}\n", row.join(" "))
            })
            .collect();
        // One pre-concatenated batch per client; every iteration writes
        // the whole batch and reads its replies back in order.
        let chunks: Vec<(String, usize)> =
            lines.chunks(n / c).map(|ch| (ch.concat(), ch.len())).collect();

        let bindp = || {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            let a = l.local_addr().unwrap();
            (l, a)
        };
        let (l0, a0) = bindp();
        let (l1, a1) = bindp();
        let eps = vec![a0.to_string(), a1.to_string()];
        std::thread::scope(|s| {
            let serve_one = |l: TcpListener, dir: std::path::PathBuf| {
                move || {
                    let mut rep = ReplicaState::new(&dir).unwrap();
                    let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
                    serve_fleet(l, reg, &ServeOptions::default(), &mut rep).unwrap();
                }
            };
            s.spawn(serve_one(l0, scratch.join("rep0")));
            s.spawn(serve_one(l1, scratch.join("rep1")));
            let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
            for o in ctl.push(&art, true) {
                assert_eq!(o.result, Ok(1), "replica {} refused the bench artifact", o.endpoint);
            }

            for (name, pool, threads) in [("serial", 1usize, 1usize), ("pooled", 2, 0)] {
                let (rl, ra) = bindp();
                let opts = RouterOptions {
                    seed: 42,
                    vnodes: 64,
                    timeout: Duration::from_secs(10),
                    probe_every: Duration::from_secs(600),
                    pool,
                    threads,
                };
                let eps2 = eps.clone();
                let router = s.spawn(move || run_router(rl, eps2, &opts).unwrap());

                let mut conns: Vec<(TcpStream, BufReader<TcpStream>, &str, usize)> = chunks
                    .iter()
                    .map(|(batch, cnt)| {
                        let sx = TcpStream::connect(ra).unwrap();
                        sx.set_nodelay(true).ok();
                        (sx.try_clone().unwrap(), BufReader::new(sx), batch.as_str(), *cnt)
                    })
                    .collect();
                bench(&format!("router/{name}/c{c}/n{n}"), 200, || {
                    std::thread::scope(|s2| {
                        for conn in conns.iter_mut() {
                            let (w, r, batch, cnt) = (&mut conn.0, &mut conn.1, conn.2, conn.3);
                            s2.spawn(move || {
                                w.write_all(batch.as_bytes()).unwrap();
                                w.flush().unwrap();
                                let mut reply = String::new();
                                for _ in 0..cnt {
                                    reply.clear();
                                    r.read_line(&mut reply).unwrap();
                                    assert!(reply.starts_with("ok "), "router error: {reply}");
                                }
                            });
                        }
                    });
                });
                drop(conns);

                let sx = TcpStream::connect(ra).unwrap();
                let mut w = sx.try_clone().unwrap();
                let mut r = BufReader::new(sx);
                w.write_all(b"shutdown\n").unwrap();
                w.flush().unwrap();
                let mut reply = String::new();
                r.read_line(&mut reply).unwrap();
                let report = router.join().unwrap();
                assert_eq!(report.replica_dead, 0, "{name} router marked a replica dead");
            }

            for addr in [a0, a1] {
                let sx = TcpStream::connect(addr).unwrap();
                let mut w = sx.try_clone().unwrap();
                let mut r = BufReader::new(sx);
                w.write_all(b"shutdown\n").unwrap();
                w.flush().unwrap();
                let mut reply = String::new();
                r.read_line(&mut reply).unwrap();
            }
        });
        let _ = std::fs::remove_dir_all(&scratch);
    }

    if enabled("eval") {
        group("batched evaluation (native vs xla artifact)");
        let svs = random_store(512, 128, 5);
        let mut rng = Xoshiro256::new(6);
        let rows: Vec<Vec<f32>> = (0..256)
            .map(|_| (0..128).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let q = DenseMatrix::from_rows(rows);
        let mut nat = NativeBackend::new();
        bench("eval/native/B512/d128/n256", 300, || nat.margins(&svs, gamma, &q));
        if let Ok(mut x) = XlaBackend::new(&ArtifactRegistry::default_dir()) {
            let _ = x.margins(&svs, gamma, &q);
            bench("eval/xla/B512/d128/n256", 300, || x.margins(&svs, gamma, &q));
        }
    }

    // Derived acceptance ratios (only for combinations that ran).
    let ratio = |num: &str, den: &str| -> Option<f64> {
        let n = recorded_median(num)?.as_secs_f64();
        let d = recorded_median(den)?.as_secs_f64();
        if d > 0.0 {
            Some(n / d)
        } else {
            None
        }
    };
    let mut derived: Vec<(String, f64)> = Vec::new();
    if let Some(s) = ratio(
        "merge_scores/native-exact/B512/d128",
        "merge_scores/native-lut/B512/d128",
    ) {
        println!("\nmerge_scores LUT speedup at B=512,d=128: {s:.2}x");
        derived.push(("speedup/merge_scores_lut_vs_exact/B512/d128".into(), s));
    }
    if let Some(s) = ratio("margin1/seed-loop/B512/d128", "margin1/native/B512/d128") {
        println!("margin1 norm-cache speedup at B=512,d=128: {s:.2}x");
        derived.push(("speedup/margin1_normcache_vs_seed/B512/d128".into(), s));
    }
    // Tile-engine acceptance ratios: scalar-vs-tiled and 1-vs-N-thread
    // for every (B, d, batch) shape that ran (ISSUE 3 gate: >= 3 shapes).
    let nt = bench_threads();
    for &(b, d, n) in &[(128usize, 32usize, 64usize), (512, 128, 256), (2048, 128, 256)] {
        let shape = format!("B{b}/d{d}/n{n}");
        if let Some(s) = ratio(
            &format!("margins/scalar-rows/{shape}"),
            &format!("margins/tiled-t1/{shape}"),
        ) {
            println!("margins tiled-vs-scalar speedup at {shape}: {s:.2}x");
            derived.push((format!("speedup/margins_tiled_vs_scalar/{shape}"), s));
        }
        if let Some(s) = ratio(
            &format!("margins/tiled-t1/{shape}"),
            &format!("margins/tiled-t{nt}/{shape}"),
        ) {
            println!("margins {nt}-thread speedup at {shape}: {s:.2}x");
            derived.push((format!("speedup/margins_threads{nt}_vs_1/{shape}"), s));
        }
    }
    for &(b, d, k) in &[(128usize, 32usize, 8usize), (512, 128, 8), (2048, 128, 8)] {
        let shape = format!("B{b}/d{d}/k{k}");
        if let Some(s) = ratio(
            &format!("merge_batch/per-event/{shape}"),
            &format!("merge_batch/tiled-t1/{shape}"),
        ) {
            println!("merge_scores_batch amortization at {shape}: {s:.2}x");
            derived.push((format!("speedup/merge_batch_vs_per_event/{shape}"), s));
        }
        if let Some(s) = ratio(
            &format!("merge_batch/tiled-t1/{shape}"),
            &format!("merge_batch/tiled-t{nt}/{shape}"),
        ) {
            derived.push((format!("speedup/merge_batch_threads{nt}_vs_1/{shape}"), s));
        }
    }
    // Serving acceptance ratio: micro-batched registry pass vs n
    // sequential single-query decisions (ISSUE 4 gate).
    for &(b, d, n) in &[(128usize, 32usize, 64usize), (512, 128, 256), (2048, 128, 256)] {
        let shape = format!("B{b}/d{d}/n{n}");
        if let Some(s) =
            ratio(&format!("serve/single/{shape}"), &format!("serve/batched/{shape}"))
        {
            println!("serve micro-batch speedup at {shape}: {s:.2}x");
            derived.push((format!("speedup/serve_batched_vs_single/{shape}"), s));
        }
    }
    // SIMD-substrate acceptance ratios (ISSUE 5 gate: 3 shapes each):
    // dispatched vs forced-scalar dots, persistent vs scoped pool
    // dispatch, batched-exp vs inline inner loop.
    for &d in &[32usize, 128, 300] {
        if let Some(s) =
            ratio(&format!("simd/dot-scalar/d{d}"), &format!("simd/dot-dispatch/d{d}"))
        {
            println!("dot dispatch speedup at d={d}: {s:.2}x");
            derived.push((format!("speedup/dot_simd_vs_scalar/d{d}"), s));
        }
    }
    for &(b, d, n) in &[(512usize, 64usize, 64usize), (512, 64, 128), (2048, 128, 256)] {
        let shape = format!("B{b}/d{d}/n{n}");
        if let Some(s) = ratio(
            &format!("pool/scoped-t{nt}/{shape}"),
            &format!("pool/persistent-t{nt}/{shape}"),
        ) {
            println!("persistent-pool speedup at {shape}: {s:.2}x");
            derived.push((format!("speedup/margins_persistent_vs_scoped/{shape}"), s));
        }
    }
    for &(b, d) in &[(512usize, 32usize), (2048, 64), (4096, 128)] {
        let shape = format!("B{b}/d{d}");
        if let Some(s) =
            ratio(&format!("exp_batch/inline/{shape}"), &format!("exp_batch/batched/{shape}"))
        {
            println!("batched-exp speedup at {shape}: {s:.2}x");
            derived.push((format!("speedup/exp_batched_vs_inline/{shape}"), s));
        }
    }
    // Exponent-substrate acceptance ratios (ISSUE 8 gate: 3 block
    // sizes): vectorized polynomial exp vs the libm loop.
    for &n in &[32usize, 128, 512] {
        if let Some(s) =
            ratio(&format!("exp_vector/libm/n{n}"), &format!("exp_vector/vector/n{n}"))
        {
            println!("vector-exp speedup at n={n}: {s:.2}x");
            derived.push((format!("speedup/exp_vector_vs_libm/n{n}"), s));
        }
    }
    // Fleet acceptance metrics (ISSUE 7 gate): artifact trust-path
    // latency in ms, and the 2-replica ring-sharded capacity ratio.
    if let Some(m) = recorded_median("fleet/artifact-load-verify/B256/d128") {
        let ms = m.as_secs_f64() * 1e3;
        println!("artifact load+verify at B=256,d=128: {ms:.3} ms");
        derived.push(("artifact_load_verify_ms".into(), ms));
    }
    if let Some(s) =
        ratio("fleet/routed-1replica/B512/d128/n64", "fleet/routed-2replicas/B512/d128/n64")
    {
        println!("ring-sharded 2-replica speedup at B512/d128/n64: {s:.2}x");
        derived.push(("speedup/router_2replicas_vs_1/B512/d128/n64".into(), s));
    }
    // Concurrent-router acceptance ratio (ISSUE 10 gate): per-client
    // workers over a pooled 2-link-per-replica data plane vs the
    // single-link one-forward-at-a-time baseline, 4 concurrent clients.
    if let Some(s) = ratio("router/serial/c4/n64", "router/pooled/c4/n64") {
        println!("pooled concurrent router speedup at c4/n64: {s:.2}x");
        derived.push(("speedup/router_pooled_vs_serial/c4/n64".into(), s));
    }
    emit_json("BENCH_hotpaths.json", &derived);

    // Keep MaintenanceKind linked in (ablation completeness).
    let _ = MaintenanceKind::parse("merge:3");
}

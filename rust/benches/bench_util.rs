//! First-party micro-bench harness (criterion is not vendored in this
//! offline image).  Adaptive iteration count, warmup, median/p10/p90
//! reporting — enough statistical hygiene for the before/after deltas
//! recorded in EXPERIMENTS.md §Perf.  Every report is also recorded so
//! drivers can dump a machine-readable summary via [`emit_json`].

// Included via `#[path]` by several bench drivers; not every driver
// uses every helper.
#![allow(dead_code)]

use std::cell::RefCell;
use std::time::{Duration, Instant};

#[derive(Clone)]
pub struct BenchReport {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

thread_local! {
    static RECORDS: RefCell<Vec<BenchReport>> = const { RefCell::new(Vec::new()) };
}

/// Median of a recorded bench by exact name (None if it never ran).
pub fn recorded_median(name: &str) -> Option<Duration> {
    RECORDS.with(|r| {
        r.borrow().iter().find(|b| b.name == name).map(|b| b.median)
    })
}

/// Write every recorded report (plus caller-computed derived ratios) as
/// a JSON document — the perf evidence file checked by CI and quoted in
/// EXPERIMENTS.md §Perf.
pub fn emit_json(path: &str, derived: &[(String, f64)]) {
    use mmbsgd::util::json::{obj, to_string, Json};
    let runs: Vec<Json> = RECORDS.with(|r| {
        r.borrow()
            .iter()
            .map(|b| {
                obj(vec![
                    ("name", Json::Str(b.name.clone())),
                    ("median_ns", Json::Num(b.median.as_nanos() as f64)),
                    ("p10_ns", Json::Num(b.p10.as_nanos() as f64)),
                    ("p90_ns", Json::Num(b.p90.as_nanos() as f64)),
                    ("iters", Json::Num(b.iters as f64)),
                ])
            })
            .collect()
    });
    let derived: Vec<Json> = derived
        .iter()
        .map(|(k, v)| obj(vec![("name", Json::Str(k.clone())), ("value", Json::Num(*v))]))
        .collect();
    let doc = obj(vec![
        ("schema", Json::Str("mmbsgd-bench-v1".into())),
        ("runs", Json::Arr(runs)),
        ("derived", Json::Arr(derived)),
    ]);
    match std::fs::write(path, to_string(&doc)) {
        Ok(()) => println!("\n[bench] wrote {path}"),
        Err(e) => eprintln!("\n[bench] FAILED writing {path}: {e}"),
    }
}

/// Benchmark `f`, auto-scaling iterations to ~`budget_ms` of wall clock.
pub fn bench<T>(name: &str, budget_ms: u64, mut f: impl FnMut() -> T) -> BenchReport {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let target = Duration::from_millis(budget_ms);
    let iters = ((target.as_secs_f64() / once.as_secs_f64()) as usize).clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed());
    }
    samples.sort();
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    let rep = BenchReport {
        name: name.to_string(),
        median: pick(0.5),
        p10: pick(0.1),
        p90: pick(0.9),
        iters,
    };
    println!(
        "{:48} median {:>12} p10 {:>12} p90 {:>12} (n={})",
        rep.name,
        fmt_dur(rep.median),
        fmt_dur(rep.p10),
        fmt_dur(rep.p90),
        rep.iters
    );
    RECORDS.with(|r| r.borrow_mut().push(rep.clone()));
    rep
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Group header.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

/// Filter from CLI args (cargo bench -- <substring>).
pub fn filter() -> Option<String> {
    std::env::args().skip(1).find(|a| !a.starts_with('-'))
}

pub fn enabled(name: &str) -> bool {
    match filter() {
        Some(f) => name.contains(&f),
        None => true,
    }
}

//! End-to-end benches — one group per paper table/figure, at a reduced
//! scale so `cargo bench` completes in minutes.  The full-resolution
//! regeneration lives in `mmbsgd experiment --id <table1|fig1|...>`;
//! these benches track the *cost* of each experiment's characteristic
//! workload so perf regressions show up in CI.
//!
//! Run: `cargo bench --bench paper_tables [-- <filter>]`

#[path = "bench_util.rs"]
mod bench_util;
use bench_util::{bench, enabled, group};

use mmbsgd::budget::MaintenanceKind;
use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::solver::bsgd;
use mmbsgd::solver::smo::{self, SmoParams};

const SCALE: f64 = 0.01;

fn cfg_for(spec: &SynthSpec, n_train: usize, budget: usize, m: usize) -> TrainConfig {
    TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, n_train),
        gamma: spec.gamma,
        budget,
        mergees: m,
        epochs: 1,
        seed: 1,
        ..TrainConfig::default()
    }
}

fn main() {
    // --- Table 1: cascade vs GD merge executor, ADULT ---
    if enabled("table1") {
        group("table1: one epoch ADULT, M=3, cascade vs GD (B=64)");
        let spec = SynthSpec::adult_like(SCALE);
        let split = dataset(&spec, 1);
        for (kind, tag) in [
            (MaintenanceKind::Merge { m: 3 }, "cascade"),
            (MaintenanceKind::MergeGd { m: 3 }, "gd"),
        ] {
            let mut cfg = cfg_for(&spec, split.train.len(), 64, 3);
            cfg.maintenance = Some(kind);
            bench(&format!("table1/epoch/{tag}"), 1500, || {
                bsgd::train(&split.train, &cfg).unwrap()
            });
        }
    }

    // --- Table 2: the exact-solver reference ---
    if enabled("table2") {
        group("table2: SMO reference solve (PHISHING subsample)");
        let spec = SynthSpec::phishing_like(SCALE * 4.0);
        let split = dataset(&spec, 1);
        let params = SmoParams { c: spec.c, gamma: spec.gamma, ..Default::default() };
        bench("table2/smo/phishing", 2000, || smo::train(&split.train, &params));
    }

    // --- Fig 1: merge-time fraction across M ---
    if enabled("fig1") {
        group("fig1: one epoch per M (ADULT, B=32): time should fall with M");
        let spec = SynthSpec::adult_like(SCALE);
        let split = dataset(&spec, 1);
        for m in [2usize, 5, 10] {
            let cfg = cfg_for(&spec, split.train.len(), 32, m);
            bench(&format!("fig1/epoch/M{m}"), 1500, || bsgd::train(&split.train, &cfg).unwrap());
        }
    }

    // --- Fig 2/3: accuracy/time sweeps — characteristic single runs ---
    if enabled("fig2") {
        group("fig2/3: one epoch per dataset family (B=64, M=4)");
        for spec in [
            SynthSpec::phishing_like(SCALE),
            SynthSpec::web_like(SCALE),
            SynthSpec::ijcnn_like(SCALE),
            SynthSpec::skin_like(SCALE),
        ] {
            let split = dataset(&spec, 1);
            let cfg = cfg_for(&spec, split.train.len(), 64, 4);
            bench(&format!("fig2/epoch/{}", spec.name), 1500, || {
                bsgd::train(&split.train, &cfg).unwrap()
            });
        }
    }

    // --- Fig 4: the Pareto workload = many (B, M) runs; bench one cell
    //     at the largest budget (dominates the sweep's cost) ---
    if enabled("fig4") {
        group("fig4: largest-budget cell (ADULT, B=256)");
        let spec = SynthSpec::adult_like(SCALE * 4.0);
        let split = dataset(&spec, 1);
        for m in [2usize, 11] {
            let cfg = cfg_for(&spec, split.train.len(), 256, m);
            bench(&format!("fig4/cell/M{m}"), 2000, || bsgd::train(&split.train, &cfg).unwrap());
        }
    }

    // --- Fig 5: hyperparameter grid — bench the extreme-γ cells that
    //     dominate its runtime ---
    if enabled("fig5") {
        group("fig5: extreme-gamma cells (PHISHING, B=64, M=3)");
        let mut spec = SynthSpec::phishing_like(SCALE);
        let split = dataset(&spec, 1);
        for gamma in [0.5, 128.0] {
            spec.gamma = gamma;
            let mut cfg = cfg_for(&spec, split.train.len(), 64, 3);
            cfg.gamma = gamma;
            bench(&format!("fig5/cell/gamma{gamma}"), 1500, || {
                bsgd::train(&split.train, &cfg).unwrap()
            });
        }
    }
}

//! Figure 4: accuracy-vs-training-time trade-off on ADULT for
//! M ∈ {2..11} across the budget grid, with the Pareto front of
//! non-dominated (time, accuracy) points.
//!
//! Shape to reproduce: the paper's decisive observation — all M = 2
//! (classic BSGD) runs sit *off* the Pareto front except at the largest
//! budget; merging more points and re-investing the saved time into a
//! larger budget dominates the baseline.

use super::common::{budget_grid, emit, reference_sv_count, run_all, spec_for, ExpOptions};
use crate::data::synth::SynthSpec;
use crate::util::stats::pareto_front;
use crate::util::table::{num, Table};
use anyhow::Result;

pub const MERGEES: std::ops::RangeInclusive<usize> = 2..=11;

pub fn run(opts: &ExpOptions) -> Result<()> {
    let data = SynthSpec::adult_like(opts.scale);
    println!("== Figure 4: accuracy/time Pareto, ADULT (scale={}) ==", opts.scale);
    let (n_sv, _) = reference_sv_count(&data, opts.scale, opts.seed)?;
    let budgets = budget_grid(n_sv);
    println!("[adult] reference #SV={} -> budgets {:?}", n_sv, budgets);

    let mut specs = Vec::new();
    for &b in &budgets {
        for m in MERGEES {
            specs.push(spec_for(&data, opts, b, m, opts.seed));
        }
    }
    let results = run_all(specs, 1)?; // timed sweep

    let times: Vec<f64> = results.iter().map(|r| r.train_seconds).collect();
    let accs: Vec<f64> = results.iter().map(|r| r.test_accuracy).collect();
    let front = pareto_front(&times, &accs);
    let on_front = |i: usize| front.contains(&i);

    let mut t = Table::new(&["B", "M", "train_sec", "accuracy_pct", "pareto"]);
    for (i, r) in results.iter().enumerate() {
        t.row(vec![
            r.budget.to_string(),
            r.mergees.to_string(),
            num(r.train_seconds, 3),
            num(100.0 * r.test_accuracy, 2),
            if on_front(i) { "*".into() } else { "-".into() },
        ]);
    }
    emit(&t, opts, "fig4")?;

    // Shape check: how many Pareto points are baseline (M=2)?
    let m2_on_front =
        front.iter().filter(|&&i| results[i].mergees == 2).count();
    println!(
        "[shape] Pareto front has {} points, {} of them M=2 \
         (paper: baseline off the front except at the largest budget)",
        front.len(),
        m2_on_front
    );
    Ok(())
}

//! Ablation of the paper's design choices (DESIGN.md §4, beyond the
//! published tables):
//!
//! 1. **Partner selection** (paper §3's "approximately transitive"
//!    heuristic): best-(M−1)-by-weight-degradation vs
//!    nearest-(M−1)-by-distance vs random partners.
//! 2. **Cascade order** (paper footnote 1): merging in increasing-wd
//!    order vs reversed.
//!
//! Run: `mmbsgd experiment --id ablation [--scale F]`.

use super::common::{emit, ExpOptions};
use crate::budget::golden::{self, GS_ITERS};
use crate::budget::{MaintStats, Maintainer};
use crate::config::TrainConfig;
use crate::data::synth::SynthSpec;
use crate::model::SvStore;
use crate::runtime::{exact_multi_wd, Backend, NativeBackend};
use crate::solver::bsgd;
use crate::util::table::{num, Table};
use anyhow::Result;

/// Partner-selection policies under ablation.
#[derive(Clone, Copy, Debug)]
pub enum Selection {
    /// The paper: best M−1 by pairwise weight degradation.
    ByWd,
    /// Geometric-only proxy: nearest M−1 by squared distance.
    ByDistance,
    /// Uniformly random M−1 partners (lower bound).
    Random,
    /// Reversed cascade order (still ByWd selection).
    ByWdReversedCascade,
}

/// A multi-merge maintainer with a configurable selection policy.
pub struct AblatedMerge {
    pub m: usize,
    pub selection: Selection,
    rng_state: u64,
}

impl AblatedMerge {
    pub fn new(m: usize, selection: Selection) -> Self {
        Self { m, selection, rng_state: 0x9E3779B97F4A7C15 }
    }

    fn next_rand(&mut self) -> u64 {
        // splitmix64 step — deterministic, dependency-free
        self.rng_state = self.rng_state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.rng_state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

impl Maintainer for AblatedMerge {
    fn maintain(
        &mut self,
        svs: &mut SvStore,
        gamma: f64,
        budget: usize,
        backend: &mut dyn Backend,
    ) -> MaintStats {
        let mut stats = MaintStats::default();
        while svs.len() > budget && svs.len() >= 2 {
            let i = svs.min_abs_alpha().expect("nonempty");
            let scores = backend.merge_scores(svs, gamma, i);
            let take = (self.m - 1).min(svs.len() - 1);
            let mut partners: Vec<usize> = match self.selection {
                Selection::ByWd | Selection::ByWdReversedCascade => {
                    let mut idx: Vec<usize> =
                        (0..svs.len()).filter(|&j| scores.wd[j].is_finite()).collect();
                    idx.sort_by(|&a, &b| scores.wd[a].total_cmp(&scores.wd[b]));
                    idx.truncate(take);
                    idx
                }
                Selection::ByDistance => {
                    let mut idx: Vec<usize> =
                        (0..svs.len()).filter(|&j| j != i).collect();
                    idx.sort_by(|&a, &b| scores.d2[a].total_cmp(&scores.d2[b]));
                    idx.truncate(take);
                    idx
                }
                Selection::Random => {
                    let mut idx: Vec<usize> =
                        (0..svs.len()).filter(|&j| j != i).collect();
                    // partial Fisher-Yates for `take` picks
                    for k in 0..take.min(idx.len()) {
                        let r = k + (self.next_rand() as usize) % (idx.len() - k);
                        idx.swap(k, r);
                    }
                    idx.truncate(take);
                    idx
                }
            };
            if matches!(self.selection, Selection::ByWdReversedCascade) {
                partners.reverse(); // most-expensive-first cascade
            }
            if partners.is_empty() {
                let a = svs.alpha(i);
                stats.weight_degradation += a * a;
                svs.swap_remove(i);
                stats.removed += 1;
                continue;
            }
            let merge_points: Vec<(Vec<f32>, f64)> = std::iter::once(i)
                .chain(partners.iter().copied())
                .map(|j| (svs.point(j).to_vec(), svs.alpha(j)))
                .collect();
            // cascade of binary merges in the given order
            let (mut z, mut a_z) = (merge_points[0].0.clone(), merge_points[0].1);
            for (p, a) in &merge_points[1..] {
                let (z2, a2, _) = golden::merge_pair(&z, a_z, p, *a, gamma, GS_ITERS);
                z = z2;
                a_z = a2;
                stats.merge_ops += 1;
            }
            let pts: Vec<(&[f32], f64)> =
                merge_points.iter().map(|(x, a)| (x.as_slice(), *a)).collect();
            stats.weight_degradation += exact_multi_wd(&pts, &z, a_z, gamma).max(0.0);
            let mut rm: Vec<usize> =
                std::iter::once(i).chain(partners.iter().copied()).collect();
            rm.sort_unstable_by(|a, b| b.cmp(a));
            for j in rm {
                svs.swap_remove(j);
            }
            svs.push(&z, a_z);
            stats.removed += merge_points.len() - 1;
        }
        stats
    }

    fn name(&self) -> &'static str {
        "ablated-merge"
    }
}

pub fn run(opts: &ExpOptions) -> Result<()> {
    println!("== Ablation: partner selection & cascade order (scale={}) ==", opts.scale);
    let spec = SynthSpec::adult_like(opts.scale);
    let split = crate::data::synth::dataset(&spec, opts.seed);
    let budget = ((600.0 * opts.scale) as usize).clamp(16, 4096);
    let m = 4;
    let cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget,
        mergees: m,
        epochs: opts.epochs,
        seed: opts.seed,
        ..TrainConfig::default()
    };

    let mut t = Table::new(&[
        "selection", "train_sec", "accuracy_pct", "events", "total_wd",
    ]);
    let variants: Vec<(&str, Selection)> = vec![
        ("by-wd (paper)", Selection::ByWd),
        ("by-distance", Selection::ByDistance),
        ("random", Selection::Random),
        ("by-wd, reversed cascade", Selection::ByWdReversedCascade),
    ];
    let mut wd_by_name = Vec::new();
    for (name, sel) in variants {
        // Run BSGD with the ablated maintainer by training manually:
        // reuse the solver via a custom Budget is not exposed, so drive
        // the comparison at the maintenance level on identical stores
        // PLUS a full training run using MultiMerge for the paper row.
        let mut backend = NativeBackend::new();
        let mut svs_seed = SvStore::new(split.train.dim());
        // Build a realistic overflowing store from the first 2B margin
        // violators of a vanilla run.
        let probe = bsgd::train(&split.train, &TrainConfig { budget: 10 * budget, ..cfg.clone() })?;
        for j in 0..probe.model.svs.len().min(budget + 40) {
            svs_seed.push(probe.model.svs.point(j), probe.model.svs.alpha(j));
        }
        let t0 = std::time::Instant::now();
        let mut maint = AblatedMerge::new(m, sel);
        let mut svs = svs_seed.clone();
        let stats = maint.maintain(&mut svs, cfg.gamma, budget, &mut backend);
        let secs = t0.elapsed().as_secs_f64();
        // Accuracy proxy: decision agreement with the pre-maintenance model.
        let q = crate::data::split::stratified_subsample(&split.test, 400, 1);
        let mut be2 = NativeBackend::new();
        let before = be2.margins(&svs_seed, cfg.gamma, &q.x);
        let after = be2.margins(&svs, cfg.gamma, &q.x);
        let agree = before
            .iter()
            .zip(&after)
            .filter(|(a, b)| (a.signum() - b.signum()).abs() < 0.5)
            .count() as f64
            / before.len() as f64;
        t.row(vec![
            name.to_string(),
            num(secs, 4),
            num(100.0 * agree, 2),
            (stats.removed / (m - 1).max(1)).to_string(),
            format!("{:.3e}", stats.weight_degradation),
        ]);
        wd_by_name.push((name, stats.weight_degradation));
    }
    emit(&t, opts, "ablation")?;
    let paper_wd = wd_by_name[0].1;
    let random_wd = wd_by_name[2].1;
    println!(
        "[shape] total wd: by-wd {:.3e} vs random {:.3e} ({}x) — the paper's \
         selection heuristic is what keeps multi-merge cheap",
        paper_wd,
        random_wd,
        num(random_wd / paper_wd.max(1e-12), 1)
    );
    Ok(())
}

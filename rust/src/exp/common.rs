//! Shared experiment machinery: options, budget grids, SV-count
//! reference estimation, and result printing.

use crate::config::{BackendChoice, TrainConfig};
use crate::coordinator::{run_grid, RunResult, RunSpec};
use crate::data::synth::SynthSpec;
use crate::solver::smo::{self, SmoParams};
use crate::util::table::Table;
use anyhow::Result;
use std::path::PathBuf;

/// Options shared by all experiment drivers (CLI surface).
#[derive(Clone, Debug)]
pub struct ExpOptions {
    /// Dataset size multiplier (1.0 = paper size).  Experiments default
    /// to CI-scale fractions; the driver prints the scale it used.
    pub scale: f64,
    /// Workers for accuracy-only sweeps (timed sweeps always run 1).
    pub threads: usize,
    /// Where CSVs land.
    pub out_dir: PathBuf,
    /// Backend for the runs.
    pub backend: BackendChoice,
    /// Base RNG seed.
    pub seed: u64,
    /// Epochs (paper: 1).
    pub epochs: usize,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            scale: 0.05,
            threads: default_threads(),
            out_dir: PathBuf::from("results"),
            backend: BackendChoice::Native,
            seed: 1,
            epochs: 1,
        }
    }
}

pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Paper budget fractions of the reference SV count (sec. 4.2):
/// "roughly {1%, 5%, 10%, 15%, 25%, 50%}".
pub const BUDGET_FRACTIONS: [f64; 6] = [0.01, 0.05, 0.10, 0.15, 0.25, 0.50];

/// Estimate the full-SVM support-vector count for a dataset spec by
/// solving a stratified subsample with SMO and extrapolating linearly
/// (Steinwart 2003: #SV grows linearly in n).  Returns (n_sv_estimate,
/// subsample_accuracy).
pub fn reference_sv_count(spec: &SynthSpec, _scale: f64, seed: u64) -> Result<(usize, f64)> {
    let split = crate::data::synth::dataset(spec, seed);
    let cap = 1500usize.min(split.train.len());
    let sub = crate::data::split::stratified_subsample(&split.train, cap, seed ^ 0xABCD);
    let params = SmoParams { c: spec.c, gamma: spec.gamma, ..Default::default() };
    let (model, stats) = smo::train(&sub, &params);
    // Batched through the blocked kernel-tile engine
    // (`SvmModel::accuracy` → `runtime::tile::margins`), not a
    // per-query margin loop — reference evaluation on the full test
    // split is itself a hot path at experiment scale.
    let acc = model.accuracy(&split.test);
    let frac = stats.n_sv as f64 / sub.len() as f64;
    let est = (frac * split.train.len() as f64).round() as usize;
    Ok((est.max(8), acc))
}

/// Budgets for a dataset: paper fractions of the reference SV count,
/// clamped to the artifact lattice maximum (4096) and deduplicated.
pub fn budget_grid(n_sv_reference: usize) -> Vec<usize> {
    let mut budgets: Vec<usize> = BUDGET_FRACTIONS
        .iter()
        .map(|f| ((n_sv_reference as f64 * f).round() as usize).clamp(8, 4096))
        .collect();
    budgets.dedup();
    budgets
}

/// Build one RunSpec for a (dataset, B, M) grid point.
pub fn spec_for(
    data: &SynthSpec,
    opts: &ExpOptions,
    budget: usize,
    mergees: usize,
    seed: u64,
) -> RunSpec {
    RunSpec {
        name: format!("{}-B{}-M{}", data.name, budget, mergees),
        data: data.clone(),
        data_seed: opts.seed,
        cfg: TrainConfig {
            cost_c: Some(data.c), // resolved against train size by the coordinator
            gamma: data.gamma,
            budget,
            mergees,
            epochs: opts.epochs,
            seed,
            backend: opts.backend,
            ..TrainConfig::default()
        },
    }
}

/// Run a grid, unwrap, keep order.  Timed experiments pass threads = 1.
pub fn run_all(specs: Vec<RunSpec>, threads: usize) -> Result<Vec<RunResult>> {
    run_grid(specs, threads).into_iter().collect()
}

/// Print + save a table under the experiment's name.
pub fn emit(table: &Table, opts: &ExpOptions, name: &str) -> Result<()> {
    println!("{}", table.render());
    let path = table.save_csv(&opts.out_dir, name)?;
    println!("[saved] {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_grid_shapes() {
        let g = budget_grid(1000);
        assert_eq!(g, vec![10, 50, 100, 150, 250, 500]);
        // tiny reference clamps at 8 and dedups
        let g = budget_grid(20);
        assert!(g.iter().all(|&b| b >= 8));
        assert!(g.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn reference_sv_count_runs_on_tiny_data() {
        let spec = SynthSpec::ijcnn_like(0.01);
        let (n_sv, acc) = reference_sv_count(&spec, 0.01, 1).unwrap();
        assert!(n_sv >= 8);
        assert!(acc > 0.6, "reference accuracy {acc}");
    }

    #[test]
    fn spec_for_carries_paper_hparams() {
        let data = SynthSpec::adult_like(0.01);
        let opts = ExpOptions::default();
        let s = spec_for(&data, &opts, 64, 3, 9);
        assert_eq!(s.cfg.gamma, 0.008);
        assert_eq!(s.cfg.cost_c, Some(32.0)); // pending C, resolved by run_on_split
        assert_eq!(s.cfg.budget, 64);
        // unresolved C must be a dedicated, actionable error
        assert!(matches!(
            s.cfg.validate(),
            Err(crate::error::TrainError::UnresolvedCost { .. })
        ));
    }
}

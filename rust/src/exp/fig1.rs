//! Figure 1: fraction of training time spent merging, as a function of
//! the number of mergees M, for budgets B ∈ {100, 500} on ADULT and
//! IJCNN.
//!
//! Shape to reproduce: at M = 2 merging eats a large fraction (the
//! paper measures ~45-85 % depending on budget); the fraction falls
//! roughly like 1/(M−1) because one scoring pass now retires M−1
//! margin-violating points.

use super::common::{emit, run_all, spec_for, ExpOptions};
use crate::data::synth::SynthSpec;
use crate::util::table::{num, Table};
use anyhow::Result;

pub const PAPER_BUDGETS: [usize; 2] = [100, 500];
pub const MERGEES: std::ops::RangeInclusive<usize> = 2..=11;

pub fn run(opts: &ExpOptions) -> Result<()> {
    println!("== Figure 1: merge-time fraction vs M (scale={}) ==", opts.scale);
    let datasets = [SynthSpec::adult_like(opts.scale), SynthSpec::ijcnn_like(opts.scale)];
    let mut t = Table::new(&["dataset", "B", "M", "merge_fraction", "train_sec", "events"]);
    for data in &datasets {
        for &b_paper in &PAPER_BUDGETS {
            let b = ((b_paper as f64 * opts.scale).round() as usize).clamp(8, 4096);
            let specs: Vec<_> = MERGEES
                .map(|m| spec_for(data, opts, b, m, opts.seed))
                .collect();
            // timed measurement — single worker
            let results = run_all(specs, 1)?;
            for r in &results {
                t.row(vec![
                    data.name.to_string(),
                    b.to_string(),
                    r.mergees.to_string(),
                    num(r.merge_fraction, 4),
                    num(r.train_seconds, 3),
                    r.maintenance_events.to_string(),
                ]);
            }
            let f2 = results[0].merge_fraction;
            let f11 = results.last().unwrap().merge_fraction;
            println!(
                "[shape] {} B={b}: fraction M=2 {:.1}% -> M=11 {:.1}% (paper: falls sharply)",
                data.name,
                100.0 * f2,
                100.0 * f11
            );
        }
    }
    emit(&t, opts, "fig1")
}

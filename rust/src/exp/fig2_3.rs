//! Figures 2 and 3: test accuracy (left) and training time (right) of
//! multi-merge for M ∈ {2,3,4,5} across the paper's budget grid.
//! Fig. 2 covers PHISHING / WEB / ADULT; Fig. 3 covers IJCNN / SKIN.
//!
//! Shapes to reproduce: training time falls systematically with M
//! (log-scale time axis in the paper); accuracy is roughly monotone in
//! B and shows no systematic degradation for moderate M.

use super::common::{budget_grid, emit, reference_sv_count, run_all, spec_for, ExpOptions};
use crate::data::synth::SynthSpec;
use crate::util::table::{num, Table};
use anyhow::Result;

pub const MERGEES: [usize; 4] = [2, 3, 4, 5];

pub fn run_figure(opts: &ExpOptions, fig: u8) -> Result<()> {
    let datasets: Vec<SynthSpec> = match fig {
        2 => vec![
            SynthSpec::phishing_like(opts.scale),
            SynthSpec::web_like(opts.scale),
            SynthSpec::adult_like(opts.scale),
        ],
        3 => vec![SynthSpec::ijcnn_like(opts.scale), SynthSpec::skin_like(opts.scale)],
        _ => anyhow::bail!("figure must be 2 or 3"),
    };
    println!("== Figure {fig}: accuracy & time vs B for M in 2..5 (scale={}) ==", opts.scale);
    let mut t = Table::new(&[
        "dataset", "B", "M", "accuracy_pct", "train_sec", "merge_fraction", "ref_acc_pct",
    ]);
    for data in &datasets {
        let (n_sv, ref_acc) = reference_sv_count(data, opts.scale, opts.seed)?;
        let budgets = budget_grid(n_sv);
        println!(
            "[{}] reference #SV={} -> budgets {:?} (exact acc {:.2}%)",
            data.name,
            n_sv,
            budgets,
            100.0 * ref_acc
        );
        let mut specs = Vec::new();
        for &b in &budgets {
            for &m in &MERGEES {
                specs.push(spec_for(data, opts, b, m, opts.seed));
            }
        }
        let results = run_all(specs, 1)?; // timed sweep
        for r in &results {
            t.row(vec![
                data.name.to_string(),
                r.budget.to_string(),
                r.mergees.to_string(),
                num(100.0 * r.test_accuracy, 2),
                num(r.train_seconds, 3),
                num(r.merge_fraction, 4),
                num(100.0 * ref_acc, 2),
            ]);
        }
        // Shape check: per budget, time(M=5) < time(M=2).
        for &b in &budgets {
            let tm = |m: usize| {
                results
                    .iter()
                    .find(|r| r.budget == b && r.mergees == m)
                    .map(|r| r.train_seconds)
                    .unwrap_or(f64::NAN)
            };
            println!(
                "[shape] {} B={b}: sec M=2 {:.3} vs M=5 {:.3} ({}x)",
                data.name,
                tm(2),
                tm(5),
                num(tm(2) / tm(5).max(1e-9), 2),
            );
        }
    }
    emit(&t, opts, &format!("fig{fig}"))
}

//! Figure 5: hyperparameter study on PHISHING — a 3×3 grid of (C, γ)
//! with the tuned configuration (C=8, γ=8) at the center.  For each
//! cell: the exact-solver accuracy (dashed line in the paper), plain
//! BSGD (M=2), and multi-merge with M ∈ {3,4,5} across budgets tracking
//! that cell's reference SV count.
//!
//! Shapes to reproduce: γ moves results much more than C; small γ is
//! noisy for every method; multi-merge tracks plain BSGD across the
//! whole grid (no systematic hyperparameter sensitivity of the method).

use super::common::{budget_grid, emit, run_all, spec_for, ExpOptions};
use crate::data::split::stratified_subsample;
use crate::data::synth::SynthSpec;
use crate::solver::smo::{self, SmoParams};
use crate::util::table::{num, Table};
use anyhow::Result;

pub const C_GRID: [f64; 3] = [2.0, 8.0, 32.0];
pub const GAMMA_GRID: [f64; 3] = [0.5, 8.0, 128.0];
pub const MERGEES: [usize; 4] = [2, 3, 4, 5];

pub fn run(opts: &ExpOptions) -> Result<()> {
    let base = SynthSpec::phishing_like(opts.scale);
    println!("== Figure 5: (C, gamma) study on PHISHING (scale={}) ==", opts.scale);
    let split = crate::data::synth::dataset(&base, opts.seed);
    let mut t = Table::new(&[
        "C", "gamma", "B", "method", "M", "accuracy_pct", "train_sec", "exact_acc_pct",
    ]);

    for &gamma in &GAMMA_GRID {
        for &c in &C_GRID {
            // Exact reference for this cell (subsampled SMO).
            let cap = 1200usize.min(split.train.len());
            let sub = stratified_subsample(&split.train, cap, opts.seed ^ 0x51);
            let (ref_model, stats) =
                smo::train(&sub, &SmoParams { c, gamma, ..Default::default() });
            let exact_acc = ref_model.accuracy(&split.test);
            let n_sv_est = ((stats.n_sv as f64 / sub.len() as f64)
                * split.train.len() as f64)
                .round() as usize;
            let budgets = budget_grid(n_sv_est.max(8));
            println!(
                "[cell C={c} gamma={gamma}] exact acc {:.2}%, est #SV {} -> budgets {:?}",
                100.0 * exact_acc,
                n_sv_est,
                budgets
            );

            let mut data = base.clone();
            data.c = c;
            data.gamma = gamma;
            let mut specs = Vec::new();
            for &b in &budgets {
                for &m in &MERGEES {
                    specs.push(spec_for(&data, opts, b, m, opts.seed));
                }
            }
            // Accuracy-focused sweep — parallel workers are fine here;
            // the paper's Fig. 5 y-axis is accuracy only.
            let results = run_all(specs, opts.threads)?;
            for r in &results {
                t.row(vec![
                    num(c, 0),
                    format!("{gamma}"),
                    r.budget.to_string(),
                    if r.mergees == 2 { "bsgd".into() } else { "mm".into() },
                    r.mergees.to_string(),
                    num(100.0 * r.test_accuracy, 2),
                    num(r.train_seconds, 3),
                    num(100.0 * exact_acc, 2),
                ]);
            }
        }
    }
    emit(&t, opts, "fig5")
}

//! Experiment drivers — one per table/figure of the paper.
//! (Populated module-by-module; see DESIGN.md §4 for the index.)

pub mod ablation;
pub mod common;
pub mod fig1;
pub mod fig2_3;
pub mod fig4;
pub mod fig5;
pub mod table1;
pub mod table2;

pub use common::ExpOptions;

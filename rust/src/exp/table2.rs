//! Table 2: dataset statistics, tuned hyperparameters, and the "exact"
//! reference solution (our SMO stand-in for LIBSVM) per dataset.

use super::common::{emit, reference_sv_count, ExpOptions};
use crate::data::synth::SynthSpec;
use crate::util::table::{num, Table};
use anyhow::Result;

pub fn run(opts: &ExpOptions) -> Result<()> {
    println!("== Table 2: datasets + exact-solver reference (scale={}) ==", opts.scale);
    let mut t = Table::new(&[
        "data set",
        "size",
        "# features",
        "C",
        "gamma",
        "test acc (ours)",
        "test acc (paper)",
        "ref #SV (est)",
    ]);
    for spec in SynthSpec::paper_suite(opts.scale) {
        let (n_sv, acc) = reference_sv_count(&spec, opts.scale, opts.seed)?;
        t.row(vec![
            spec.name.to_uppercase(),
            spec.n.to_string(),
            spec.dim.to_string(),
            num(spec.c, 0),
            format!("{}", spec.gamma),
            num(100.0 * acc, 2),
            num(100.0 * spec.paper_accuracy, 2),
            n_sv.to_string(),
        ]);
    }
    emit(&t, opts, "table2")
}

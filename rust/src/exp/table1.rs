//! Table 1: merging M=3 via the cascade (3→2→1, Alg. 1) vs joint
//! gradient descent (3→1, Alg. 2) on ADULT — training seconds and test
//! accuracy over budgets B ∈ {120, 600, 1200, 1800, 2500}.
//!
//! Paper finding to reproduce: GD is slightly faster, accuracies nearly
//! equal — the merge *executor* does not matter much.

use super::common::{emit, run_all, spec_for, ExpOptions};
use crate::budget::MaintenanceKind;
use crate::data::synth::SynthSpec;
use crate::util::table::{num, Table};
use anyhow::Result;

pub const PAPER_BUDGETS: [usize; 5] = [120, 600, 1200, 1800, 2500];

pub fn run(opts: &ExpOptions) -> Result<()> {
    let data = SynthSpec::adult_like(opts.scale);
    println!(
        "== Table 1: 3->2->1 (Alg.1) vs 3->1 (Alg.2), ADULT scale={} ==",
        opts.scale
    );
    // Budgets scale with the dataset so the maintenance pressure matches
    // the paper's regime.
    let budgets: Vec<usize> = PAPER_BUDGETS
        .iter()
        .map(|&b| ((b as f64 * opts.scale).round() as usize).clamp(8, 4096))
        .collect();

    let mut specs = Vec::new();
    for &(kind, label) in &[
        (MaintenanceKind::Merge { m: 3 }, "cascade"),
        (MaintenanceKind::MergeGd { m: 3 }, "gd"),
    ] {
        for &b in &budgets {
            let mut s = spec_for(&data, opts, b, 3, opts.seed);
            s.cfg.maintenance = Some(kind);
            s.name = format!("{label}-B{b}");
            specs.push(s);
        }
    }
    // Timed comparison: single-threaded.
    let results = run_all(specs, 1)?;
    let (cascade, gd) = results.split_at(budgets.len());

    let mut header = vec!["B".to_string()];
    header.extend(budgets.iter().map(|b| b.to_string()));
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let row = |tag: &str, vals: Vec<String>| {
        let mut r = vec![tag.to_string()];
        r.extend(vals);
        r
    };
    t.row(row(
        "Merging (3->2->1) sec",
        cascade.iter().map(|r| num(r.train_seconds, 3)).collect(),
    ));
    t.row(row(
        "Merging (3->2->1) %",
        cascade.iter().map(|r| num(100.0 * r.test_accuracy, 2)).collect(),
    ));
    t.row(row(
        "Merging (3->1) sec",
        gd.iter().map(|r| num(r.train_seconds, 3)).collect(),
    ));
    t.row(row(
        "Merging (3->1) %",
        gd.iter().map(|r| num(100.0 * r.test_accuracy, 2)).collect(),
    ));
    emit(&t, opts, "table1")?;

    // Paper-shape check, printed for EXPERIMENTS.md.
    let sec_c: f64 = cascade.iter().map(|r| r.train_seconds).sum();
    let sec_g: f64 = gd.iter().map(|r| r.train_seconds).sum();
    let max_acc_gap = cascade
        .iter()
        .zip(gd)
        .map(|(a, b)| (a.test_accuracy - b.test_accuracy).abs())
        .fold(0.0, f64::max);
    println!(
        "[shape] total sec cascade={:.3} gd={:.3} (paper: gd slightly faster); \
         max |acc gap| = {:.2} pp (paper: nearly equal)",
        sec_c,
        sec_g,
        100.0 * max_acc_gap
    );
    Ok(())
}

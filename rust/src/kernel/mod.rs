//! Kernel functions.
//!
//! The paper (and its merging math) is specific to the Gaussian kernel —
//! merging relies on the pre-image of a sum of two Gaussians lying on the
//! connecting line — so [`Gaussian`] is the kernel the solvers use.
//! [`Linear`] and [`Polynomial`] exist for the SMO reference solver and
//! for sanity baselines.

mod cache;
pub mod simd;
pub use cache::RowCache;
pub use simd::{dot_block, ExpMode, Isa, SimdMode};

/// A Mercer kernel over dense `f32` vectors.
pub trait Kernel: Send + Sync {
    fn eval(&self, a: &[f32], b: &[f32]) -> f64;

    /// k(x, x) — 1.0 for the Gaussian; overridable for others.
    fn self_eval(&self, a: &[f32]) -> f64 {
        self.eval(a, a)
    }

    fn name(&self) -> &'static str;
}

/// Squared euclidean distance ‖a−b‖², runtime-dispatched to the best
/// available ISA ([`simd::sq_dist`]: AVX2 / SSE2 / NEON / scalar, all
/// bit-identical — the fixed 8-lane accumulator layout is the
/// determinism contract, see the [`simd`] module docs).
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    simd::sq_dist(a, b)
}

/// Dot product ⟨a,b⟩ with the same fixed 8-lane accumulator layout as
/// [`sq_dist`], runtime-dispatched ([`simd::dot`]).  The norm-cached
/// hot paths prefer this over the difference form: one multiply per
/// lane instead of a subtract plus a multiply.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    simd::dot(a, b)
}

/// Squared euclidean norm ‖a‖² (cached per SV by
/// [`crate::model::SvStore`]).
#[inline]
pub fn sq_norm(a: &[f32]) -> f64 {
    dot(a, a)
}

/// Relative threshold below which the norm expansion is considered
/// cancellation-dominated and [`sq_dist_cached`] recomputes with the
/// difference form.  The f32 lane accumulators carry ~1e-7 relative
/// error, so an expansion result under 1e-4 of the operand magnitude
/// may hold only noise; the guard costs one compare per pair and fires
/// only for near-coincident points (which are exactly the pairs whose
/// d² the merge scorer must rank correctly).
const SQ_DIST_CANCEL_REL: f64 = 1e-4;

/// Norm-cached squared distance: `d² = ‖a‖² + ‖b‖² − 2⟨a,b⟩` with the
/// norms supplied from a cache, so the inner loop is a pure dot product.
///
/// Near-coincident points make the expansion cancellation-dominated
/// (the three ~‖x‖²-magnitude terms annihilate), so results below
/// [`SQ_DIST_CANCEL_REL`] of the operand magnitude — including the
/// epsilon-negative ones — are recomputed with the exact difference
/// form, which subtracts componentwise *before* squaring and loses
/// nothing to cancellation.
#[inline]
pub fn sq_dist_cached(a: &[f32], norm2_a: f64, b: &[f32], norm2_b: f64) -> f64 {
    sq_dist_cached_with_dot(a, norm2_a, b, norm2_b, dot(a, b))
}

/// [`sq_dist_cached`] with the dot product supplied by the caller — the
/// tile engine computes a whole block of dots through the
/// [`simd::dot_block`] micro-kernel and feeds each one here, so the
/// expansion *and the cancellation guard* stay byte-for-byte the same
/// decision the per-pair path makes (`dot_block` values are
/// bit-identical to [`dot`], and IEEE addition/multiplication are
/// bitwise commutative, so argument order cannot change the result).
#[inline]
pub fn sq_dist_cached_with_dot(
    a: &[f32],
    norm2_a: f64,
    b: &[f32],
    norm2_b: f64,
    dot_ab: f64,
) -> f64 {
    let d2 = norm2_a + norm2_b - 2.0 * dot_ab;
    if d2 < SQ_DIST_CANCEL_REL * (norm2_a + norm2_b) {
        sq_dist(a, b)
    } else {
        d2
    }
}

/// Exponent threshold above which `exp(-e)` is treated as exactly zero
/// on the native hot paths: `e^-40 ≈ 4e-18` is far below f32 resolution
/// of any accumulated margin, and the guard skips the (dominant) `exp`
/// call for far pairs — the common case on clustered data.
pub const EXP_NEG_CUTOFF: f64 = 40.0;

/// Gaussian (RBF) kernel `k(x,x') = exp(-gamma ||x-x'||^2)`.
#[derive(Clone, Copy, Debug)]
pub struct Gaussian {
    pub gamma: f64,
}

impl Gaussian {
    pub fn new(gamma: f64) -> Self {
        assert!(gamma >= 0.0 && gamma.is_finite(), "bad gamma {gamma}");
        Self { gamma }
    }

    /// Kernel value from a precomputed squared distance.
    #[inline]
    pub fn from_sq_dist(&self, d2: f64) -> f64 {
        (-self.gamma * d2).exp()
    }
}

impl Kernel for Gaussian {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        self.from_sq_dist(sq_dist(a, b))
    }

    #[inline]
    fn self_eval(&self, _a: &[f32]) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "gaussian"
    }
}

/// Linear kernel `k(x,x') = <x,x'>`.
#[derive(Clone, Copy, Debug, Default)]
pub struct Linear;

impl Kernel for Linear {
    #[inline]
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| (x as f64) * (y as f64)).sum()
    }

    fn name(&self) -> &'static str {
        "linear"
    }
}

/// Polynomial kernel `k(x,x') = (scale <x,x'> + offset)^degree`.
#[derive(Clone, Copy, Debug)]
pub struct Polynomial {
    pub degree: u32,
    pub scale: f64,
    pub offset: f64,
}

impl Kernel for Polynomial {
    fn eval(&self, a: &[f32], b: &[f32]) -> f64 {
        let dot: f64 = Linear.eval(a, b);
        (self.scale * dot + self.offset).powi(self.degree as i32)
    }

    fn name(&self) -> &'static str {
        "polynomial"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_dist_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32) * 0.1).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32) * -0.05 + 1.0).collect();
        let naive: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
            .sum();
        assert!((sq_dist(&a, &b) - naive).abs() < 1e-6 * naive.max(1.0));
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| (i as f32) * 0.1).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32) * -0.05 + 1.0).collect();
        let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-5 * naive.abs().max(1.0));
    }

    #[test]
    fn sq_dist_cached_matches_sq_dist() {
        for d in [1usize, 7, 8, 33, 128] {
            let a: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..d).map(|i| (i as f32 * 0.91).cos()).collect();
            let direct = sq_dist(&a, &b);
            let cached = sq_dist_cached(&a, sq_norm(&a), &b, sq_norm(&b));
            assert!(
                (direct - cached).abs() < 1e-4 * (1.0 + direct),
                "d={d}: {direct} vs {cached}"
            );
        }
        // coincident points: the fallback guarantees exact zero
        let x = [0.25f32, -3.5, 1.0];
        assert_eq!(sq_dist_cached(&x, sq_norm(&x), &x, sq_norm(&x)), 0.0);
    }

    #[test]
    fn sq_dist_cached_survives_cancellation() {
        // Near-duplicate points with huge norms (unscaled LIBSVM-style
        // features): the naive norm expansion cancels ~1e6-magnitude
        // f32-accumulated terms and returns noise; the guard must route
        // these through the exact difference form.
        let a: Vec<f32> = (0..128).map(|i| 200.0 + (i as f32 * 0.7).sin()).collect();
        let mut b = a.clone();
        for (i, v) in b.iter_mut().enumerate() {
            *v += 5e-3 * ((i as f32) * 1.3).cos();
        }
        let exact = sq_dist(&a, &b); // ~1e-3, no cancellation by construction
        let cached = sq_dist_cached(&a, sq_norm(&a), &b, sq_norm(&b));
        assert!(
            (cached - exact).abs() <= 1e-3 * exact,
            "cancellation not handled: cached {cached} vs exact {exact}"
        );
    }

    #[test]
    fn gaussian_basics() {
        let k = Gaussian::new(0.5);
        let a = [1.0f32, 2.0];
        let b = [2.0f32, 2.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-12);
        assert!((k.eval(&a, &b) - (-0.5f64).exp()).abs() < 1e-9);
        // symmetry
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
    }

    #[test]
    fn gaussian_decreases_with_distance() {
        let k = Gaussian::new(1.0);
        let a = [0.0f32];
        assert!(k.eval(&a, &[1.0]) > k.eval(&a, &[2.0]));
    }

    #[test]
    fn linear_and_poly() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        assert_eq!(Linear.eval(&a, &b), 11.0);
        let p = Polynomial { degree: 2, scale: 1.0, offset: 1.0 };
        assert_eq!(p.eval(&a, &b), 144.0);
    }

    #[test]
    #[should_panic]
    fn gaussian_rejects_nan_gamma() {
        Gaussian::new(f64::NAN);
    }
}

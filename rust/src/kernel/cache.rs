//! Kernel-row cache for the SMO reference solver.
//!
//! SMO repeatedly needs full kernel rows k(x_i, ·) for the pair of active
//! indices; recomputing them dominates runtime.  This is a fixed-capacity
//! LRU keyed by row index — the standard LIBSVM design, sized in rows
//! rather than bytes for simplicity.

use std::collections::HashMap;

pub struct RowCache {
    capacity: usize,
    rows: HashMap<usize, (u64, Vec<f64>)>, // index -> (last-use tick, row)
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { capacity, rows: HashMap::new(), tick: 0, hits: 0, misses: 0 }
    }

    /// Fetch row `i`, computing it with `make` on a miss.
    pub fn get(&mut self, i: usize, make: impl FnOnce() -> Vec<f64>) -> &[f64] {
        self.tick += 1;
        let tick = self.tick;
        if self.rows.contains_key(&i) {
            self.hits += 1;
            let e = self.rows.get_mut(&i).unwrap();
            e.0 = tick;
            return &e.1;
        }
        self.misses += 1;
        if self.rows.len() >= self.capacity {
            // Evict least-recently-used.
            let lru = *self
                .rows
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(k, _)| k)
                .unwrap();
            self.rows.remove(&lru);
        }
        self.rows.insert(i, (tick, make()));
        &self.rows[&i].1
    }

    /// Drop every cached row (used after shrinking / alpha resets).
    pub fn clear(&mut self) {
        self.rows.clear();
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_miss() {
        let mut c = RowCache::new(2);
        let r = c.get(0, || vec![1.0, 2.0]).to_vec();
        assert_eq!(r, vec![1.0, 2.0]);
        let _ = c.get(0, || panic!("must be cached"));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn evicts_lru() {
        let mut c = RowCache::new(2);
        c.get(0, || vec![0.0]);
        c.get(1, || vec![1.0]);
        c.get(0, || unreachable!()); // refresh 0
        c.get(2, || vec![2.0]); // evicts 1
        assert_eq!(c.len(), 2);
        let mut recomputed = false;
        c.get(1, || {
            recomputed = true;
            vec![1.0]
        });
        assert!(recomputed, "row 1 should have been evicted");
    }

    #[test]
    fn clear_resets() {
        let mut c = RowCache::new(4);
        c.get(7, || vec![7.0]);
        c.clear();
        assert!(c.is_empty());
    }
}

//! Explicit SIMD substrate for the kernel inner loops.
//!
//! PR 3's tile engine blocked the batch hot paths for cache locality
//! but left the innermost `dot` / `sq_dist` loops to LLVM
//! autovectorization — which, at the x86-64 *baseline* target every
//! release binary is compiled for, means 128-bit SSE2 even on machines
//! with 256-bit AVX2 units.  This module ends that roulette: the three
//! kernel primitives are implemented per ISA with `core::arch`
//! intrinsics and dispatched **at runtime**
//! (`is_x86_feature_detected!`), so one binary runs 8-wide on AVX2
//! hardware, 4-wide on bare SSE2/NEON, and scalar everywhere else.
//!
//! # The fixed-lane determinism contract
//!
//! Every path — scalar fallback included — computes the *identical*
//! arithmetic:
//!
//! * products accumulate into the same **fixed [`LANES`] = 8 f32
//!   accumulator lanes**, lane `l` owning elements `i ≡ l (mod 8)`;
//! * each lane update is a separately rounded IEEE-754 multiply then
//!   add.  The AVX2 path deliberately uses `mul_ps` + `add_ps`, **not**
//!   `fmadd_ps`: FMA skips the intermediate rounding and would produce
//!   different bits than the scalar lanes (the FMA capability is still
//!   part of the [`Isa::Avx2Fma`] dispatch gate — it identifies the
//!   µarch generation — it is just not allowed to change the math);
//! * the horizontal reduction sums the 8 lanes **sequentially in lane
//!   order** through one shared `finish_dot`/`finish_sq` helper, then
//!   folds the `len % 8` remainder in f64, exactly like the pre-SIMD
//!   scalar code.
//!
//! IEEE-754 single ops are exactly specified, so lane-parallel
//! `mul`/`add`/`sub` produce the same bits as their scalar
//! counterparts — results are **bit-identical across every dispatch
//! target** (`rust/tests/simd_parity.rs` pins it, and CI re-runs the
//! tile-engine suite under `MMBSGD_FORCE_SCALAR=1`).  That is what
//! keeps the repo's pinned invariants (tile-engine parity, checkpoint
//! resume, serve batched-vs-`decision1`) true on heterogeneous fleets:
//! the ISA, like the thread count, is a pure wall-clock knob.
//!
//! # Escape hatch
//!
//! Two ways to force the scalar reference path, both safe to flip at
//! any time *because* of the parity contract:
//!
//! * `MMBSGD_FORCE_SCALAR=1` in the environment (read once, wins over
//!   everything — the CI dispatch-matrix smoke uses it);
//! * [`set_mode`]`(SimdMode::Scalar)` — the `TrainConfig::simd_mode` /
//!   `--simd-mode` plumbing.
//!
//! # The vectorized exponent substrate
//!
//! After the tile engine stripped the surviving-exponent pass into one
//! contiguous loop, libm `exp` calls were the last scalar serial tail
//! under every hot path.  [`exp_neg_block`] replaces them with a
//! fixed-degree polynomial `e^{-x} = 2^{-k} · p(r)` (range reduction
//! `t = x·log₂e`, `k = round(t)`, `r = t - k ∈ [-½, ½]`, degree-6
//! near-minimax `p(r) ≈ 2^{-r}`), implemented per ISA over f64 lanes
//! with the same no-FMA mul+add discipline as the dot kernels.  Unlike
//! the dot substrate it is **not** bit-identical to the libm path it
//! replaces — libm's `exp` is a different (platform-varying!)
//! approximation — so it sits behind its own opt-in knob:
//!
//! * every dispatch target (scalar [`exp_neg_poly`] included) runs the
//!   identical IEEE-754 f64 op sequence, so vector-mode results are
//!   **bit-identical across ISAs and thread counts** — a vector-mode
//!   run reproduces exactly on a heterogeneous fleet;
//! * accuracy vs libm is *bounded*, not bitwise: max relative error
//!   ≈ 6.2·10⁻⁹ over the whole live range `[0, EXP_NEG_CUTOFF)`
//!   (budget 10⁻⁶, pinned in `rust/tests/simd_parity.rs`);
//! * [`set_exp_mode`] / [`exp_mode`] select `libm` (default — preserves
//!   every libm-pinned bit-exact invariant) or `vector`;
//!   `MMBSGD_FORCE_LIBM=1` is the outermost escape hatch, and like
//!   `threads`/`simd_mode` the knob is never checkpointed.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Accumulator lanes of every kernel primitive (see module docs).
pub const LANES: usize = 8;

/// SV rows per block-micro-kernel step: the query chunk is loaded once
/// and reused across this many rows (4 accumulator vectors + the query
/// and one row register stay comfortably within every ISA's register
/// file).
pub const BLOCK: usize = 4;

/// Requested dispatch policy (`TrainConfig::simd_mode`, TOML
/// `simd_mode`, `--simd-mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Runtime-detect the best ISA (the default).
    Auto,
    /// Force the scalar reference path (results are bit-identical
    /// either way; this is a debugging / attribution knob).
    Scalar,
}

impl SimdMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(Self::Auto),
            "scalar" => Some(Self::Scalar),
            _ => None,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Scalar => "scalar",
        }
    }
}

/// Exponent-path policy (`TrainConfig::exp_mode` / `ServeConfig::
/// exp_mode`, TOML `exp_mode`, `--exp-mode`).  Selects how the hot
/// paths evaluate `e^{-γd²}`; see the module docs for why `vector` is
/// accuracy-bounded rather than bit-identical to `libm`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExpMode {
    /// Per-element libm `exp` (the default): keeps results bit-identical
    /// to every pre-existing pinned invariant (tile parity vs the
    /// scalar margin loop, checkpoint resume `cmp`, serve parity).
    #[default]
    Libm,
    /// The polynomial substrate ([`exp_neg_block`]): faster, ISA- and
    /// thread-invariant bits, rel err ≤ 1e-6 vs libm on the live range.
    Vector,
}

impl ExpMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "libm" => Some(Self::Libm),
            "vector" => Some(Self::Vector),
            _ => None,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            Self::Libm => "libm",
            Self::Vector => "vector",
        }
    }
}

/// The instruction set actually executing the kernel primitives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar reference (also the forced-scalar escape hatch).
    Scalar,
    /// x86-64 baseline: two 128-bit vectors per 8-lane chunk.
    Sse2,
    /// 256-bit AVX2 with the FMA generation gate (one 8-lane vector per
    /// chunk; FMA itself is unused — see the module docs).
    Avx2Fma,
    /// aarch64 NEON: two 128-bit vectors per 8-lane chunk.
    Neon,
}

impl Isa {
    pub fn describe(&self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Sse2 => "sse2",
            Self::Avx2Fma => "avx2+fma",
            Self::Neon => "neon",
        }
    }
}

/// Process-wide forced-scalar flag ([`set_mode`]).  Relaxed ordering is
/// enough: the flag only selects between bit-identical implementations,
/// so a racing reader picking the stale path is still correct.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Process-wide exponent-path flag ([`set_exp_mode`]).  `true` selects
/// the polynomial substrate.  Relaxed ordering: the flag is a startup
/// knob and both paths are valid; a racing reader picking the stale
/// path still returns a correct (mode-consistent) exponent.
static VECTOR_EXP: AtomicBool = AtomicBool::new(false);

/// `MMBSGD_FORCE_LIBM` result, read once (same "env wins, sampled at
/// first use" semantics as `MMBSGD_FORCE_SCALAR`).
static FORCED_LIBM: OnceLock<bool> = OnceLock::new();

/// Hardware detection result, cached after the first query (feature
/// detection is a CPUID dance; the hot loops must not repeat it).
static DETECTED: OnceLock<Isa> = OnceLock::new();

fn env_forced_scalar() -> bool {
    match std::env::var("MMBSGD_FORCE_SCALAR") {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    }
}

fn env_forced_libm() -> bool {
    *FORCED_LIBM.get_or_init(|| match std::env::var("MMBSGD_FORCE_LIBM") {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    })
}

#[cfg(target_arch = "x86_64")]
fn native_isa() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Isa::Avx2Fma
    } else {
        // SSE2 is part of the x86-64 baseline: always present.
        Isa::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn native_isa() -> Isa {
    // NEON is mandatory on aarch64.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn native_isa() -> Isa {
    Isa::Scalar
}

fn detected() -> Isa {
    *DETECTED.get_or_init(|| {
        if env_forced_scalar() {
            Isa::Scalar
        } else {
            native_isa()
        }
    })
}

/// Apply a requested [`SimdMode`].  `MMBSGD_FORCE_SCALAR` wins over
/// `Auto` (the env var is the outermost escape hatch).  Safe to call at
/// any point: every path is bit-identical, so in-flight computations
/// cannot change value.
pub fn set_mode(mode: SimdMode) {
    FORCE_SCALAR.store(mode == SimdMode::Scalar, Ordering::Relaxed);
}

/// The mode currently requested through [`set_mode`].
pub fn mode() -> SimdMode {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        SimdMode::Scalar
    } else {
        SimdMode::Auto
    }
}

/// The ISA the kernel primitives dispatch to right now (mode and env
/// overrides applied) — the value `mmbsgd train/evaluate/serve` print
/// next to the effective-threads line.
pub fn active_isa() -> Isa {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        Isa::Scalar
    } else {
        detected()
    }
}

/// Apply a requested [`ExpMode`].  `MMBSGD_FORCE_LIBM` wins over
/// `Vector` (the env var is the outermost escape hatch, mirroring
/// `MMBSGD_FORCE_SCALAR`).  A startup knob like `set_mode`: flipping it
/// mid-run changes which approximation later exponents use, so the CLI
/// applies it once, before any training or serving work.
pub fn set_exp_mode(mode: ExpMode) {
    VECTOR_EXP.store(mode == ExpMode::Vector && !env_forced_libm(), Ordering::Relaxed);
}

/// The exponent path currently selected through [`set_exp_mode`] (env
/// override applied) — printed in the `[perf ]` attribution line.
pub fn exp_mode() -> ExpMode {
    if VECTOR_EXP.load(Ordering::Relaxed) {
        ExpMode::Vector
    } else {
        ExpMode::Libm
    }
}

// ------------------------------------------------------------------
// shared reduction tails (one implementation => provably same bits)
// ------------------------------------------------------------------

/// Sequential lane-order reduction + f64 remainder fold for a dot
/// product.  Every ISA path funnels through this, so the reduction
/// order is fixed by construction.
#[inline]
fn finish_dot(acc: [f32; LANES], ra: &[f32], rb: &[f32]) -> f64 {
    let mut s = 0.0f32;
    for v in acc {
        s += v;
    }
    let mut s = s as f64;
    for (x, y) in ra.iter().zip(rb) {
        s += (*x as f64) * (*y as f64);
    }
    s
}

/// [`finish_dot`]'s squared-distance twin (f64 difference form on the
/// remainder, as the pre-SIMD scalar loop did).
#[inline]
fn finish_sq(acc: [f32; LANES], ra: &[f32], rb: &[f32]) -> f64 {
    let mut s = 0.0f32;
    for v in acc {
        s += v;
    }
    let mut s = s as f64;
    for (x, y) in ra.iter().zip(rb) {
        let d = (x - y) as f64;
        s += d * d;
    }
    s
}

// ------------------------------------------------------------------
// scalar reference path
// ------------------------------------------------------------------

/// Scalar reference dot product — the 8-lane loop every vector path
/// must match bit-for-bit.  Public for the parity suite and the
/// `speedup/dot_simd_vs_scalar` bench; production code calls the
/// dispatched [`dot`].
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for (l, v) in acc.iter_mut().enumerate() {
            // plain mul + add: each op separately rounded — the
            // contract every ISA path reproduces
            *v += xa[l] * xb[l];
        }
    }
    finish_dot(acc, ra, rb)
}

/// Scalar reference squared distance (same lane layout as
/// [`dot_scalar`]).
pub fn sq_dist_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for (l, v) in acc.iter_mut().enumerate() {
            let d = xa[l] - xb[l];
            *v += d * d;
        }
    }
    finish_sq(acc, ra, rb)
}

/// Scalar reference multi-row kernel: `out[r] = dot(q, rows[r])`.
/// Definitionally row-wise, so vector block kernels that interleave
/// rows must still equal it per row (they do: lanes are independent).
pub fn dot_block_scalar(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
    debug_assert_eq!(rows.len(), out.len() * dim);
    for (k, o) in out.iter_mut().enumerate() {
        *o = dot_scalar(q, &rows[k * dim..(k + 1) * dim]);
    }
}

// ------------------------------------------------------------------
// dispatched entry points
// ------------------------------------------------------------------

#[inline]
fn dot_isa(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    match isa {
        // SAFETY: `Isa::Avx2Fma` is only ever produced by `native_isa`
        // after a positive runtime `is_x86_feature_detected!("avx2")`.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { x86::dot_avx2(a, b) },
        // SAFETY: SSE2 is unconditionally part of the x86-64 baseline.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::dot_sse2(a, b) },
        // SAFETY: NEON is unconditionally available on aarch64.
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::dot_neon(a, b) },
        _ => dot_scalar(a, b),
    }
}

#[inline]
fn sq_dist_isa(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    match isa {
        // SAFETY: see `dot_isa` — same detection guarantees.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { x86::sq_dist_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::sq_dist_sse2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::sq_dist_neon(a, b) },
        _ => sq_dist_scalar(a, b),
    }
}

/// Runtime-dispatched dot product ⟨a,b⟩ — bit-identical to
/// [`dot_scalar`] on every ISA.  Mismatched lengths are a caller bug
/// (debug-asserted); release builds truncate to the shorter slice on
/// every path — the scalar `chunks_exact` + `zip` semantics — and
/// never read out of bounds.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    dot_isa(active_isa(), a, b)
}

/// Runtime-dispatched squared distance ‖a−b‖² — bit-identical to
/// [`sq_dist_scalar`] on every ISA.  Same length contract as [`dot`]:
/// mismatches truncate, never read out of bounds.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    sq_dist_isa(active_isa(), a, b)
}

/// Multi-row micro-kernel: `out[r] = ⟨q, rows[r·dim .. (r+1)·dim]⟩` for
/// every row of a contiguous row-major block (the flat `SvStore`
/// layout).  Rows are processed [`BLOCK`] at a time with the query
/// chunk loaded **once** per step and reused across the block — the
/// query stops round-tripping through the load units once per row,
/// which is where a queries×SVs kernel block spends most of its
/// bandwidth.  Per row the result is bit-identical to [`dot`] (lane
/// accumulators are per-row; interleaving changes nothing).
pub fn dot_block(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
    // Real asserts, not debug: the block micro-kernels do raw loads
    // sized by these shapes, so a caller bug must fail loudly here
    // rather than read out of bounds in release (one branch per
    // dot_block call — amortized over up to `out.len() · dim` lanes).
    assert_eq!(q.len(), dim, "dot_block: query/dim mismatch");
    assert_eq!(rows.len(), out.len() * dim, "dot_block: rows/out shape mismatch");
    let isa = active_isa();
    let mut r = 0;
    while r + BLOCK <= out.len() {
        let rs = &rows[r * dim..(r + BLOCK) * dim];
        let os = &mut out[r..r + BLOCK];
        match isa {
            // SAFETY: see `dot_isa` — same detection guarantees.
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2Fma => unsafe { x86::dot_block4_avx2(q, rs, dim, os) },
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => unsafe { x86::dot_block4_sse2(q, rs, dim, os) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { arm::dot_block4_neon(q, rs, dim, os) },
            _ => dot_block_scalar(q, rs, dim, os),
        }
        r += BLOCK;
    }
    // tail rows (< BLOCK): plain per-row dots on the same ISA
    for (k, o) in out.iter_mut().enumerate().skip(r) {
        *o = dot_isa(isa, q, &rows[k * dim..(k + 1) * dim]);
    }
}

// ------------------------------------------------------------------
// the vectorized exponent substrate
// ------------------------------------------------------------------

/// Inputs are clamped to `[0, EXP_ARG_MAX]` before range reduction.
/// The upper clamp keeps the 2^{-k} exponent bit-trick inside normal
/// f64 range (k ≤ 1022); every real caller passes `γd² <
/// EXP_NEG_CUTOFF = 40` (plus golden-section probes up to `4c`), so
/// the clamp never fires on live arguments.  Callers must not pass
/// NaN (the per-ISA min/max NaN conventions differ); no caller can —
/// arguments are products of finite norms, and the LUT scorer filters
/// non-finite `c` before any exponent.
const EXP_ARG_MAX: f64 = 708.0;

/// 1.5·2⁵², the round-to-nearest-integer magic constant: for
/// `t ∈ [0, 1022]`, `(t + EXP_MAGIC) - EXP_MAGIC` is `round(t)` and
/// the low mantissa bits of `t + EXP_MAGIC` hold `round(t)` verbatim.
const EXP_MAGIC: f64 = 6755399441055744.0;

/// Degree-6 near-minimax polynomial for `2^{-r}` on `r ∈ [-½, ½]`
/// (ascending powers; Chebyshev fit frozen to f64).  Max relative
/// error of the full pipeline vs libm: ≈ 6.2·10⁻⁹ over `[0, 160]` —
/// two orders under the 10⁻⁶ acceptance budget (EXPERIMENTS.md §Perf).
const EXP_POLY: [f64; 7] = [
    0.9999999999718422,
    -0.6931472000626832,
    0.2402265110131333,
    -0.055503406807421427,
    0.00961803994575737,
    -0.001339527980070497,
    0.00015465312332545763,
];

/// Scalar reference for the polynomial `e^{-x}` — the exact IEEE-754
/// f64 op sequence every vector lane reproduces, so
/// [`exp_neg_block`] is bit-identical to this on every ISA.  Public
/// for the parity suite and benches; production code calls the
/// mode-aware [`exp_neg`] / [`exp_neg_block`].
#[inline]
pub fn exp_neg_poly(x: f64) -> f64 {
    let x = x.clamp(0.0, EXP_ARG_MAX);
    let t = x * std::f64::consts::LOG2_E;
    let m = t + EXP_MAGIC; // round-to-nearest(t), in the mantissa
    let k = m.to_bits().wrapping_sub(EXP_MAGIC.to_bits()); // k ∈ [0, 1022]
    let kf = m - EXP_MAGIC; // k as f64 (exact)
    let r = t - kf; // r ∈ [-½, ½] (exact subtraction of nearby values)
    // Horner with separately rounded mul + add — no FMA, same
    // determinism contract as the dot kernels
    let mut p = EXP_POLY[6];
    for j in (0..6).rev() {
        p = p * r + EXP_POLY[j];
    }
    // 2^{-k} assembled directly in the exponent field
    let scale = f64::from_bits(1023u64.wrapping_sub(k) << 52);
    p * scale
}

/// Mode-aware scalar `e^{-x}`: libm in the default mode, the
/// polynomial under `exp_mode = vector`.  The one-shot twin of
/// [`exp_neg_block`] for callers outside the tile engine (golden
/// section, LUT nodes).
#[inline]
pub fn exp_neg(x: f64) -> f64 {
    if VECTOR_EXP.load(Ordering::Relaxed) {
        exp_neg_poly(x)
    } else {
        (-x).exp()
    }
}

/// Vectorized `out[i] = e^{-args[i]}` over a contiguous block — the
/// staged survivor pass of the tile engine.  Always evaluates the
/// polynomial (callers branch on [`exp_mode`]); dispatched per ISA
/// (AVX2: 4 f64 lanes, SSE2/NEON: 2) with the remainder on
/// [`exp_neg_poly`].  Element-wise, no reduction — which is why,
/// unlike the dot kernels, lane width cannot reorder anything and
/// every ISA is bit-identical by construction.
pub fn exp_neg_block(args: &[f64], out: &mut [f64]) {
    assert_eq!(args.len(), out.len(), "exp_neg_block: args/out shape mismatch");
    match active_isa() {
        // SAFETY: see `dot_isa` — same detection guarantees.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { x86::exp_neg_block_avx2(args, out) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::exp_neg_block_sse2(args, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::exp_neg_block_neon(args, out) },
        _ => {
            for (o, &a) in out.iter_mut().zip(args) {
                *o = exp_neg_poly(a);
            }
        }
    }
}

// ------------------------------------------------------------------
// x86-64 paths
// ------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{
        exp_neg_poly, finish_dot, finish_sq, BLOCK, EXP_ARG_MAX, EXP_MAGIC, EXP_POLY, LANES,
    };
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.  (Bounds:
    /// the trip count is derived from the *shorter* slice — mismatched
    /// lengths truncate like the scalar `chunks_exact` + `zip` loop,
    /// never read past either allocation.)
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            // mul + add, NOT fmadd: see the module determinism contract
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        finish_dot(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.  Bounds: see
    /// [`dot_avx2`] — min-length trip count, no out-of-bounds reads.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sq_dist_avx2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        finish_sq(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime; `out.len()`
    /// must be [`BLOCK`] and `rows.len()` must be `BLOCK * dim`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_block4_avx2(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), BLOCK);
        debug_assert_eq!(rows.len(), BLOCK * dim);
        let n = dim - dim % LANES;
        let (qp, rp) = (q.as_ptr(), rows.as_ptr());
        let mut acc = [_mm256_setzero_ps(); BLOCK];
        let mut i = 0;
        while i < n {
            let vq = _mm256_loadu_ps(qp.add(i));
            for (r, a) in acc.iter_mut().enumerate() {
                let vr = _mm256_loadu_ps(rp.add(r * dim + i));
                *a = _mm256_add_ps(*a, _mm256_mul_ps(vq, vr));
            }
            i += LANES;
        }
        for (r, (o, a)) in out.iter_mut().zip(acc).enumerate() {
            let mut lanes = [0.0f32; LANES];
            _mm256_storeu_ps(lanes.as_mut_ptr(), a);
            *o = finish_dot(lanes, &q[n..], &rows[r * dim + n..(r + 1) * dim]);
        }
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline; always safe there.
    /// Bounds: see [`dot_avx2`] — min-length trip count.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = _mm_setzero_ps();
        let mut hi = _mm_setzero_ps();
        let mut i = 0;
        while i < n {
            lo = _mm_add_ps(lo, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
            hi = _mm_add_ps(
                hi,
                _mm_mul_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4))),
            );
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        finish_dot(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline; always safe there.
    /// Bounds: see [`dot_avx2`] — min-length trip count.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn sq_dist_sse2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = _mm_setzero_ps();
        let mut hi = _mm_setzero_ps();
        let mut i = 0;
        while i < n {
            let dl = _mm_sub_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i)));
            let dh = _mm_sub_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4)));
            lo = _mm_add_ps(lo, _mm_mul_ps(dl, dl));
            hi = _mm_add_ps(hi, _mm_mul_ps(dh, dh));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        finish_sq(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// SSE2 baseline; `out.len() == BLOCK`, `rows.len() == BLOCK * dim`.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_block4_sse2(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), BLOCK);
        debug_assert_eq!(rows.len(), BLOCK * dim);
        let n = dim - dim % LANES;
        let (qp, rp) = (q.as_ptr(), rows.as_ptr());
        let mut lo = [_mm_setzero_ps(); BLOCK];
        let mut hi = [_mm_setzero_ps(); BLOCK];
        let mut i = 0;
        while i < n {
            let ql = _mm_loadu_ps(qp.add(i));
            let qh = _mm_loadu_ps(qp.add(i + 4));
            for (r, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let base = r * dim + i;
                *l = _mm_add_ps(*l, _mm_mul_ps(ql, _mm_loadu_ps(rp.add(base))));
                *h = _mm_add_ps(*h, _mm_mul_ps(qh, _mm_loadu_ps(rp.add(base + 4))));
            }
            i += LANES;
        }
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; LANES];
            _mm_storeu_ps(lanes.as_mut_ptr(), lo[r]);
            _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi[r]);
            *o = finish_dot(lanes, &q[n..], &rows[r * dim + n..(r + 1) * dim]);
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.  Bounds: trip
    /// count from the shorter slice, remainder handled in scalar.
    ///
    /// Every lane runs the op sequence of [`super::exp_neg_poly`]
    /// verbatim (min/max clamp, mul, add, sub, integer sub/shift — all
    /// exactly specified per lane, no FMA), so the results are
    /// bit-identical to the scalar reference.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn exp_neg_block_avx2(args: &[f64], out: &mut [f64]) {
        const W: usize = 4;
        let len = args.len().min(out.len());
        let n = len - len % W;
        let (pa, po) = (args.as_ptr(), out.as_mut_ptr());
        let zero = _mm256_setzero_pd();
        let arg_max = _mm256_set1_pd(EXP_ARG_MAX);
        let log2e = _mm256_set1_pd(std::f64::consts::LOG2_E);
        let magic = _mm256_set1_pd(EXP_MAGIC);
        let magic_bits = _mm256_set1_epi64x(EXP_MAGIC.to_bits() as i64);
        let bias = _mm256_set1_epi64x(1023);
        let mut i = 0;
        while i < n {
            // clamp: max(x, 0) then min(·, ARG_MAX) — NaN-free domain
            let x = _mm256_min_pd(_mm256_max_pd(_mm256_loadu_pd(pa.add(i)), zero), arg_max);
            let t = _mm256_mul_pd(x, log2e);
            let m = _mm256_add_pd(t, magic);
            let k = _mm256_sub_epi64(_mm256_castpd_si256(m), magic_bits);
            let kf = _mm256_sub_pd(m, magic);
            let r = _mm256_sub_pd(t, kf);
            // Horner, mul + add, NOT fmadd: the determinism contract
            let mut p = _mm256_set1_pd(EXP_POLY[6]);
            for j in (0..6).rev() {
                p = _mm256_add_pd(_mm256_mul_pd(p, r), _mm256_set1_pd(EXP_POLY[j]));
            }
            let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_sub_epi64(bias, k)));
            _mm256_storeu_pd(po.add(i), _mm256_mul_pd(p, scale));
            i += W;
        }
        for j in n..len {
            out[j] = exp_neg_poly(args[j]);
        }
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline; always safe there.  Bounds
    /// and bit-identity: see [`exp_neg_block_avx2`] (2 f64 lanes here).
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn exp_neg_block_sse2(args: &[f64], out: &mut [f64]) {
        const W: usize = 2;
        let len = args.len().min(out.len());
        let n = len - len % W;
        let (pa, po) = (args.as_ptr(), out.as_mut_ptr());
        let zero = _mm_setzero_pd();
        let arg_max = _mm_set1_pd(EXP_ARG_MAX);
        let log2e = _mm_set1_pd(std::f64::consts::LOG2_E);
        let magic = _mm_set1_pd(EXP_MAGIC);
        let magic_bits = _mm_set1_epi64x(EXP_MAGIC.to_bits() as i64);
        let bias = _mm_set1_epi64x(1023);
        let mut i = 0;
        while i < n {
            let x = _mm_min_pd(_mm_max_pd(_mm_loadu_pd(pa.add(i)), zero), arg_max);
            let t = _mm_mul_pd(x, log2e);
            let m = _mm_add_pd(t, magic);
            let k = _mm_sub_epi64(_mm_castpd_si128(m), magic_bits);
            let kf = _mm_sub_pd(m, magic);
            let r = _mm_sub_pd(t, kf);
            let mut p = _mm_set1_pd(EXP_POLY[6]);
            for j in (0..6).rev() {
                p = _mm_add_pd(_mm_mul_pd(p, r), _mm_set1_pd(EXP_POLY[j]));
            }
            let scale = _mm_castsi128_pd(_mm_slli_epi64::<52>(_mm_sub_epi64(bias, k)));
            _mm_storeu_pd(po.add(i), _mm_mul_pd(p, scale));
            i += W;
        }
        for j in n..len {
            out[j] = exp_neg_poly(args[j]);
        }
    }
}

// ------------------------------------------------------------------
// aarch64 NEON paths
// ------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{
        exp_neg_poly, finish_dot, finish_sq, BLOCK, EXP_ARG_MAX, EXP_MAGIC, EXP_POLY, LANES,
    };
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is mandatory on aarch64; always safe there.  Bounds: trip
    /// count from the shorter slice — no out-of-bounds reads.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n {
            // vmul + vadd, not vfma: the determinism contract again
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4))));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        finish_dot(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// NEON is mandatory on aarch64; always safe there.  Bounds: trip
    /// count from the shorter slice — no out-of-bounds reads.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn sq_dist_neon(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n {
            let dl = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            let dh = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            lo = vaddq_f32(lo, vmulq_f32(dl, dl));
            hi = vaddq_f32(hi, vmulq_f32(dh, dh));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        finish_sq(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// NEON mandatory; `out.len() == BLOCK`, `rows.len() == BLOCK * dim`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_block4_neon(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), BLOCK);
        debug_assert_eq!(rows.len(), BLOCK * dim);
        let n = dim - dim % LANES;
        let (qp, rp) = (q.as_ptr(), rows.as_ptr());
        let mut lo = [vdupq_n_f32(0.0); BLOCK];
        let mut hi = [vdupq_n_f32(0.0); BLOCK];
        let mut i = 0;
        while i < n {
            let ql = vld1q_f32(qp.add(i));
            let qh = vld1q_f32(qp.add(i + 4));
            for (r, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let base = r * dim + i;
                *l = vaddq_f32(*l, vmulq_f32(ql, vld1q_f32(rp.add(base))));
                *h = vaddq_f32(*h, vmulq_f32(qh, vld1q_f32(rp.add(base + 4))));
            }
            i += LANES;
        }
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; LANES];
            vst1q_f32(lanes.as_mut_ptr(), lo[r]);
            vst1q_f32(lanes.as_mut_ptr().add(4), hi[r]);
            *o = finish_dot(lanes, &q[n..], &rows[r * dim + n..(r + 1) * dim]);
        }
    }

    /// # Safety
    /// NEON is mandatory on aarch64; always safe there.  Bounds: trip
    /// count from the shorter slice, remainder in scalar.  Each of the
    /// 2 f64 lanes runs [`super::exp_neg_poly`]'s op sequence verbatim
    /// (no FMA), so results are bit-identical to the scalar reference.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn exp_neg_block_neon(args: &[f64], out: &mut [f64]) {
        const W: usize = 2;
        let len = args.len().min(out.len());
        let n = len - len % W;
        let (pa, po) = (args.as_ptr(), out.as_mut_ptr());
        let zero = vdupq_n_f64(0.0);
        let arg_max = vdupq_n_f64(EXP_ARG_MAX);
        let log2e = vdupq_n_f64(std::f64::consts::LOG2_E);
        let magic = vdupq_n_f64(EXP_MAGIC);
        let magic_bits = vdupq_n_s64(EXP_MAGIC.to_bits() as i64);
        let bias = vdupq_n_s64(1023);
        let mut i = 0;
        while i < n {
            let x = vminq_f64(vmaxq_f64(vld1q_f64(pa.add(i)), zero), arg_max);
            let t = vmulq_f64(x, log2e);
            let m = vaddq_f64(t, magic);
            let k = vsubq_s64(vreinterpretq_s64_f64(m), magic_bits);
            let kf = vsubq_f64(m, magic);
            let r = vsubq_f64(t, kf);
            // vmul + vadd, not vfma: the determinism contract again
            let mut p = vdupq_n_f64(EXP_POLY[6]);
            for j in (0..6).rev() {
                p = vaddq_f64(vmulq_f64(p, r), vdupq_n_f64(EXP_POLY[j]));
            }
            let scale = vreinterpretq_f64_s64(vshlq_n_s64::<52>(vsubq_s64(bias, k)));
            vst1q_f64(po.add(i), vmulq_f64(p, scale));
            i += W;
        }
        for j in n..len {
            out[j] = exp_neg_poly(args[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    // Dispatch parity over ragged dims / row counts lives in
    // `rust/tests/simd_parity.rs` (one home for the contract; CI runs
    // that suite under both dispatch modes).  The unit tests here
    // cover only what the integration suite does not: bitwise
    // commutativity and the mode/ISA plumbing.
    use super::*;

    fn vecs(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::rng::Xoshiro256::new(seed);
        let a: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 1.7).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.6 - 0.3).collect();
        (a, b)
    }

    #[test]
    fn dot_is_bitwise_commutative() {
        // The tile engine relies on dot(q, x) == dot(x, q) bitwise (it
        // feeds dot_block values into expansions written either way).
        for d in [1usize, 7, 8, 33, 300] {
            let (a, b) = vecs(d, d as u64 + 7);
            assert_eq!(dot(&a, &b).to_bits(), dot(&b, &a).to_bits(), "d={d}");
            assert_eq!(sq_dist(&a, &b).to_bits(), sq_dist(&b, &a).to_bits(), "d={d}");
        }
    }

    #[test]
    fn mode_round_trip_and_parse() {
        assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("avx2"), None);
        for m in [SimdMode::Auto, SimdMode::Scalar] {
            assert_eq!(SimdMode::parse(m.describe()), Some(m));
        }
        // Isa labels are stable (they land in perf reports)
        assert_eq!(Isa::Avx2Fma.describe(), "avx2+fma");
    }

    #[test]
    fn exp_mode_round_trip_and_default() {
        assert_eq!(ExpMode::parse("libm"), Some(ExpMode::Libm));
        assert_eq!(ExpMode::parse("vector"), Some(ExpMode::Vector));
        assert_eq!(ExpMode::parse("poly"), None);
        for m in [ExpMode::Libm, ExpMode::Vector] {
            assert_eq!(ExpMode::parse(m.describe()), Some(m));
        }
        // libm is the default: it preserves every libm-pinned invariant
        assert_eq!(ExpMode::default(), ExpMode::Libm);
    }

    #[test]
    fn exp_block_bit_matches_scalar_poly_on_active_isa() {
        // Ragged lengths exercise every vector width + remainder path.
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 128] {
            let mut rng = crate::rng::Xoshiro256::new(len as u64 + 3);
            let args: Vec<f64> = (0..len).map(|_| rng.next_f64() * 40.0).collect();
            let mut out = vec![0.0f64; len];
            exp_neg_block(&args, &mut out);
            for (j, (&a, &o)) in args.iter().zip(&out).enumerate() {
                assert_eq!(
                    o.to_bits(),
                    exp_neg_poly(a).to_bits(),
                    "len={len} j={j} isa={:?}",
                    active_isa()
                );
            }
        }
    }

    #[test]
    fn exp_poly_tracks_libm_at_spot_values() {
        // The full-range sweep lives in rust/tests/simd_parity.rs; spot
        // values here keep the kernel honest under plain `cargo test`.
        for x in [0.0f64, 1e-9, 0.25, 0.5, 1.0, 5.0, 17.3, 39.999_999_9] {
            let got = exp_neg_poly(x);
            let want = (-x).exp();
            assert!(
                (got - want).abs() <= 1e-6 * want,
                "x={x}: poly {got:e} vs libm {want:e}"
            );
        }
        // clamp semantics past the live range: monotone-ish, never inf/NaN
        assert!(exp_neg_poly(1000.0) > 0.0 && exp_neg_poly(1000.0) < 1e-300);
        assert_eq!(exp_neg_poly(-3.0).to_bits(), exp_neg_poly(0.0).to_bits());
    }
}

//! Explicit SIMD substrate for the kernel inner loops.
//!
//! PR 3's tile engine blocked the batch hot paths for cache locality
//! but left the innermost `dot` / `sq_dist` loops to LLVM
//! autovectorization — which, at the x86-64 *baseline* target every
//! release binary is compiled for, means 128-bit SSE2 even on machines
//! with 256-bit AVX2 units.  This module ends that roulette: the three
//! kernel primitives are implemented per ISA with `core::arch`
//! intrinsics and dispatched **at runtime**
//! (`is_x86_feature_detected!`), so one binary runs 8-wide on AVX2
//! hardware, 4-wide on bare SSE2/NEON, and scalar everywhere else.
//!
//! # The fixed-lane determinism contract
//!
//! Every path — scalar fallback included — computes the *identical*
//! arithmetic:
//!
//! * products accumulate into the same **fixed [`LANES`] = 8 f32
//!   accumulator lanes**, lane `l` owning elements `i ≡ l (mod 8)`;
//! * each lane update is a separately rounded IEEE-754 multiply then
//!   add.  The AVX2 path deliberately uses `mul_ps` + `add_ps`, **not**
//!   `fmadd_ps`: FMA skips the intermediate rounding and would produce
//!   different bits than the scalar lanes (the FMA capability is still
//!   part of the [`Isa::Avx2Fma`] dispatch gate — it identifies the
//!   µarch generation — it is just not allowed to change the math);
//! * the horizontal reduction sums the 8 lanes **sequentially in lane
//!   order** through one shared `finish_dot`/`finish_sq` helper, then
//!   folds the `len % 8` remainder in f64, exactly like the pre-SIMD
//!   scalar code.
//!
//! IEEE-754 single ops are exactly specified, so lane-parallel
//! `mul`/`add`/`sub` produce the same bits as their scalar
//! counterparts — results are **bit-identical across every dispatch
//! target** (`rust/tests/simd_parity.rs` pins it, and CI re-runs the
//! tile-engine suite under `MMBSGD_FORCE_SCALAR=1`).  That is what
//! keeps the repo's pinned invariants (tile-engine parity, checkpoint
//! resume, serve batched-vs-`decision1`) true on heterogeneous fleets:
//! the ISA, like the thread count, is a pure wall-clock knob.
//!
//! # Escape hatch
//!
//! Two ways to force the scalar reference path, both safe to flip at
//! any time *because* of the parity contract:
//!
//! * `MMBSGD_FORCE_SCALAR=1` in the environment (read once, wins over
//!   everything — the CI dispatch-matrix smoke uses it);
//! * [`set_mode`]`(SimdMode::Scalar)` — the `TrainConfig::simd_mode` /
//!   `--simd-mode` plumbing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Accumulator lanes of every kernel primitive (see module docs).
pub const LANES: usize = 8;

/// SV rows per block-micro-kernel step: the query chunk is loaded once
/// and reused across this many rows (4 accumulator vectors + the query
/// and one row register stay comfortably within every ISA's register
/// file).
pub const BLOCK: usize = 4;

/// Requested dispatch policy (`TrainConfig::simd_mode`, TOML
/// `simd_mode`, `--simd-mode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Runtime-detect the best ISA (the default).
    Auto,
    /// Force the scalar reference path (results are bit-identical
    /// either way; this is a debugging / attribution knob).
    Scalar,
}

impl SimdMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(Self::Auto),
            "scalar" => Some(Self::Scalar),
            _ => None,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Scalar => "scalar",
        }
    }
}

/// The instruction set actually executing the kernel primitives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar reference (also the forced-scalar escape hatch).
    Scalar,
    /// x86-64 baseline: two 128-bit vectors per 8-lane chunk.
    Sse2,
    /// 256-bit AVX2 with the FMA generation gate (one 8-lane vector per
    /// chunk; FMA itself is unused — see the module docs).
    Avx2Fma,
    /// aarch64 NEON: two 128-bit vectors per 8-lane chunk.
    Neon,
}

impl Isa {
    pub fn describe(&self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Sse2 => "sse2",
            Self::Avx2Fma => "avx2+fma",
            Self::Neon => "neon",
        }
    }
}

/// Process-wide forced-scalar flag ([`set_mode`]).  Relaxed ordering is
/// enough: the flag only selects between bit-identical implementations,
/// so a racing reader picking the stale path is still correct.
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Hardware detection result, cached after the first query (feature
/// detection is a CPUID dance; the hot loops must not repeat it).
static DETECTED: OnceLock<Isa> = OnceLock::new();

fn env_forced_scalar() -> bool {
    match std::env::var("MMBSGD_FORCE_SCALAR") {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => false,
    }
}

#[cfg(target_arch = "x86_64")]
fn native_isa() -> Isa {
    if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma") {
        Isa::Avx2Fma
    } else {
        // SSE2 is part of the x86-64 baseline: always present.
        Isa::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn native_isa() -> Isa {
    // NEON is mandatory on aarch64.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn native_isa() -> Isa {
    Isa::Scalar
}

fn detected() -> Isa {
    *DETECTED.get_or_init(|| {
        if env_forced_scalar() {
            Isa::Scalar
        } else {
            native_isa()
        }
    })
}

/// Apply a requested [`SimdMode`].  `MMBSGD_FORCE_SCALAR` wins over
/// `Auto` (the env var is the outermost escape hatch).  Safe to call at
/// any point: every path is bit-identical, so in-flight computations
/// cannot change value.
pub fn set_mode(mode: SimdMode) {
    FORCE_SCALAR.store(mode == SimdMode::Scalar, Ordering::Relaxed);
}

/// The mode currently requested through [`set_mode`].
pub fn mode() -> SimdMode {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        SimdMode::Scalar
    } else {
        SimdMode::Auto
    }
}

/// The ISA the kernel primitives dispatch to right now (mode and env
/// overrides applied) — the value `mmbsgd train/evaluate/serve` print
/// next to the effective-threads line.
pub fn active_isa() -> Isa {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        Isa::Scalar
    } else {
        detected()
    }
}

// ------------------------------------------------------------------
// shared reduction tails (one implementation => provably same bits)
// ------------------------------------------------------------------

/// Sequential lane-order reduction + f64 remainder fold for a dot
/// product.  Every ISA path funnels through this, so the reduction
/// order is fixed by construction.
#[inline]
fn finish_dot(acc: [f32; LANES], ra: &[f32], rb: &[f32]) -> f64 {
    let mut s = 0.0f32;
    for v in acc {
        s += v;
    }
    let mut s = s as f64;
    for (x, y) in ra.iter().zip(rb) {
        s += (*x as f64) * (*y as f64);
    }
    s
}

/// [`finish_dot`]'s squared-distance twin (f64 difference form on the
/// remainder, as the pre-SIMD scalar loop did).
#[inline]
fn finish_sq(acc: [f32; LANES], ra: &[f32], rb: &[f32]) -> f64 {
    let mut s = 0.0f32;
    for v in acc {
        s += v;
    }
    let mut s = s as f64;
    for (x, y) in ra.iter().zip(rb) {
        let d = (x - y) as f64;
        s += d * d;
    }
    s
}

// ------------------------------------------------------------------
// scalar reference path
// ------------------------------------------------------------------

/// Scalar reference dot product — the 8-lane loop every vector path
/// must match bit-for-bit.  Public for the parity suite and the
/// `speedup/dot_simd_vs_scalar` bench; production code calls the
/// dispatched [`dot`].
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for (l, v) in acc.iter_mut().enumerate() {
            // plain mul + add: each op separately rounded — the
            // contract every ISA path reproduces
            *v += xa[l] * xb[l];
        }
    }
    finish_dot(acc, ra, rb)
}

/// Scalar reference squared distance (same lane layout as
/// [`dot_scalar`]).
pub fn sq_dist_scalar(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let ca = a.chunks_exact(LANES);
    let cb = b.chunks_exact(LANES);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for (l, v) in acc.iter_mut().enumerate() {
            let d = xa[l] - xb[l];
            *v += d * d;
        }
    }
    finish_sq(acc, ra, rb)
}

/// Scalar reference multi-row kernel: `out[r] = dot(q, rows[r])`.
/// Definitionally row-wise, so vector block kernels that interleave
/// rows must still equal it per row (they do: lanes are independent).
pub fn dot_block_scalar(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
    debug_assert_eq!(rows.len(), out.len() * dim);
    for (k, o) in out.iter_mut().enumerate() {
        *o = dot_scalar(q, &rows[k * dim..(k + 1) * dim]);
    }
}

// ------------------------------------------------------------------
// dispatched entry points
// ------------------------------------------------------------------

#[inline]
fn dot_isa(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    match isa {
        // SAFETY: `Isa::Avx2Fma` is only ever produced by `native_isa`
        // after a positive runtime `is_x86_feature_detected!("avx2")`.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { x86::dot_avx2(a, b) },
        // SAFETY: SSE2 is unconditionally part of the x86-64 baseline.
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::dot_sse2(a, b) },
        // SAFETY: NEON is unconditionally available on aarch64.
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::dot_neon(a, b) },
        _ => dot_scalar(a, b),
    }
}

#[inline]
fn sq_dist_isa(isa: Isa, a: &[f32], b: &[f32]) -> f64 {
    match isa {
        // SAFETY: see `dot_isa` — same detection guarantees.
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2Fma => unsafe { x86::sq_dist_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::sq_dist_sse2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { arm::sq_dist_neon(a, b) },
        _ => sq_dist_scalar(a, b),
    }
}

/// Runtime-dispatched dot product ⟨a,b⟩ — bit-identical to
/// [`dot_scalar`] on every ISA.  Mismatched lengths are a caller bug
/// (debug-asserted); release builds truncate to the shorter slice on
/// every path — the scalar `chunks_exact` + `zip` semantics — and
/// never read out of bounds.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    dot_isa(active_isa(), a, b)
}

/// Runtime-dispatched squared distance ‖a−b‖² — bit-identical to
/// [`sq_dist_scalar`] on every ISA.  Same length contract as [`dot`]:
/// mismatches truncate, never read out of bounds.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    sq_dist_isa(active_isa(), a, b)
}

/// Multi-row micro-kernel: `out[r] = ⟨q, rows[r·dim .. (r+1)·dim]⟩` for
/// every row of a contiguous row-major block (the flat `SvStore`
/// layout).  Rows are processed [`BLOCK`] at a time with the query
/// chunk loaded **once** per step and reused across the block — the
/// query stops round-tripping through the load units once per row,
/// which is where a queries×SVs kernel block spends most of its
/// bandwidth.  Per row the result is bit-identical to [`dot`] (lane
/// accumulators are per-row; interleaving changes nothing).
pub fn dot_block(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
    // Real asserts, not debug: the block micro-kernels do raw loads
    // sized by these shapes, so a caller bug must fail loudly here
    // rather than read out of bounds in release (one branch per
    // dot_block call — amortized over up to `out.len() · dim` lanes).
    assert_eq!(q.len(), dim, "dot_block: query/dim mismatch");
    assert_eq!(rows.len(), out.len() * dim, "dot_block: rows/out shape mismatch");
    let isa = active_isa();
    let mut r = 0;
    while r + BLOCK <= out.len() {
        let rs = &rows[r * dim..(r + BLOCK) * dim];
        let os = &mut out[r..r + BLOCK];
        match isa {
            // SAFETY: see `dot_isa` — same detection guarantees.
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2Fma => unsafe { x86::dot_block4_avx2(q, rs, dim, os) },
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => unsafe { x86::dot_block4_sse2(q, rs, dim, os) },
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => unsafe { arm::dot_block4_neon(q, rs, dim, os) },
            _ => dot_block_scalar(q, rs, dim, os),
        }
        r += BLOCK;
    }
    // tail rows (< BLOCK): plain per-row dots on the same ISA
    for (k, o) in out.iter_mut().enumerate().skip(r) {
        *o = dot_isa(isa, q, &rows[k * dim..(k + 1) * dim]);
    }
}

// ------------------------------------------------------------------
// x86-64 paths
// ------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{finish_dot, finish_sq, BLOCK, LANES};
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.  (Bounds:
    /// the trip count is derived from the *shorter* slice — mismatched
    /// lengths truncate like the scalar `chunks_exact` + `zip` loop,
    /// never read past either allocation.)
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            // mul + add, NOT fmadd: see the module determinism contract
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        finish_dot(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime.  Bounds: see
    /// [`dot_avx2`] — min-length trip count, no out-of-bounds reads.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn sq_dist_avx2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        finish_sq(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// Caller must have verified AVX2 support at runtime; `out.len()`
    /// must be [`BLOCK`] and `rows.len()` must be `BLOCK * dim`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_block4_avx2(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), BLOCK);
        debug_assert_eq!(rows.len(), BLOCK * dim);
        let n = dim - dim % LANES;
        let (qp, rp) = (q.as_ptr(), rows.as_ptr());
        let mut acc = [_mm256_setzero_ps(); BLOCK];
        let mut i = 0;
        while i < n {
            let vq = _mm256_loadu_ps(qp.add(i));
            for (r, a) in acc.iter_mut().enumerate() {
                let vr = _mm256_loadu_ps(rp.add(r * dim + i));
                *a = _mm256_add_ps(*a, _mm256_mul_ps(vq, vr));
            }
            i += LANES;
        }
        for (r, (o, a)) in out.iter_mut().zip(acc).enumerate() {
            let mut lanes = [0.0f32; LANES];
            _mm256_storeu_ps(lanes.as_mut_ptr(), a);
            *o = finish_dot(lanes, &q[n..], &rows[r * dim + n..(r + 1) * dim]);
        }
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline; always safe there.
    /// Bounds: see [`dot_avx2`] — min-length trip count.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = _mm_setzero_ps();
        let mut hi = _mm_setzero_ps();
        let mut i = 0;
        while i < n {
            lo = _mm_add_ps(lo, _mm_mul_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i))));
            hi = _mm_add_ps(
                hi,
                _mm_mul_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4))),
            );
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        finish_dot(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// SSE2 is part of the x86-64 baseline; always safe there.
    /// Bounds: see [`dot_avx2`] — min-length trip count.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn sq_dist_sse2(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = _mm_setzero_ps();
        let mut hi = _mm_setzero_ps();
        let mut i = 0;
        while i < n {
            let dl = _mm_sub_ps(_mm_loadu_ps(pa.add(i)), _mm_loadu_ps(pb.add(i)));
            let dh = _mm_sub_ps(_mm_loadu_ps(pa.add(i + 4)), _mm_loadu_ps(pb.add(i + 4)));
            lo = _mm_add_ps(lo, _mm_mul_ps(dl, dl));
            hi = _mm_add_ps(hi, _mm_mul_ps(dh, dh));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm_storeu_ps(lanes.as_mut_ptr(), lo);
        _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi);
        finish_sq(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// SSE2 baseline; `out.len() == BLOCK`, `rows.len() == BLOCK * dim`.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_block4_sse2(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), BLOCK);
        debug_assert_eq!(rows.len(), BLOCK * dim);
        let n = dim - dim % LANES;
        let (qp, rp) = (q.as_ptr(), rows.as_ptr());
        let mut lo = [_mm_setzero_ps(); BLOCK];
        let mut hi = [_mm_setzero_ps(); BLOCK];
        let mut i = 0;
        while i < n {
            let ql = _mm_loadu_ps(qp.add(i));
            let qh = _mm_loadu_ps(qp.add(i + 4));
            for (r, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let base = r * dim + i;
                *l = _mm_add_ps(*l, _mm_mul_ps(ql, _mm_loadu_ps(rp.add(base))));
                *h = _mm_add_ps(*h, _mm_mul_ps(qh, _mm_loadu_ps(rp.add(base + 4))));
            }
            i += LANES;
        }
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; LANES];
            _mm_storeu_ps(lanes.as_mut_ptr(), lo[r]);
            _mm_storeu_ps(lanes.as_mut_ptr().add(4), hi[r]);
            *o = finish_dot(lanes, &q[n..], &rows[r * dim + n..(r + 1) * dim]);
        }
    }
}

// ------------------------------------------------------------------
// aarch64 NEON paths
// ------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{finish_dot, finish_sq, BLOCK, LANES};
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON is mandatory on aarch64; always safe there.  Bounds: trip
    /// count from the shorter slice — no out-of-bounds reads.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n {
            // vmul + vadd, not vfma: the determinism contract again
            lo = vaddq_f32(lo, vmulq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i))));
            hi = vaddq_f32(hi, vmulq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4))));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        finish_dot(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// NEON is mandatory on aarch64; always safe there.  Bounds: trip
    /// count from the shorter slice — no out-of-bounds reads.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn sq_dist_neon(a: &[f32], b: &[f32]) -> f64 {
        let len = a.len().min(b.len());
        let n = len - len % LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n {
            let dl = vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
            let dh = vsubq_f32(vld1q_f32(pa.add(i + 4)), vld1q_f32(pb.add(i + 4)));
            lo = vaddq_f32(lo, vmulq_f32(dl, dl));
            hi = vaddq_f32(hi, vmulq_f32(dh, dh));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), hi);
        finish_sq(lanes, &a[n..], &b[n..])
    }

    /// # Safety
    /// NEON mandatory; `out.len() == BLOCK`, `rows.len() == BLOCK * dim`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_block4_neon(q: &[f32], rows: &[f32], dim: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), BLOCK);
        debug_assert_eq!(rows.len(), BLOCK * dim);
        let n = dim - dim % LANES;
        let (qp, rp) = (q.as_ptr(), rows.as_ptr());
        let mut lo = [vdupq_n_f32(0.0); BLOCK];
        let mut hi = [vdupq_n_f32(0.0); BLOCK];
        let mut i = 0;
        while i < n {
            let ql = vld1q_f32(qp.add(i));
            let qh = vld1q_f32(qp.add(i + 4));
            for (r, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
                let base = r * dim + i;
                *l = vaddq_f32(*l, vmulq_f32(ql, vld1q_f32(rp.add(base))));
                *h = vaddq_f32(*h, vmulq_f32(qh, vld1q_f32(rp.add(base + 4))));
            }
            i += LANES;
        }
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; LANES];
            vst1q_f32(lanes.as_mut_ptr(), lo[r]);
            vst1q_f32(lanes.as_mut_ptr().add(4), hi[r]);
            *o = finish_dot(lanes, &q[n..], &rows[r * dim + n..(r + 1) * dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    // Dispatch parity over ragged dims / row counts lives in
    // `rust/tests/simd_parity.rs` (one home for the contract; CI runs
    // that suite under both dispatch modes).  The unit tests here
    // cover only what the integration suite does not: bitwise
    // commutativity and the mode/ISA plumbing.
    use super::*;

    fn vecs(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::rng::Xoshiro256::new(seed);
        let a: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 1.7).collect();
        let b: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.6 - 0.3).collect();
        (a, b)
    }

    #[test]
    fn dot_is_bitwise_commutative() {
        // The tile engine relies on dot(q, x) == dot(x, q) bitwise (it
        // feeds dot_block values into expansions written either way).
        for d in [1usize, 7, 8, 33, 300] {
            let (a, b) = vecs(d, d as u64 + 7);
            assert_eq!(dot(&a, &b).to_bits(), dot(&b, &a).to_bits(), "d={d}");
            assert_eq!(sq_dist(&a, &b).to_bits(), sq_dist(&b, &a).to_bits(), "d={d}");
        }
    }

    #[test]
    fn mode_round_trip_and_parse() {
        assert_eq!(SimdMode::parse("auto"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("scalar"), Some(SimdMode::Scalar));
        assert_eq!(SimdMode::parse("avx2"), None);
        for m in [SimdMode::Auto, SimdMode::Scalar] {
            assert_eq!(SimdMode::parse(m.describe()), Some(m));
        }
        // Isa labels are stable (they land in perf reports)
        assert_eq!(Isa::Avx2Fma.describe(), "avx2+fma");
    }
}

//! The support-vector store — the budget data structure.
//!
//! Contiguous row-major point storage (cache-friendly kernel loops) with
//! O(1) push / swap-remove / replace, uniform coefficient scaling done
//! lazily (Pegasos multiplies every α by `1-λη` each step; doing that
//! eagerly would be O(B) per step, so a global multiplier is kept and
//! folded in on access — the classic trick, and measurably the single
//! most important optimization in the native hot path).
//!
//! The store also caches each SV's squared norm `‖x_j‖²` (maintained on
//! every mutation), so the kernel hot loops can use the expansion
//! `d² = ‖x‖² + ‖q‖² − 2⟨x,q⟩` with a pure dot-product inner loop and
//! the query norm hoisted out of the B-loop (EXPERIMENTS.md §Perf).

use crate::kernel::sq_norm;

/// Budget of support vectors with coefficients.
#[derive(Clone, Debug)]
pub struct SvStore {
    dim: usize,
    points: Vec<f32>,
    alphas: Vec<f64>, // stored WITHOUT the global scale factor
    norms2: Vec<f64>, // cached ‖x_j‖² per SV
    scale: f64,       // every effective α_j = alphas[j] * scale
}

/// Folding threshold: when `scale` drops below this, fold it into the
/// stored coefficients to avoid denormals (Pegasos scales decay fast).
const SCALE_FOLD: f64 = 1e-100;

impl SvStore {
    pub fn new(dim: usize) -> Self {
        Self { dim, points: Vec::new(), alphas: Vec::new(), norms2: Vec::new(), scale: 1.0 }
    }

    pub fn with_capacity(dim: usize, cap: usize) -> Self {
        Self {
            dim,
            points: Vec::with_capacity(cap * dim),
            alphas: Vec::with_capacity(cap),
            norms2: Vec::with_capacity(cap),
            scale: 1.0,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.alphas.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.alphas.is_empty()
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn point(&self, j: usize) -> &[f32] {
        &self.points[j * self.dim..(j + 1) * self.dim]
    }

    /// Effective coefficient (global scale folded in).
    #[inline]
    pub fn alpha(&self, j: usize) -> f64 {
        self.alphas[j] * self.scale
    }

    /// Cached squared norm ‖x_j‖² of SV `j`.
    #[inline]
    pub fn norm2(&self, j: usize) -> f64 {
        self.norms2[j]
    }

    /// All cached squared norms (one per SV).
    #[inline]
    pub fn norms2(&self) -> &[f64] {
        &self.norms2
    }

    /// All points as one contiguous slice (runtime marshalling).
    #[inline]
    pub fn points_flat(&self) -> &[f32] {
        &self.points
    }

    /// Effective coefficients, materialized.
    pub fn alphas_vec(&self) -> Vec<f64> {
        self.alphas.iter().map(|a| a * self.scale).collect()
    }

    /// Raw stored coefficients WITHOUT the lazy scale folded in
    /// (checkpointing: serializing `(raw, scale)` instead of the folded
    /// product keeps a resumed run bit-identical — folding would
    /// re-associate the multiplication chain and drift in the last ulp).
    #[inline]
    pub fn raw_alphas(&self) -> &[f64] {
        &self.alphas
    }

    /// The lazy global scale factor (see [`SvStore::raw_alphas`]).
    #[inline]
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Rebuild a store from checkpoint parts: flat row-major points,
    /// raw (unscaled) coefficients, and the lazy scale.  Norm caches
    /// are recomputed (deterministically) from the points.
    ///
    /// Callers must pre-validate `points.len() == alphas.len() * dim`;
    /// the checkpoint parser does.
    pub fn from_raw(dim: usize, points: Vec<f32>, alphas: Vec<f64>, scale: f64) -> Self {
        assert_eq!(points.len(), alphas.len() * dim, "points/alphas shape mismatch");
        let norms2 = if dim == 0 {
            vec![0.0; alphas.len()]
        } else {
            points.chunks_exact(dim).map(sq_norm).collect()
        };
        Self { dim, points, alphas, norms2, scale }
    }

    pub fn push(&mut self, point: &[f32], alpha: f64) {
        assert_eq!(point.len(), self.dim, "point dim mismatch");
        self.points.extend_from_slice(point);
        self.norms2.push(sq_norm(point));
        // Store pre-divided so the effective value is `alpha`.
        self.alphas.push(alpha / self.scale);
    }

    /// O(1) removal; the last SV moves into slot `j`.
    pub fn swap_remove(&mut self, j: usize) {
        let last = self.len() - 1;
        if j != last {
            let (head, tail) = self.points.split_at_mut(last * self.dim);
            head[j * self.dim..(j + 1) * self.dim].copy_from_slice(&tail[..self.dim]);
        }
        self.points.truncate(last * self.dim);
        self.alphas.swap_remove(j);
        self.norms2.swap_remove(j);
    }

    /// Overwrite SV `j` with a new point/coefficient (merge result).
    pub fn replace(&mut self, j: usize, point: &[f32], alpha: f64) {
        assert_eq!(point.len(), self.dim);
        self.points[j * self.dim..(j + 1) * self.dim].copy_from_slice(point);
        self.norms2[j] = sq_norm(point);
        self.alphas[j] = alpha / self.scale;
    }

    /// Add to SV `j`'s effective coefficient (SGD update on an existing SV).
    pub fn add_alpha(&mut self, j: usize, delta: f64) {
        self.alphas[j] += delta / self.scale;
    }

    /// Multiply every effective coefficient by `f` — O(1).
    ///
    /// `f = 0` (the first Pegasos step has η₁λ = 1) zeroes the stored
    /// coefficients eagerly: a zero lazy scale would make later pushes
    /// divide by zero.
    pub fn scale_all(&mut self, f: f64) {
        debug_assert!(f.is_finite());
        if f == 0.0 {
            for a in &mut self.alphas {
                *a = 0.0;
            }
            self.scale = 1.0;
            return;
        }
        self.scale *= f;
        if self.scale.abs() < SCALE_FOLD {
            self.fold_scale();
        }
    }

    /// Fold the lazy scale into storage (needed before handing raw alphas
    /// to code that bypasses `alpha()`).
    pub fn fold_scale(&mut self) {
        if self.scale != 1.0 {
            for a in &mut self.alphas {
                *a *= self.scale;
            }
            self.scale = 1.0;
        }
    }

    /// Index of the SV with the smallest |effective α| — the paper's
    /// first-merge-candidate heuristic. O(B). The global scale does not
    /// change the argmin, so the lazy factor is ignored.
    pub fn min_abs_alpha(&self) -> Option<usize> {
        if self.is_empty() {
            return None;
        }
        let mut best = 0;
        let mut best_v = f64::INFINITY;
        for (j, &a) in self.alphas.iter().enumerate() {
            let v = a.abs();
            if v < best_v {
                best_v = v;
                best = j;
            }
        }
        Some(best)
    }

    /// Drop SVs whose effective |α| is below `eps` (post-merge hygiene —
    /// merged-away points with cancelled coefficients carry no signal but
    /// cost kernel evaluations forever).
    pub fn prune(&mut self, eps: f64) -> usize {
        let mut removed = 0;
        let mut j = 0;
        while j < self.len() {
            if self.alpha(j).abs() < eps {
                self.swap_remove(j);
                removed += 1;
            } else {
                j += 1;
            }
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_access() {
        let mut s = SvStore::new(2);
        s.push(&[1.0, 2.0], 0.5);
        s.push(&[3.0, 4.0], -0.25);
        assert_eq!(s.len(), 2);
        assert_eq!(s.point(1), &[3.0, 4.0]);
        assert_eq!(s.alpha(0), 0.5);
    }

    #[test]
    fn lazy_scale_matches_eager() {
        let mut s = SvStore::new(1);
        s.push(&[0.0], 2.0);
        s.push(&[1.0], -1.0);
        s.scale_all(0.5);
        s.scale_all(0.8);
        assert!((s.alpha(0) - 0.8).abs() < 1e-15);
        assert!((s.alpha(1) + 0.4).abs() < 1e-15);
        // push after scaling must still read back exactly
        s.push(&[2.0], 0.7);
        assert!((s.alpha(2) - 0.7).abs() < 1e-15);
        s.fold_scale();
        assert!((s.alpha(0) - 0.8).abs() < 1e-15);
    }

    #[test]
    fn swap_remove_moves_last() {
        let mut s = SvStore::new(1);
        for i in 0..4 {
            s.push(&[i as f32], i as f64);
        }
        s.swap_remove(1);
        assert_eq!(s.len(), 3);
        assert_eq!(s.point(1), &[3.0]);
        assert_eq!(s.alpha(1), 3.0);
    }

    #[test]
    fn swap_remove_last_element() {
        let mut s = SvStore::new(1);
        s.push(&[1.0], 1.0);
        s.swap_remove(0);
        assert!(s.is_empty());
    }

    #[test]
    fn replace_and_add_alpha() {
        let mut s = SvStore::new(2);
        s.push(&[0.0, 0.0], 1.0);
        s.scale_all(0.5);
        s.replace(0, &[9.0, 9.0], 3.0);
        assert_eq!(s.point(0), &[9.0, 9.0]);
        assert!((s.alpha(0) - 3.0).abs() < 1e-15);
        s.add_alpha(0, 0.5);
        assert!((s.alpha(0) - 3.5).abs() < 1e-15);
    }

    #[test]
    fn min_abs_alpha_finds_smallest() {
        let mut s = SvStore::new(1);
        s.push(&[0.0], -3.0);
        s.push(&[1.0], 0.1);
        s.push(&[2.0], 2.0);
        assert_eq!(s.min_abs_alpha(), Some(1));
        assert_eq!(SvStore::new(1).min_abs_alpha(), None);
    }

    #[test]
    fn scale_fold_avoids_denormals() {
        let mut s = SvStore::new(1);
        s.push(&[0.0], 1.0);
        for _ in 0..2000 {
            s.scale_all(0.8);
        }
        // effective alpha underflows to ~0 but stays finite / non-NaN
        assert!(s.alpha(0).is_finite());
    }

    #[test]
    fn norm_cache_tracks_every_mutation() {
        let mut s = SvStore::new(2);
        s.push(&[3.0, 4.0], 1.0);
        s.push(&[1.0, 0.0], 2.0);
        s.push(&[0.0, 2.0], 3.0);
        assert_eq!(s.norm2(0), 25.0);
        assert_eq!(s.norms2(), &[25.0, 1.0, 4.0]);
        s.swap_remove(0); // last SV moves into slot 0
        assert_eq!(s.norm2(0), 4.0);
        assert_eq!(s.len(), 2);
        s.replace(1, &[0.5, 0.5], 1.0);
        assert!((s.norm2(1) - 0.5).abs() < 1e-12);
        // cache always mirrors a fresh computation
        for j in 0..s.len() {
            assert_eq!(s.norm2(j), crate::kernel::sq_norm(s.point(j)));
        }
    }

    #[test]
    fn from_raw_roundtrips_lazy_scale_exactly() {
        let mut s = SvStore::new(2);
        s.push(&[1.0, 2.0], 0.7);
        s.push(&[-3.0, 0.5], -1.3);
        s.scale_all(0.999_877);
        s.scale_all(0.875);
        let re = SvStore::from_raw(
            s.dim(),
            s.points_flat().to_vec(),
            s.raw_alphas().to_vec(),
            s.scale(),
        );
        assert_eq!(re.len(), 2);
        assert_eq!(re.scale(), s.scale());
        assert_eq!(re.raw_alphas(), s.raw_alphas());
        // bit-identical effective coefficients and rebuilt norm cache
        for j in 0..2 {
            assert_eq!(re.alpha(j).to_bits(), s.alpha(j).to_bits());
            assert_eq!(re.norm2(j), s.norm2(j));
        }
    }

    #[test]
    fn prune_removes_tiny() {
        let mut s = SvStore::new(1);
        s.push(&[0.0], 1.0);
        s.push(&[1.0], 1e-12);
        s.push(&[2.0], -2.0);
        let n = s.prune(1e-9);
        assert_eq!(n, 1);
        assert_eq!(s.len(), 2);
        assert!(s.alphas_vec().iter().all(|a| a.abs() > 1e-9));
    }
}

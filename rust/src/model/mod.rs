//! The kernel-SVM model: support vectors, coefficients, bias; prediction,
//! weight-vector norms, persistence.

mod store;
pub use store::SvStore;

use crate::data::{Dataset, DenseMatrix};
use crate::kernel::{sq_dist_cached, Gaussian, EXP_NEG_CUTOFF};
use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// A trained (budgeted) kernel SVM: `f(x) = Σ_j α_j k(x_j, x) + b`.
#[derive(Clone, Debug)]
pub struct SvmModel {
    pub svs: SvStore,
    pub bias: f64,
    pub gamma: f64,
    /// Provenance string recorded by the trainer (solver, M, B, seed).
    pub meta: String,
}

impl SvmModel {
    pub fn new(dim: usize, gamma: f64) -> Self {
        Self { svs: SvStore::new(dim), bias: 0.0, gamma, meta: String::new() }
    }

    pub fn kernel(&self) -> Gaussian {
        Gaussian::new(self.gamma)
    }

    /// Decision value for one point — routed through the norm-cached
    /// native margin loop (`d² = ‖x‖² + ‖q‖² − 2⟨x,q⟩` with the SV
    /// norms read from the [`SvStore`] cache), the same hot path the
    /// trainer uses.
    pub fn decision(&self, x: &[f32]) -> f64 {
        self.bias + crate::runtime::margin1_native(&self.svs, self.gamma, x)
    }

    /// Predicted label (±1).
    pub fn predict(&self, x: &[f32]) -> f32 {
        if self.decision(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Decision values for a batch of query rows through the blocked
    /// kernel-tile engine (single worker, local scratch) — bit-identical
    /// to calling [`SvmModel::decision`] per row, without re-streaming
    /// the SV store once per query.  Backend-holding callers
    /// ([`crate::serve::Predictor`], `bsgd::evaluate`) should prefer
    /// `Backend::margins`, which adds thread sharding on top.
    pub fn decision_batch(&self, queries: &DenseMatrix) -> Vec<f64> {
        let mut out = crate::runtime::tile::margins(&self.svs, self.gamma, queries);
        for f in &mut out {
            *f += self.bias;
        }
        out
    }

    /// Accuracy over a dataset (batched through the tile engine).
    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let decisions = self.decision_batch(&ds.x);
        let correct = decisions
            .iter()
            .zip(&ds.y)
            .filter(|(&f, &y)| (if f >= 0.0 { 1.0 } else { -1.0 }) == y)
            .count();
        correct as f64 / ds.len() as f64
    }

    /// `||w||^2 = α^T K α` — the regularizer value, O(B²) kernel evals.
    ///
    /// Distances use the dot-product identity with the [`SvStore`] norm
    /// cache (row norm hoisted out of the inner loop), and far pairs
    /// (`γd²` > [`EXP_NEG_CUTOFF`], contribution < 4e-18) skip the
    /// `exp` — the same treatment as the training hot paths.
    pub fn weight_norm2(&self) -> f64 {
        let b = self.svs.len();
        let mut s = 0.0;
        for i in 0..b {
            let a_i = self.svs.alpha(i);
            let x_i = self.svs.point(i);
            let n_i = self.svs.norm2(i);
            s += a_i * a_i; // k(x_i,x_i)=1
            for j in (i + 1)..b {
                let d2 = sq_dist_cached(x_i, n_i, self.svs.point(j), self.svs.norm2(j));
                let e = self.gamma * d2;
                if e < EXP_NEG_CUTOFF {
                    s += 2.0 * a_i * self.svs.alpha(j) * (-e).exp();
                }
            }
        }
        s
    }

    /// Primal objective `λ/2 ||w||² + 1/n Σ hinge` on a dataset
    /// (hinge terms batched through the tile engine).
    pub fn primal_objective(&self, ds: &Dataset, lambda: f64) -> f64 {
        let mut loss = 0.0;
        for (f, &y) in self.decision_batch(&ds.x).into_iter().zip(&ds.y) {
            loss += (1.0 - (y as f64) * f).max(0.0);
        }
        lambda / 2.0 * self.weight_norm2() + loss / ds.len().max(1) as f64
    }

    // ------------------------------------------------------ persistence

    /// Serialize to a simple self-describing text format.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "mmbsgd-model v1");
        let _ = writeln!(out, "gamma {}", self.gamma);
        let _ = writeln!(out, "bias {}", self.bias);
        let _ = writeln!(out, "dim {}", self.svs.dim());
        let _ = writeln!(out, "nsv {}", self.svs.len());
        let _ = writeln!(out, "meta {}", self.meta.replace('\n', " "));
        for j in 0..self.svs.len() {
            let _ = write!(out, "{}", self.svs.alpha(j));
            for &v in self.svs.point(j) {
                let _ = write!(out, " {v}");
            }
            out.push('\n');
        }
        out
    }

    pub fn from_text(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let magic = lines.next().context("empty model file")?;
        if magic.trim() != "mmbsgd-model v1" {
            bail!("bad magic line: {magic:?}");
        }
        let mut gamma = None;
        let mut bias = None;
        let mut dim = None;
        let mut nsv = None;
        let mut meta = String::new();
        for _ in 0..5 {
            let line = lines.next().context("truncated header")?;
            let (key, val) = line.split_once(' ').unwrap_or((line, ""));
            match key {
                "gamma" => gamma = Some(val.parse::<f64>()?),
                "bias" => bias = Some(val.parse::<f64>()?),
                "dim" => dim = Some(val.parse::<usize>()?),
                "nsv" => nsv = Some(val.parse::<usize>()?),
                "meta" => meta = val.to_string(),
                k => bail!("unknown header key {k:?}"),
            }
        }
        let dim = dim.context("missing dim")?;
        let nsv = nsv.context("missing nsv")?;
        let mut model = SvmModel::new(dim, gamma.context("missing gamma")?);
        model.bias = bias.context("missing bias")?;
        model.meta = meta;
        for _ in 0..nsv {
            let line = lines.next().context("truncated SV block")?;
            let mut it = line.split_ascii_whitespace();
            let alpha: f64 = it.next().context("missing alpha")?.parse()?;
            let point: Vec<f32> =
                it.map(|t| t.parse::<f32>()).collect::<Result<_, _>>()?;
            if point.len() != dim {
                bail!("SV has {} features, expected {dim}", point.len());
            }
            model.svs.push(&point, alpha);
        }
        Ok(model)
    }

    /// Save through the durable layer: atomic replace with a checksum
    /// footer, previous generation kept at `<path>.prev`.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::durable::write_atomic(path, &self.to_text())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Load a model file, verifying the durable checksum footer when
    /// one is present (files written before the footer existed load
    /// unchecked — `from_text`'s structural validation is the
    /// backstop for those).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = crate::util::durable::verify(&text, path)?;
        Self::from_text(v.payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DenseMatrix;
    use crate::kernel::Kernel;

    fn toy_model() -> SvmModel {
        let mut m = SvmModel::new(2, 0.5);
        m.svs.push(&[0.0, 0.0], 1.0);
        m.svs.push(&[1.0, 0.0], -0.5);
        m.bias = 0.1;
        m.meta = "test".into();
        m
    }

    #[test]
    fn decision_matches_manual() {
        let m = toy_model();
        let x = [0.0f32, 1.0];
        let k = Gaussian::new(0.5);
        let want = 1.0 * k.eval(&[0.0, 0.0], &x) - 0.5 * k.eval(&[1.0, 0.0], &x) + 0.1;
        assert!((m.decision(&x) - want).abs() < 1e-12);
    }

    #[test]
    fn accuracy_counts() {
        let m = toy_model();
        let x = DenseMatrix::from_rows(vec![vec![0.0, 0.0], vec![5.0, 5.0]]);
        // decision(0,0) ≈ 1 - 0.5 e^{-.5} + .1 > 0 -> +1; far point -> bias 0.1 -> +1
        let ds = Dataset::new(x, vec![1.0, -1.0], "t");
        assert!((m.accuracy(&ds) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn weight_norm_two_points() {
        let m = toy_model();
        let k = Gaussian::new(0.5).eval(&[0.0, 0.0], &[1.0, 0.0]);
        let want = 1.0 + 0.25 + 2.0 * 1.0 * (-0.5) * k;
        assert!((m.weight_norm2() - want).abs() < 1e-12);
    }

    #[test]
    fn text_roundtrip() {
        let m = toy_model();
        let re = SvmModel::from_text(&m.to_text()).unwrap();
        assert_eq!(re.svs.len(), 2);
        assert_eq!(re.bias, m.bias);
        assert_eq!(re.gamma, m.gamma);
        assert_eq!(re.meta, "test");
        assert_eq!(re.svs.point(1), m.svs.point(1));
        assert_eq!(re.svs.alpha(0), m.svs.alpha(0));
    }

    #[test]
    fn from_text_rejects_garbage() {
        assert!(SvmModel::from_text("").is_err());
        assert!(SvmModel::from_text("wrong magic\n").is_err());
        let truncated = "mmbsgd-model v1\ngamma 1\nbias 0\ndim 2\nnsv 3\nmeta\n1.0 0 0\n";
        assert!(SvmModel::from_text(truncated).is_err());
    }

    #[test]
    fn primal_objective_decreases_with_margin() {
        let mut m = SvmModel::new(1, 1.0);
        m.svs.push(&[1.0], 2.0);
        let x = DenseMatrix::from_rows(vec![vec![1.0]]);
        let ds = Dataset::new(x, vec![1.0], "t");
        // margin = 2.0 -> hinge 0; objective = λ/2 * 4
        let obj = m.primal_objective(&ds, 0.5);
        assert!((obj - 1.0).abs() < 1e-12);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! BSGD is a randomized algorithm (random presentation order); the paper
//! repeatedly attributes result noise to this randomness.  Every run in
//! this crate is therefore seeded explicitly — experiments are exactly
//! reproducible — using xoshiro256**, a small, fast, well-tested
//! generator (Blackman & Vigna).  No external `rand` crate: the image is
//! offline and the generator is ~40 lines.

/// xoshiro256** 1.0.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that small/sequential seeds give well-mixed
    /// initial states (the canonical seeding recommendation).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased
    /// enough for shuffling; exact rejection is overkill here).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; generation is not a hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A fresh generator whose state is derived from this one (for
    /// spawning per-worker streams that do not overlap in practice).
    pub fn fork(&mut self) -> Self {
        Self::new(self.next_u64())
    }

    /// The raw 256-bit state, for checkpointing: a generator restored
    /// via [`Xoshiro256::from_state`] continues the exact same stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Xoshiro256::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        let mut a = Xoshiro256::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Xoshiro256::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut v: Vec<usize> = (0..1000).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }
}

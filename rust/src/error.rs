//! Typed errors for the training / serving entry paths.
//!
//! The library's public surface (session construction, stepping,
//! checkpoint parsing, batched prediction) must never panic on
//! user-supplied input: every invalid configuration, malformed
//! checkpoint, or shape mismatch maps to a [`TrainError`] variant the
//! caller can match on.  The variants carry enough structure for
//! programmatic handling (which config field, which dimensions) while
//! `Display` renders an actionable message; `std::error::Error` is
//! implemented so `?` converts into `anyhow::Error` at the CLI layer.

use std::fmt;

/// Everything that can go wrong constructing or driving a training
/// session or a serving [`crate::serve::Predictor`].
#[derive(Clone, Debug, PartialEq)]
pub enum TrainError {
    /// A [`crate::config::TrainConfig`] invariant is violated.
    InvalidConfig {
        /// The offending field (`"lambda"`, `"gamma"`, `"budget"`,
        /// `"mergees"`, `"epochs"`, `"folds"`, ...).
        field: &'static str,
        message: String,
    },
    /// A `c = ...` cost parameter was set (TOML/CLI convenience) but
    /// never resolved against the training-set size.  λ = 1/(n·C)
    /// needs n; call [`crate::config::TrainConfig::resolve_c`] first.
    UnresolvedCost { c: f64 },
    /// The training (or evaluation) dataset holds no samples.
    EmptyDataset,
    /// A sample or query row has the wrong feature count.
    DimMismatch { expected: usize, got: usize },
    /// A checkpoint (or model) blob failed to parse.
    Checkpoint(String),
    /// A checkpoint file on disk failed its durable-layer checksum or
    /// its structural parse, and no usable `.prev` generation could
    /// stand in.  Produced by [`crate::solver::load_checkpoint`];
    /// unlike [`TrainError::Checkpoint`] it names the file, the failing
    /// section, the byte offset, and whether a `.prev` fallback existed.
    CorruptCheckpoint {
        /// The checkpoint path as given.
        path: String,
        /// Failing section: `"io"`, `"footer"`, `"payload"`, `"body"`.
        section: String,
        /// Byte offset within the file where the check failed
        /// (0 when the failure has no position, e.g. a missing file).
        offset: u64,
        /// Whether a `<path>.prev` generation was present (it too
        /// failed, or the error would not have been raised).
        prev_exists: bool,
        /// Human-readable cause, including the `.prev` failure when
        /// the fallback was tried.
        detail: String,
    },
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::InvalidConfig { field, message } => {
                write!(f, "invalid config: {field}: {message}")
            }
            TrainError::UnresolvedCost { c } => write!(
                f,
                "cost parameter C = {c} is unresolved; λ = 1/(n·C) needs the \
                 training-set size — call TrainConfig::resolve_c(n) before training"
            ),
            TrainError::EmptyDataset => write!(f, "empty dataset"),
            TrainError::DimMismatch { expected, got } => {
                write!(f, "feature-dimension mismatch: expected {expected}, got {got}")
            }
            TrainError::Checkpoint(msg) => write!(f, "bad checkpoint: {msg}"),
            TrainError::CorruptCheckpoint { path, section, offset, prev_exists, detail } => {
                let fallback = if *prev_exists {
                    "a .prev generation exists but also failed"
                } else {
                    "no .prev fallback generation is present"
                };
                write!(
                    f,
                    "corrupt checkpoint {path}: {section} at byte {offset}: {detail} ({fallback})"
                )
            }
        }
    }
}

impl std::error::Error for TrainError {}

/// Everything that can go wrong serving a request through the
/// [`crate::serve`] subsystem.  Serving is per-request fallible: a
/// malformed line, an over-quota queue, or a mismatched query dimension
/// fails *that request* with a variant the server renders as an `err`
/// reply — the process, the connection, and every other queued request
/// keep going.  Nothing in the serving path panics on user input.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// A request (or route arm, or `swap-model`) named a model the
    /// registry does not hold.
    UnknownModel(String),
    /// The pending queue is at capacity and the engine runs
    /// [`crate::serve::ShedPolicy::Reject`]: the *new* request is
    /// refused up front.
    QueueFull { limit: usize },
    /// The pending queue was at capacity under
    /// [`crate::serve::ShedPolicy::Oldest`] and this (oldest) request
    /// was dropped to admit a newer one.
    Shed,
    /// A protocol line failed to parse (unknown command, bad float,
    /// missing argument).  Carries the reason verbatim for the `err`
    /// reply.
    BadRequest(String),
    /// A route table was rejected (empty, zero total weight, or an arm
    /// naming an absent model).
    BadRoute(String),
    /// Model validation / query shape errors, forwarded from the
    /// training-side typed errors (e.g. [`TrainError::DimMismatch`]).
    Model(TrainError),
    /// Socket-level failure (bind, accept, read, write).  String-typed:
    /// `std::io::Error` is neither `Clone` nor `PartialEq`, and serving
    /// only ever reports these, never matches on the kind.
    Io(String),
    /// The request sat in the engine queue past the configured
    /// per-request deadline and was expired at flush time instead of
    /// occupying a batch row.
    Deadline { waited_ms: u64, deadline_ms: u64 },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownModel(name) => write!(f, "unknown model {name:?}"),
            ServeError::QueueFull { limit } => {
                write!(f, "queue full ({limit} pending); request rejected")
            }
            ServeError::Shed => write!(f, "request shed: queue overflowed while waiting"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::BadRoute(msg) => write!(f, "bad route: {msg}"),
            ServeError::Model(e) => write!(f, "model: {e}"),
            ServeError::Io(msg) => write!(f, "io: {msg}"),
            ServeError::Deadline { waited_ms, deadline_ms } => write!(
                f,
                "deadline exceeded: waited {waited_ms}ms against a {deadline_ms}ms deadline"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<TrainError> for ServeError {
    fn from(e: TrainError) -> Self {
        ServeError::Model(e)
    }
}

impl From<std::io::Error> for ServeError {
    fn from(e: std::io::Error) -> Self {
        ServeError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_field() {
        let e = TrainError::InvalidConfig { field: "gamma", message: "must be positive".into() };
        let s = e.to_string();
        assert!(s.contains("gamma") && s.contains("positive"), "{s}");
    }

    #[test]
    fn unresolved_cost_tells_the_fix() {
        let s = TrainError::UnresolvedCost { c: 8.0 }.to_string();
        assert!(s.contains("resolve_c"), "{s}");
        assert!(s.contains('8'), "{s}");
    }

    #[test]
    fn converts_into_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(TrainError::EmptyDataset)?
        }
        let err = inner().unwrap_err();
        assert!(format!("{err}").contains("empty dataset"));
    }

    #[test]
    fn dim_mismatch_carries_both_sides() {
        let e = TrainError::DimMismatch { expected: 22, got: 7 };
        assert_eq!(e, TrainError::DimMismatch { expected: 22, got: 7 });
        assert!(e.to_string().contains("22"));
    }

    #[test]
    fn serve_errors_render_actionably() {
        let e = ServeError::QueueFull { limit: 64 };
        assert!(e.to_string().contains("64"), "{e}");
        let e = ServeError::UnknownModel("champion".into());
        assert!(e.to_string().contains("champion"), "{e}");
        let e: ServeError = TrainError::DimMismatch { expected: 3, got: 5 }.into();
        assert_eq!(e, ServeError::Model(TrainError::DimMismatch { expected: 3, got: 5 }));
        assert!(e.to_string().contains("mismatch"), "{e}");
        let e = ServeError::Deadline { waited_ms: 120, deadline_ms: 50 };
        let s = e.to_string();
        assert!(s.contains("120") && s.contains("50"), "{s}");
    }

    #[test]
    fn corrupt_checkpoint_names_section_offset_and_fallback() {
        let e = TrainError::CorruptCheckpoint {
            path: "ck.txt".into(),
            section: "payload".into(),
            offset: 412,
            prev_exists: false,
            detail: "checksum mismatch".into(),
        };
        let s = e.to_string();
        assert!(s.contains("ck.txt"), "{s}");
        assert!(s.contains("payload"), "{s}");
        assert!(s.contains("412"), "{s}");
        assert!(s.contains("no .prev fallback"), "{s}");
        let e = TrainError::CorruptCheckpoint {
            path: "ck.txt".into(),
            section: "body".into(),
            offset: 9,
            prev_exists: true,
            detail: "line 2: bad rng".into(),
        };
        assert!(e.to_string().contains("also failed"), "{e}");
    }
}

//! Typed errors for the training / serving entry paths.
//!
//! The library's public surface (session construction, stepping,
//! checkpoint parsing, batched prediction) must never panic on
//! user-supplied input: every invalid configuration, malformed
//! checkpoint, or shape mismatch maps to a [`TrainError`] variant the
//! caller can match on.  The variants carry enough structure for
//! programmatic handling (which config field, which dimensions) while
//! `Display` renders an actionable message; `std::error::Error` is
//! implemented so `?` converts into `anyhow::Error` at the CLI layer.

use std::fmt;

/// Everything that can go wrong constructing or driving a training
/// session or a serving [`crate::serve::Predictor`].
#[derive(Clone, Debug, PartialEq)]
pub enum TrainError {
    /// A [`crate::config::TrainConfig`] invariant is violated.
    InvalidConfig {
        /// The offending field (`"lambda"`, `"gamma"`, `"budget"`,
        /// `"mergees"`, `"epochs"`, `"folds"`, ...).
        field: &'static str,
        message: String,
    },
    /// A `c = ...` cost parameter was set (TOML/CLI convenience) but
    /// never resolved against the training-set size.  λ = 1/(n·C)
    /// needs n; call [`crate::config::TrainConfig::resolve_c`] first.
    UnresolvedCost { c: f64 },
    /// The training (or evaluation) dataset holds no samples.
    EmptyDataset,
    /// A sample or query row has the wrong feature count.
    DimMismatch { expected: usize, got: usize },
    /// A checkpoint (or model) blob failed to parse.
    Checkpoint(String),
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::InvalidConfig { field, message } => {
                write!(f, "invalid config: {field}: {message}")
            }
            TrainError::UnresolvedCost { c } => write!(
                f,
                "cost parameter C = {c} is unresolved; λ = 1/(n·C) needs the \
                 training-set size — call TrainConfig::resolve_c(n) before training"
            ),
            TrainError::EmptyDataset => write!(f, "empty dataset"),
            TrainError::DimMismatch { expected, got } => {
                write!(f, "feature-dimension mismatch: expected {expected}, got {got}")
            }
            TrainError::Checkpoint(msg) => write!(f, "bad checkpoint: {msg}"),
        }
    }
}

impl std::error::Error for TrainError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_field() {
        let e = TrainError::InvalidConfig { field: "gamma", message: "must be positive".into() };
        let s = e.to_string();
        assert!(s.contains("gamma") && s.contains("positive"), "{s}");
    }

    #[test]
    fn unresolved_cost_tells_the_fix() {
        let s = TrainError::UnresolvedCost { c: 8.0 }.to_string();
        assert!(s.contains("resolve_c"), "{s}");
        assert!(s.contains('8'), "{s}");
    }

    #[test]
    fn converts_into_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(TrainError::EmptyDataset)?
        }
        let err = inner().unwrap_err();
        assert!(format!("{err}").contains("empty dataset"));
    }

    #[test]
    fn dim_mismatch_carries_both_sides() {
        let e = TrainError::DimMismatch { expected: 22, got: 7 };
        assert_eq!(e, TrainError::DimMismatch { expected: 22, got: 7 });
        assert!(e.to_string().contains("22"));
    }
}

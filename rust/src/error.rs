//! Typed errors for the training / serving entry paths.
//!
//! The library's public surface (session construction, stepping,
//! checkpoint parsing, batched prediction) must never panic on
//! user-supplied input: every invalid configuration, malformed
//! checkpoint, or shape mismatch maps to a [`TrainError`] variant the
//! caller can match on.  The variants carry enough structure for
//! programmatic handling (which config field, which dimensions) while
//! `Display` renders an actionable message; `std::error::Error` is
//! implemented so `?` converts into `anyhow::Error` at the CLI layer.

use std::fmt;

/// Everything that can go wrong constructing or driving a training
/// session or a serving [`crate::serve::Predictor`].
#[derive(Clone, Debug, PartialEq)]
pub enum TrainError {
    /// A [`crate::config::TrainConfig`] invariant is violated.
    InvalidConfig {
        /// The offending field (`"lambda"`, `"gamma"`, `"budget"`,
        /// `"mergees"`, `"epochs"`, `"folds"`, ...).
        field: &'static str,
        message: String,
    },
    /// A `c = ...` cost parameter was set (TOML/CLI convenience) but
    /// never resolved against the training-set size.  λ = 1/(n·C)
    /// needs n; call [`crate::config::TrainConfig::resolve_c`] first.
    UnresolvedCost { c: f64 },
    /// The training (or evaluation) dataset holds no samples.
    EmptyDataset,
    /// A sample or query row has the wrong feature count.
    DimMismatch { expected: usize, got: usize },
    /// A checkpoint (or model) blob failed to parse.
    Checkpoint(String),
    /// A checkpoint file on disk failed its durable-layer checksum or
    /// its structural parse, and no usable `.prev` generation could
    /// stand in.  Produced by [`crate::solver::load_checkpoint`];
    /// unlike [`TrainError::Checkpoint`] it names the file, the failing
    /// section, the byte offset, and whether a `.prev` fallback existed.
    CorruptCheckpoint {
        /// The checkpoint path as given.
        path: String,
        /// Failing section: `"io"`, `"footer"`, `"payload"`, `"body"`.
        section: String,
        /// Byte offset within the file where the check failed
        /// (0 when the failure has no position, e.g. a missing file).
        offset: u64,
        /// Whether a `<path>.prev` generation was present (it too
        /// failed, or the error would not have been raised).
        prev_exists: bool,
        /// Human-readable cause, including the `.prev` failure when
        /// the fallback was tried.
        detail: String,
    },
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::InvalidConfig { field, message } => {
                write!(f, "invalid config: {field}: {message}")
            }
            TrainError::UnresolvedCost { c } => write!(
                f,
                "cost parameter C = {c} is unresolved; λ = 1/(n·C) needs the \
                 training-set size — call TrainConfig::resolve_c(n) before training"
            ),
            TrainError::EmptyDataset => write!(f, "empty dataset"),
            TrainError::DimMismatch { expected, got } => {
                write!(f, "feature-dimension mismatch: expected {expected}, got {got}")
            }
            TrainError::Checkpoint(msg) => write!(f, "bad checkpoint: {msg}"),
            TrainError::CorruptCheckpoint { path, section, offset, prev_exists, detail } => {
                let fallback = if *prev_exists {
                    "a .prev generation exists but also failed"
                } else {
                    "no .prev fallback generation is present"
                };
                write!(
                    f,
                    "corrupt checkpoint {path}: {section} at byte {offset}: {detail} ({fallback})"
                )
            }
        }
    }
}

impl std::error::Error for TrainError {}

/// Everything that can go wrong serving a request through the
/// [`crate::serve`] subsystem.  Serving is per-request fallible: a
/// malformed line, an over-quota queue, or a mismatched query dimension
/// fails *that request* with a variant the server renders as an `err`
/// reply — the process, the connection, and every other queued request
/// keep going.  Nothing in the serving path panics on user input.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeError {
    /// A request (or route arm, or `swap-model`) named a model the
    /// registry does not hold.
    UnknownModel(String),
    /// The pending queue is at capacity and the engine runs
    /// [`crate::serve::ShedPolicy::Reject`]: the *new* request is
    /// refused up front.
    QueueFull { limit: usize },
    /// The pending queue was at capacity under
    /// [`crate::serve::ShedPolicy::Oldest`] and this (oldest) request
    /// was dropped to admit a newer one.
    Shed,
    /// A protocol line failed to parse (unknown command, bad float,
    /// missing argument).  Carries the reason verbatim for the `err`
    /// reply.
    BadRequest(String),
    /// A route table was rejected (empty, zero total weight, or an arm
    /// naming an absent model).
    BadRoute(String),
    /// Model validation / query shape errors, forwarded from the
    /// training-side typed errors (e.g. [`TrainError::DimMismatch`]).
    Model(TrainError),
    /// Socket-level failure (bind, accept, read, write).  String-typed:
    /// `std::io::Error` is neither `Clone` nor `PartialEq`, and serving
    /// only ever reports these, never matches on the kind.
    Io(String),
    /// The request sat in the engine queue past the configured
    /// per-request deadline and was expired at flush time instead of
    /// occupying a batch row.
    Deadline { waited_ms: u64, deadline_ms: u64 },
    /// The connection (line protocol) or request (HTTP) failed the
    /// shared-secret auth check configured by
    /// [`crate::serve::ServeOptions`]`::auth_token`: missing, stale,
    /// or wrong credential.  The connection closes after the reply —
    /// an unauthenticated peer never reaches the engine.
    Unauthorized,
    /// `swap-model` / `activate` offered a model whose feature
    /// dimension differs from the version currently serving under the
    /// same name.  Rejected at swap time so queued requests validated
    /// against the old dimension are never flushed through the new
    /// model; distinct from [`ServeError::Model`] wrapping
    /// [`TrainError::DimMismatch`], which is the per-request shape
    /// check.
    DimMismatch { name: String, serving: usize, incoming: usize },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownModel(name) => write!(f, "unknown model {name:?}"),
            ServeError::QueueFull { limit } => {
                write!(f, "queue full ({limit} pending); request rejected")
            }
            ServeError::Shed => write!(f, "request shed: queue overflowed while waiting"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::BadRoute(msg) => write!(f, "bad route: {msg}"),
            ServeError::Model(e) => write!(f, "model: {e}"),
            ServeError::Io(msg) => write!(f, "io: {msg}"),
            ServeError::Unauthorized => {
                write!(f, "unauthorized: a valid auth token is required")
            }
            ServeError::Deadline { waited_ms, deadline_ms } => write!(
                f,
                "deadline exceeded: waited {waited_ms}ms against a {deadline_ms}ms deadline"
            ),
            ServeError::DimMismatch { name, serving, incoming } => write!(
                f,
                "swap rejected for {name:?}: serving dimension {serving}, \
                 incoming model has {incoming}"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

impl From<TrainError> for ServeError {
    fn from(e: TrainError) -> Self {
        ServeError::Model(e)
    }
}

impl From<std::io::Error> for ServeError {
    fn from(e: std::io::Error) -> Self {
        ServeError::Io(e.to_string())
    }
}

/// Everything that can go wrong packaging, distributing, or activating
/// a versioned model artifact through the [`crate::fleet`] subsystem.
/// Like the other error families this is fully typed — loads refuse
/// mismatched checksums and dimensions with a variant the caller can
/// match on, never a panic or a silent acceptance.
#[derive(Clone, Debug, PartialEq)]
pub enum FleetError {
    /// The underlying filesystem or socket operation failed (or an
    /// `io` fault was injected at `artifact.read`).
    Io { path: String, detail: String },
    /// The artifact file failed the durable layer's whole-file
    /// checksum or structure check (torn write, bit rot).
    Corrupt { path: String, section: String, offset: u64, detail: String },
    /// The manifest text failed to parse (bad header, missing field,
    /// malformed section line).
    Manifest { detail: String },
    /// A per-section checksum in the manifest does not match the bytes
    /// actually carried: the bundle was tampered with or spliced.
    SectionChecksum { section: String, expected: u64, got: u64 },
    /// The manifest's declared shape disagrees with the embedded model
    /// (defense against a manifest from one model pasted onto another).
    DimMismatch { manifest: usize, model: usize },
    /// The embedded model text parsed but failed model validation;
    /// carries the rendered cause.
    Model(String),
    /// A replica endpoint refused or dropped a control-plane exchange.
    Replica { endpoint: String, detail: String },
    /// No replica could answer (all dead, or the set is empty).
    NoReplica { detail: String },
    /// A version-level refusal: unknown version at activate, no
    /// last-good generation at rollback, or a stale acknowledgement.
    Version { detail: String },
}

impl fmt::Display for FleetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FleetError::Io { path, detail } => write!(f, "fleet io on {path}: {detail}"),
            FleetError::Corrupt { path, section, offset, detail } => {
                write!(f, "corrupt artifact {path}: {section} at byte {offset}: {detail}")
            }
            FleetError::Manifest { detail } => write!(f, "bad artifact manifest: {detail}"),
            FleetError::SectionChecksum { section, expected, got } => write!(
                f,
                "artifact section {section:?} checksum mismatch: \
                 manifest fnv={expected:016x}, computed {got:016x}"
            ),
            FleetError::DimMismatch { manifest, model } => write!(
                f,
                "artifact dimension mismatch: manifest declares {manifest}, \
                 embedded model has {model}"
            ),
            FleetError::Model(detail) => write!(f, "artifact model rejected: {detail}"),
            FleetError::Replica { endpoint, detail } => {
                write!(f, "replica {endpoint}: {detail}")
            }
            FleetError::NoReplica { detail } => write!(f, "no replica available: {detail}"),
            FleetError::Version { detail } => write!(f, "version error: {detail}"),
        }
    }
}

impl std::error::Error for FleetError {}

impl From<crate::util::durable::DurableError> for FleetError {
    fn from(e: crate::util::durable::DurableError) -> Self {
        use crate::util::durable::DurableError as D;
        match e {
            D::Io { path, detail } => FleetError::Io { path, detail },
            D::Corrupt { path, section, offset, detail } => {
                FleetError::Corrupt { path, section: section.to_string(), offset, detail }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_field() {
        let e = TrainError::InvalidConfig { field: "gamma", message: "must be positive".into() };
        let s = e.to_string();
        assert!(s.contains("gamma") && s.contains("positive"), "{s}");
    }

    #[test]
    fn unresolved_cost_tells_the_fix() {
        let s = TrainError::UnresolvedCost { c: 8.0 }.to_string();
        assert!(s.contains("resolve_c"), "{s}");
        assert!(s.contains('8'), "{s}");
    }

    #[test]
    fn converts_into_anyhow() {
        fn inner() -> anyhow::Result<()> {
            Err(TrainError::EmptyDataset)?
        }
        let err = inner().unwrap_err();
        assert!(format!("{err}").contains("empty dataset"));
    }

    #[test]
    fn dim_mismatch_carries_both_sides() {
        let e = TrainError::DimMismatch { expected: 22, got: 7 };
        assert_eq!(e, TrainError::DimMismatch { expected: 22, got: 7 });
        assert!(e.to_string().contains("22"));
    }

    #[test]
    fn serve_errors_render_actionably() {
        let e = ServeError::QueueFull { limit: 64 };
        assert!(e.to_string().contains("64"), "{e}");
        let e = ServeError::UnknownModel("champion".into());
        assert!(e.to_string().contains("champion"), "{e}");
        let e: ServeError = TrainError::DimMismatch { expected: 3, got: 5 }.into();
        assert_eq!(e, ServeError::Model(TrainError::DimMismatch { expected: 3, got: 5 }));
        assert!(e.to_string().contains("mismatch"), "{e}");
        let e = ServeError::Deadline { waited_ms: 120, deadline_ms: 50 };
        let s = e.to_string();
        assert!(s.contains("120") && s.contains("50"), "{s}");
        let s = ServeError::Unauthorized.to_string();
        assert!(s.starts_with("unauthorized"), "{s}");
        assert!(s.contains("auth token"), "{s}");
    }

    #[test]
    fn corrupt_checkpoint_names_section_offset_and_fallback() {
        let e = TrainError::CorruptCheckpoint {
            path: "ck.txt".into(),
            section: "payload".into(),
            offset: 412,
            prev_exists: false,
            detail: "checksum mismatch".into(),
        };
        let s = e.to_string();
        assert!(s.contains("ck.txt"), "{s}");
        assert!(s.contains("payload"), "{s}");
        assert!(s.contains("412"), "{s}");
        assert!(s.contains("no .prev fallback"), "{s}");
        let e = TrainError::CorruptCheckpoint {
            path: "ck.txt".into(),
            section: "body".into(),
            offset: 9,
            prev_exists: true,
            detail: "line 2: bad rng".into(),
        };
        assert!(e.to_string().contains("also failed"), "{e}");
    }

    #[test]
    fn swap_dim_mismatch_is_distinct_from_request_dim_mismatch() {
        let swap = ServeError::DimMismatch { name: "champ".into(), serving: 3, incoming: 5 };
        let req: ServeError = TrainError::DimMismatch { expected: 3, got: 5 }.into();
        assert_ne!(swap, req);
        let s = swap.to_string();
        assert!(s.contains("champ") && s.contains('3') && s.contains('5'), "{s}");
    }

    #[test]
    fn fleet_errors_render_actionably() {
        let e = FleetError::SectionChecksum { section: "model".into(), expected: 0xab, got: 0xcd };
        let s = e.to_string();
        assert!(s.contains("model") && s.contains("00000000000000ab"), "{s}");
        let e = FleetError::DimMismatch { manifest: 22, model: 7 };
        assert!(e.to_string().contains("22"), "{e}");
        let e = FleetError::Replica { endpoint: "127.0.0.1:9301".into(), detail: "refused".into() };
        assert!(e.to_string().contains("9301"), "{e}");
        let e = FleetError::Version { detail: "no .prev generation".into() };
        assert!(e.to_string().contains(".prev"), "{e}");
    }

    #[test]
    fn fleet_error_wraps_durable_error() {
        use crate::util::durable::DurableError;
        let e: FleetError = DurableError::Corrupt {
            path: "m.artifact".into(),
            section: "payload",
            offset: 12,
            detail: "checksum mismatch".into(),
        }
        .into();
        assert!(matches!(e, FleetError::Corrupt { offset: 12, .. }), "{e:?}");
        let e: FleetError =
            DurableError::Io { path: "m.artifact".into(), detail: "gone".into() }.into();
        assert!(matches!(e, FleetError::Io { .. }), "{e:?}");
    }
}

//! Stub [`XlaBackend`] for builds without the `xla` cargo feature.
//!
//! The default build has no external native deps (satellite of the
//! hot-path PR: the PJRT path needs the `xla` crate, which is optional),
//! so this type keeps the API surface — benches, tests, and
//! `build_backend` compile unchanged — while every constructor returns
//! an error.  Code that probes with `XlaBackend::new(..).ok()` degrades
//! exactly as if the AOT artifacts were missing.

use super::{Backend, MergeScores};
use crate::data::DenseMatrix;
use crate::model::SvStore;
use anyhow::{bail, Result};
use std::path::Path;

/// Unconstructible placeholder for the PJRT backend.
pub struct XlaBackend {
    _never: std::convert::Infallible,
}

impl XlaBackend {
    pub fn new(_dir: &Path) -> Result<Self> {
        bail!(
            "mmbsgd was built without the `xla` cargo feature; \
             rebuild with `--features xla` to enable the PJRT backend"
        )
    }

    pub fn from_default_dir() -> Result<Self> {
        Self::new(Path::new("artifacts"))
    }

    pub fn registry(&self) -> &super::artifacts::ArtifactRegistry {
        match self._never {}
    }

    pub fn try_merge_scores(
        &mut self,
        _svs: &SvStore,
        _gamma: f64,
        _i: usize,
    ) -> Result<MergeScores> {
        match self._never {}
    }

    pub fn try_merge_gd(
        &mut self,
        _points: &[(&[f32], f64)],
        _gamma: f64,
    ) -> Result<(Vec<f32>, f64, f64)> {
        match self._never {}
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn margins(&mut self, _svs: &SvStore, _gamma: f64, _queries: &DenseMatrix) -> Vec<f64> {
        match self._never {}
    }

    fn margin1(&mut self, _svs: &SvStore, _gamma: f64, _x: &[f32]) -> f64 {
        match self._never {}
    }

    fn merge_scores(&mut self, _svs: &SvStore, _gamma: f64, _i: usize) -> MergeScores {
        match self._never {}
    }

    fn merge_gd(&mut self, _points: &[(&[f32], f64)], _gamma: f64) -> (Vec<f32>, f64, f64) {
        match self._never {}
    }
}

//! AOT artifact manifest index — shared by the PJRT backend and the
//! CLI `artifacts` subcommand.  Pure fs + JSON: compiled regardless of
//! the `xla` feature so artifact tooling works in every build.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One artifact from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub entry: String,
    pub b_pad: usize,
    pub d_pad: usize,
    pub nb: usize,
    pub m_pad: usize,
}

/// Index over `artifacts/manifest.json`.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl ArtifactRegistry {
    /// Read and index the manifest.  Files written by [`Self::save`]
    /// carry a durable checksum footer which is verified here;
    /// tool-written manifests without one load unchecked (the JSON
    /// parse is the structural backstop).  The read goes through
    /// [`crate::util::durable::read_artifact_verified`], sharing the
    /// `artifact.read` fault-injection site with fleet bundle loads.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let payload = crate::util::durable::read_artifact_verified(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let json = crate::util::json::Json::parse(&payload)
            .map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let arr = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest lacks 'artifacts' array")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_usize =
                |k: &str| a.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            artifacts.push(ArtifactInfo {
                name: a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("artifact lacks name")?
                    .to_string(),
                file: dir.join(
                    a.get("file")
                        .and_then(|v| v.as_str())
                        .context("artifact lacks file")?,
                ),
                entry: a
                    .get("entry")
                    .and_then(|v| v.as_str())
                    .context("artifact lacks entry")?
                    .to_string(),
                b_pad: get_usize("b_pad"),
                d_pad: get_usize("d_pad"),
                nb: get_usize("nb"),
                m_pad: get_usize("m_pad"),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts — run `make artifacts`");
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    /// Smallest margins variant with b_pad >= b, d_pad >= d, batch nb.
    pub fn find_margins(&self, b: usize, d: usize, nb: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.entry == "margins" && a.b_pad >= b && a.d_pad >= d && a.nb == nb
            })
            .min_by_key(|a| (a.b_pad, a.d_pad))
    }

    /// Smallest merge_scores variant with b_pad >= b, d_pad >= d.
    pub fn find_merge_scores(&self, b: usize, d: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == "merge_scores" && a.b_pad >= b && a.d_pad >= d)
            .min_by_key(|a| (a.b_pad, a.d_pad))
    }

    /// Smallest merge_gd variant with d_pad >= d.
    pub fn find_merge_gd(&self, d: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == "merge_gd" && a.d_pad >= d)
            .min_by_key(|a| a.d_pad)
    }

    /// Default artifact directory: `$MMBSGD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("MMBSGD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Write `manifest.json` through the durable layer (atomic replace,
    /// checksum footer, `.prev` generation).  `file` entries are
    /// emitted relative to the registry directory, matching what
    /// [`Self::load`] joins back on.
    pub fn save(&self) -> Result<()> {
        use crate::util::json::{obj, to_string, Json};
        let arr = self
            .artifacts
            .iter()
            .map(|a| {
                let file = a
                    .file
                    .strip_prefix(&self.dir)
                    .unwrap_or(&a.file)
                    .to_string_lossy()
                    .into_owned();
                obj(vec![
                    ("name", Json::Str(a.name.clone())),
                    ("file", Json::Str(file)),
                    ("entry", Json::Str(a.entry.clone())),
                    ("b_pad", Json::Num(a.b_pad as f64)),
                    ("d_pad", Json::Num(a.d_pad as f64)),
                    ("nb", Json::Num(a.nb as f64)),
                    ("m_pad", Json::Num(a.m_pad as f64)),
                ])
            })
            .collect();
        let doc = obj(vec![("artifacts", Json::Arr(arr))]);
        let mut text = to_string(&doc);
        text.push('\n');
        let path = self.dir.join("manifest.json");
        crate::util::durable::write_atomic(&path, &text)
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrips_through_durable_save() {
        let dir = std::env::temp_dir()
            .join(format!("mmbsgd_artifacts_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg = ArtifactRegistry {
            dir: dir.clone(),
            artifacts: vec![ArtifactInfo {
                name: "margins_b64".into(),
                file: dir.join("margins_b64.pb"),
                entry: "margins".into(),
                b_pad: 64,
                d_pad: 32,
                nb: 8,
                m_pad: 0,
            }],
        };
        reg.save().unwrap();
        let back = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(back.artifacts.len(), 1);
        assert_eq!(back.artifacts[0].name, "margins_b64");
        assert_eq!(back.artifacts[0].b_pad, 64);
        assert_eq!(back.artifacts[0].file, dir.join("margins_b64.pb"));
        // a flipped byte is caught by the footer, not the JSON parser
        let p = dir.join("manifest.json");
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, text.replacen("64", "65", 1)).unwrap();
        let err = ArtifactRegistry::load(&dir).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("length"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

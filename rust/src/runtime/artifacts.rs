//! AOT artifact manifest index — shared by the PJRT backend and the
//! CLI `artifacts` subcommand.  Pure fs + JSON: compiled regardless of
//! the `xla` feature so artifact tooling works in every build.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One artifact from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub entry: String,
    pub b_pad: usize,
    pub d_pad: usize,
    pub nb: usize,
    pub m_pad: usize,
}

/// Index over `artifacts/manifest.json`.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl ArtifactRegistry {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let json = crate::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let arr = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest lacks 'artifacts' array")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for a in arr {
            let get_usize =
                |k: &str| a.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            artifacts.push(ArtifactInfo {
                name: a
                    .get("name")
                    .and_then(|v| v.as_str())
                    .context("artifact lacks name")?
                    .to_string(),
                file: dir.join(
                    a.get("file")
                        .and_then(|v| v.as_str())
                        .context("artifact lacks file")?,
                ),
                entry: a
                    .get("entry")
                    .and_then(|v| v.as_str())
                    .context("artifact lacks entry")?
                    .to_string(),
                b_pad: get_usize("b_pad"),
                d_pad: get_usize("d_pad"),
                nb: get_usize("nb"),
                m_pad: get_usize("m_pad"),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts — run `make artifacts`");
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    /// Smallest margins variant with b_pad >= b, d_pad >= d, batch nb.
    pub fn find_margins(&self, b: usize, d: usize, nb: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.entry == "margins" && a.b_pad >= b && a.d_pad >= d && a.nb == nb
            })
            .min_by_key(|a| (a.b_pad, a.d_pad))
    }

    /// Smallest merge_scores variant with b_pad >= b, d_pad >= d.
    pub fn find_merge_scores(&self, b: usize, d: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == "merge_scores" && a.b_pad >= b && a.d_pad >= d)
            .min_by_key(|a| (a.b_pad, a.d_pad))
    }

    /// Smallest merge_gd variant with d_pad >= d.
    pub fn find_merge_gd(&self, d: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == "merge_gd" && a.d_pad >= d)
            .min_by_key(|a| a.d_pad)
    }

    /// Default artifact directory: `$MMBSGD_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("MMBSGD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

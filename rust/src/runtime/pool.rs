//! Deterministic scoped worker pool for the tiled batch hot paths.
//!
//! Design constraints (EXPERIMENTS.md §Perf):
//!
//! * **No new dependencies.**  Workers are `std::thread::scope` threads
//!   spawned per call, with the caller running the first chunk itself
//!   (N-way parallelism costs N−1 spawns); for the batch shapes the
//!   tile engine handles (hundreds of queries × hundreds of SVs) the
//!   ~10 µs spawn cost is noise next to the sharded compute, and scoped
//!   threads let jobs borrow the store and output buffers directly — no
//!   channels, no `Arc`, no shared mutable state.
//! * **Bit-determinism for every thread count.**  Work is split by
//!   [`partition`] into contiguous chunks whose boundaries depend only
//!   on `(len, threads, min_chunk)` — never on timing — and every
//!   output element is written by exactly one worker using the same
//!   sequential accumulation order the single-threaded path uses.
//!   Reductions are therefore fixed-order by construction: results are
//!   bit-identical for `threads = 1, 2, 4, ...` (enforced by
//!   `rust/tests/tile_engine.rs`).
//!
//! The pool is deliberately dumb: no work stealing (it would make the
//! chunk→worker mapping timing-dependent — harmless for disjoint
//! writes, but a persistent-pool future could cache per-worker scratch,
//! and fixed chunks keep that deterministic too).

use std::ops::Range;

/// A fixed-width scoped worker pool; see the [module docs](self).
#[derive(Clone, Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of `threads` workers (0 is clamped to 1).  `threads = 1`
    /// never spawns: all work runs inline on the caller's thread.
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// The single-threaded (inline) pool.
    pub fn single() -> Self {
        Self::new(1)
    }

    /// Worker count in effect.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run one closure call per job — the first on the calling thread
    /// (which would otherwise idle inside the scope), the rest each on
    /// their own scoped worker; all inline when the pool is
    /// single-threaded or there is at most one job.  Jobs own their
    /// output slices, so workers never share mutable state; job
    /// construction order is the deterministic chunk order of
    /// [`partition`].
    pub fn run_jobs<J, F>(&self, jobs: Vec<J>, f: F)
    where
        J: Send,
        F: Fn(J) + Sync,
    {
        if self.threads == 1 || jobs.len() <= 1 {
            for job in jobs {
                f(job);
            }
            return;
        }
        let f = &f;
        std::thread::scope(|s| {
            let mut jobs = jobs.into_iter();
            let mine = jobs.next();
            for job in jobs {
                s.spawn(move || f(job));
            }
            // The caller works its own chunk concurrently with the
            // workers: one fewer spawn per batch call, same total
            // parallelism (outputs are disjoint, so order is moot).
            if let Some(job) = mine {
                f(job);
            }
        });
    }

    /// Shard `data` into at most `threads` contiguous chunks of at
    /// least `min_chunk` items and run `f(start_index, chunk)` on each.
    /// The partition depends only on `(data.len(), threads, min_chunk)`,
    /// so the element→worker mapping is identical on every run.
    pub fn run_chunks<T, F>(&self, data: &mut [T], min_chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if data.is_empty() {
            return;
        }
        let ranges = partition(data.len(), self.threads, min_chunk);
        if ranges.len() <= 1 {
            f(0, data);
            return;
        }
        let mut jobs = Vec::with_capacity(ranges.len());
        let mut rest = data;
        for r in ranges {
            let (head, tail) = rest.split_at_mut(r.end - r.start);
            jobs.push((r.start, head));
            rest = tail;
        }
        self.run_jobs(jobs, |(start, chunk)| f(start, chunk));
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::single()
    }
}

/// Split `0..n` into at most `max_parts` contiguous ranges of at least
/// `min_chunk` items (a chunk can be shorter than `min_chunk` only
/// when `n` itself is, in which case there is exactly one chunk).
/// Earlier ranges take the remainder, so sizes differ by at most one
/// item.  Pure function of its arguments — the determinism anchor of
/// the whole pool.
pub fn partition(n: usize, max_parts: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let min_chunk = min_chunk.max(1);
    // Floor division: only as many parts as can each hold a full
    // `min_chunk` — ceiling division here would hand out sub-minimum
    // chunks (n=100, min=32 must give 3 chunks of 34/33/33, not 4×25)
    // and defeat the oversharding guard.
    let parts = max_parts.max(1).min((n / min_chunk).max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly_once() {
        let cases = [
            (0usize, 4usize, 8usize),
            (1, 4, 8),
            (7, 3, 1),
            (100, 7, 1),
            (513, 4, 32),
            (64, 64, 32),
        ];
        for (n, parts, min_chunk) in cases {
            let ranges = partition(n, parts, min_chunk);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap/overlap in {ranges:?}");
                assert!(r.end > r.start, "empty range in {ranges:?}");
                next = r.end;
            }
            assert_eq!(next, n, "partition of {n} into {ranges:?} incomplete");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn partition_respects_min_chunk() {
        let ranges = partition(100, 16, 32);
        // 100 items / 32-minimum => at most 3 chunks
        assert!(ranges.len() <= 3, "{ranges:?}");
        assert!(ranges.iter().all(|r| r.end - r.start >= 32), "{ranges:?}");
        // below a single min_chunk everything collapses to one part
        let ranges = partition(7, 16, 32);
        assert_eq!(ranges, vec![0..7]);
        // every chunk >= min_chunk across a spread of shapes
        for (n, parts, min_chunk) in [(127usize, 16usize, 32usize), (513, 8, 64), (96, 3, 32)] {
            let ranges = partition(n, parts, min_chunk);
            assert!(
                ranges.iter().all(|r| r.end - r.start >= min_chunk),
                "partition({n}, {parts}, {min_chunk}) = {ranges:?}"
            );
        }
    }

    #[test]
    fn partition_is_deterministic() {
        assert_eq!(partition(513, 4, 32), partition(513, 4, 32));
    }

    #[test]
    fn run_chunks_writes_every_slot_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0u32; 257];
            pool.run_chunks(&mut out, 8, |start, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v += (start + k) as u32 + 1;
                }
            });
            for (k, &v) in out.iter().enumerate() {
                assert_eq!(v, k as u32 + 1, "slot {k} written {v} times/wrong");
            }
        }
    }

    #[test]
    fn run_jobs_inline_when_single() {
        // threads = 1 must not spawn: a !Send-unfriendly sequential
        // side effect (order-sensitive accumulation) stays in order.
        let pool = WorkerPool::single();
        let order = std::sync::Mutex::new(Vec::new());
        pool.run_jobs(vec![1, 2, 3], |j| order.lock().unwrap().push(j));
        assert_eq!(*order.lock().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
    }
}

//! Persistent, deterministic worker pool for the tiled batch hot paths.
//!
//! PR 3's pool spawned `std::thread::scope` workers on **every** batch
//! call — fine when a pass shards hundreds of queries, but serving
//! micro-batches (and the per-event merge scoring cadence) pay the
//! ~tens-of-µs spawn+join tax once per pass.  This rebuild keeps the
//! workers alive: `threads − 1` OS threads are created **once** at pool
//! construction, park on a condvar between calls, and a batch hand-off
//! costs one mutex/notify round-trip instead of thread creation.
//!
//! Design constraints (EXPERIMENTS.md §Perf):
//!
//! * **No new dependencies.**  `std::sync::{Mutex, Condvar}` only.
//!   Jobs still borrow the store and output buffers directly (no
//!   channels of owned data): a batch is published to the workers as a
//!   type-erased reference and `run_jobs` does not return until every
//!   job has finished *and* every worker has exited the batch, so the
//!   borrow never outlives its stack frame (see the safety notes on
//!   [`WorkerPool::run_jobs`]).
//! * **Bit-determinism for every thread count.**  Work is split by
//!   [`partition`] into contiguous chunks whose boundaries depend only
//!   on `(len, threads, min_chunk)` — never on timing — and every
//!   output element is written by exactly one job using the same
//!   sequential accumulation order the single-threaded path uses.
//!   *Which worker* runs a job is timing-dependent (workers claim jobs
//!   from a shared counter), but jobs own disjoint outputs, so the
//!   claim order is unobservable in the results — bit-identical for
//!   `threads = 1, 2, 4, ...` (enforced by `rust/tests/tile_engine.rs`
//!   and `rust/tests/simd_parity.rs`).
//! * **Accountable reuse.**  Every OS-thread creation increments the
//!   pool's [`WorkerPool::spawn_events`] counter; steady-state batch
//!   passes must leave it flat (`rust/tests/serve_engine.rs` pins the
//!   serving path with a `pool_reuse` assertion).
//!
//! Shutdown is clean: dropping the last clone of a pool flags the
//! workers, wakes them, and joins every handle.  A panicking job is
//! caught on the worker, the batch is completed, and the first panic
//! payload resumes on the caller — same observable behaviour as the
//! scoped pool (which propagated through scope join).

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

std::thread_local! {
    /// True while a pool job closure runs on this thread.  A nested
    /// `run_jobs` from inside a job would deadlock the hand-off
    /// protocol (the publisher holds `call_lock` for the whole batch
    /// and waits for this very thread to finish), so nested calls
    /// degrade to inline execution instead — the reentrancy tolerance
    /// the scoped pool had for free, kept loud-failure-proof.
    static IN_POOL_JOB: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Outcome of one job-claim attempt on a published batch.
enum RunStatus {
    /// Claimed and ran a job (there may be more).
    Ran,
    /// No unclaimed jobs remain; the claimer must stop touching the
    /// batch.
    Exhausted,
}

/// Type-erased view of one in-flight `run_jobs` batch.  `Sync` bound:
/// the caller and every worker claim jobs through a shared reference.
trait BatchRun: Sync {
    fn run_one(&self) -> RunStatus;
    fn jobs_done(&self) -> bool;
}

/// The concrete batch: jobs to claim + the closure to run them with.
/// Lives on the `run_jobs` caller's stack; workers reach it through a
/// lifetime-erased reference that provably never outlives the call.
struct Batch<'f, J, F: Fn(J) + Sync> {
    jobs: Vec<Mutex<Option<J>>>,
    /// Next unclaimed job index (claim = `fetch_add`).
    next: AtomicUsize,
    /// Jobs fully executed (the caller's completion predicate).
    done: AtomicUsize,
    /// First panic payload from any job, re-raised on the caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    f: &'f F,
}

impl<J: Send, F: Fn(J) + Sync> BatchRun for Batch<'_, J, F> {
    fn run_one(&self) -> RunStatus {
        let i = self.next.fetch_add(1, Ordering::SeqCst);
        if i >= self.jobs.len() {
            return RunStatus::Exhausted;
        }
        let job = self.jobs[i].lock().expect("job slot poisoned").take();
        if let Some(job) = job {
            // Catch so a panicking job can neither deadlock the caller
            // (worker dying before the done-count reaches the total)
            // nor unwind the caller mid-batch with the erased
            // reference still published.  The IN_POOL_JOB flag makes a
            // nested `run_jobs` from inside the closure run inline
            // instead of deadlocking on the batch hand-off.
            IN_POOL_JOB.with(|f| f.set(true));
            let result = catch_unwind(AssertUnwindSafe(|| {
                // Injection site `pool.job`: proves this catch_unwind
                // actually contains a panicking job (fault-inject only).
                crate::util::fault::fire_panic(crate::util::fault::site::POOL_JOB);
                (self.f)(job)
            }));
            IN_POOL_JOB.with(|f| f.set(false));
            if let Err(payload) = result {
                let mut slot = self.panic.lock().expect("panic slot poisoned");
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
        self.done.fetch_add(1, Ordering::SeqCst);
        RunStatus::Ran
    }

    fn jobs_done(&self) -> bool {
        self.done.load(Ordering::SeqCst) == self.jobs.len()
    }
}

/// Condvar-protected hand-off slot between `run_jobs` and the parked
/// workers.
struct PoolState {
    /// The published batch (`None` between calls).  The reference is
    /// lifetime-erased; see the safety notes on
    /// [`WorkerPool::run_jobs`].
    batch: Option<&'static dyn BatchRun>,
    /// Bumped once per published batch, so a worker that already
    /// drained the current batch parks instead of spinning on it.
    epoch: u64,
    /// Workers currently holding a reference into the current batch.
    /// The publisher may not retire the batch until this returns to 0.
    active: usize,
    shutdown: bool,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Workers park here waiting for a new batch (or shutdown).
    work_cv: Condvar,
    /// The publisher parks here waiting for batch completion.
    done_cv: Condvar,
}

/// The spawned workers + shared state; dropping the last pool clone
/// drops this, which shuts the workers down and joins them.
struct Workers {
    inner: Arc<PoolInner>,
    /// Serializes concurrent `run_jobs` calls on clones of one pool
    /// (the hand-off slot holds one batch at a time).
    call_lock: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Drop for Workers {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().expect("pool state poisoned");
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: Arc<PoolInner>) {
    let mut seen_epoch = 0u64;
    loop {
        let (batch, epoch) = {
            let mut st = inner.state.lock().expect("pool state poisoned");
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(b) = st.batch {
                    if st.epoch != seen_epoch {
                        st.active += 1;
                        break (b, st.epoch);
                    }
                }
                st = inner.work_cv.wait(st).expect("pool state poisoned");
            }
        };
        seen_epoch = epoch;
        // The batch reference is valid for this whole claim loop: the
        // publisher blocks until `active` returns to 0.
        while let RunStatus::Ran = batch.run_one() {}
        // From here on the batch must not be touched — deregister and
        // wake the publisher (it waits for done jobs AND active == 0).
        let mut st = inner.state.lock().expect("pool state poisoned");
        st.active -= 1;
        inner.done_cv.notify_all();
    }
}

/// A fixed-width persistent worker pool; see the [module docs](self).
/// Cloning shares the same parked workers (and the spawn counter);
/// the workers shut down when the last clone drops.
pub struct WorkerPool {
    threads: usize,
    /// `None` when `threads == 1` — the inline pool never spawns.
    workers: Option<Arc<Workers>>,
    /// OS threads ever created by this pool('s lineage) — the
    /// `pool_reuse` accounting: construction moves it, batch calls must
    /// not.
    spawns: Arc<AtomicU64>,
}

impl WorkerPool {
    /// A pool of `threads` workers (0 is clamped to 1).  `threads = 1`
    /// never spawns and runs everything inline on the caller's thread;
    /// otherwise `threads − 1` parked workers are created **here, and
    /// only here** — batch calls reuse them.
    pub fn new(threads: usize) -> Self {
        Self::with_counter(threads, Arc::new(AtomicU64::new(0)))
    }

    /// A new pool of `threads` workers that keeps accumulating **this**
    /// pool's spawn counter — the resize path (`Backend::set_threads`),
    /// so `spawn_events` stays the monotone "OS threads ever created"
    /// count its docs promise across width changes.
    pub fn resized(&self, threads: usize) -> Self {
        Self::with_counter(threads, Arc::clone(&self.spawns))
    }

    fn with_counter(threads: usize, spawns: Arc<AtomicU64>) -> Self {
        let threads = threads.max(1);
        let workers = if threads > 1 {
            let inner = Arc::new(PoolInner {
                state: Mutex::new(PoolState {
                    batch: None,
                    epoch: 0,
                    active: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            });
            let handles = (1..threads)
                .map(|k| {
                    let inner = Arc::clone(&inner);
                    spawns.fetch_add(1, Ordering::SeqCst);
                    std::thread::Builder::new()
                        .name(format!("mmbsgd-worker-{k}"))
                        .spawn(move || worker_loop(inner))
                        .expect("spawning pool worker")
                })
                .collect();
            Some(Arc::new(Workers { inner, call_lock: Mutex::new(()), handles }))
        } else {
            None
        };
        Self { threads, workers, spawns }
    }

    /// The single-threaded (inline) pool.
    pub fn single() -> Self {
        Self::new(1)
    }

    /// Worker count in effect.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// OS threads ever created by this pool and its clones.  Constant
    /// after construction (`threads − 1`); a regression back to
    /// per-call spawning would move it per batch, which
    /// `rust/tests/serve_engine.rs` pins against.
    pub fn spawn_events(&self) -> u64 {
        self.spawns.load(Ordering::SeqCst)
    }

    /// Run one closure call per job across the parked workers, with the
    /// caller claiming jobs too (it would otherwise idle while
    /// waiting); all inline when the pool is single-threaded or there
    /// is at most one job.  Jobs own their output slices, so claimants
    /// never share mutable state; job *construction* order is the
    /// deterministic chunk order of [`partition`], and which claimant
    /// runs a job cannot affect the results (disjoint writes).
    ///
    /// A panic inside a job is caught, the batch runs to completion,
    /// and the first payload is re-raised here.
    ///
    /// Reentrancy: calling `run_jobs` from *inside a job closure* runs
    /// the nested batch inline on the current thread (the hand-off
    /// slot is busy with the outer batch; blocking on it would
    /// deadlock).  Results are unaffected — inline is the
    /// deterministic reference order.
    pub fn run_jobs<J, F>(&self, jobs: Vec<J>, f: F)
    where
        J: Send,
        F: Fn(J) + Sync,
    {
        let nested = IN_POOL_JOB.with(std::cell::Cell::get);
        let workers = match &self.workers {
            Some(w) if jobs.len() > 1 && !nested => w,
            _ => {
                for job in jobs {
                    f(job);
                }
                return;
            }
        };
        let batch = Batch {
            jobs: jobs.into_iter().map(|j| Mutex::new(Some(j))).collect(),
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panic: Mutex::new(None),
            f: &f,
        };
        {
            // One batch at a time per worker set: clones of this pool
            // may be driven from different threads.
            let _call = workers
                .call_lock
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            let inner = &workers.inner;
            let erased: &dyn BatchRun = &batch;
            // SAFETY (lifetime erasure): the reference is published to
            // the workers below and retired — under the same mutex —
            // before this block exits.  We only leave once (a) every
            // job has run (`jobs_done`, counted after each closure
            // returns; job panics are caught so a worker can't die
            // mid-count) and (b) no worker still holds the reference
            // (`active == 0`, decremented only after the worker's last
            // touch of the batch).  Workers acquire the reference only
            // under the state mutex while `batch` is `Some`, so after
            // retirement no new reader can appear: the erased
            // reference never outlives `batch`'s stack frame.
            let erased: &'static dyn BatchRun =
                unsafe { std::mem::transmute::<&dyn BatchRun, &'static dyn BatchRun>(erased) };
            {
                let mut st = inner.state.lock().expect("pool state poisoned");
                debug_assert!(st.batch.is_none(), "batch slot not retired");
                st.batch = Some(erased);
                st.epoch = st.epoch.wrapping_add(1);
            }
            inner.work_cv.notify_all();
            // The caller claims jobs alongside the workers.
            while let RunStatus::Ran = batch.run_one() {}
            // Wait for completion + worker exit, then retire the batch
            // in the same critical section (no window in which a late
            // worker could re-enter a finished batch).
            let mut st = inner.state.lock().expect("pool state poisoned");
            while !(batch.jobs_done() && st.active == 0) {
                st = inner.done_cv.wait(st).expect("pool state poisoned");
            }
            st.batch = None;
        }
        if let Some(payload) = batch.panic.into_inner().expect("panic slot poisoned") {
            resume_unwind(payload);
        }
    }

    /// Shard `data` into at most `threads` contiguous chunks of at
    /// least `min_chunk` items and run `f(start_index, chunk)` on each.
    /// The partition depends only on `(data.len(), threads, min_chunk)`,
    /// so the element→chunk mapping is identical on every run.
    pub fn run_chunks<T, F>(&self, data: &mut [T], min_chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if data.is_empty() {
            return;
        }
        let ranges = partition(data.len(), self.threads, min_chunk);
        if ranges.len() <= 1 {
            f(0, data);
            return;
        }
        let mut jobs = Vec::with_capacity(ranges.len());
        let mut rest = data;
        for r in ranges {
            let (head, tail) = rest.split_at_mut(r.end - r.start);
            jobs.push((r.start, head));
            rest = tail;
        }
        self.run_jobs(jobs, |(start, chunk)| f(start, chunk));
    }
}

impl Clone for WorkerPool {
    fn clone(&self) -> Self {
        Self {
            threads: self.threads,
            workers: self.workers.clone(),
            spawns: Arc::clone(&self.spawns),
        }
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        Self::single()
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .field("spawn_events", &self.spawn_events())
            .finish()
    }
}

/// Split `0..n` into at most `max_parts` contiguous ranges of at least
/// `min_chunk` items (a chunk can be shorter than `min_chunk` only
/// when `n` itself is, in which case there is exactly one chunk).
/// Earlier ranges take the remainder, so sizes differ by at most one
/// item.  Pure function of its arguments — the determinism anchor of
/// the whole pool.
pub fn partition(n: usize, max_parts: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let min_chunk = min_chunk.max(1);
    // Floor division: only as many parts as can each hold a full
    // `min_chunk` — ceiling division here would hand out sub-minimum
    // chunks (n=100, min=32 must give 3 chunks of 34/33/33, not 4×25)
    // and defeat the oversharding guard.
    let parts = max_parts.max(1).min((n / min_chunk).max(1));
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly_once() {
        let cases = [
            (0usize, 4usize, 8usize),
            (1, 4, 8),
            (7, 3, 1),
            (100, 7, 1),
            (513, 4, 32),
            (64, 64, 32),
        ];
        for (n, parts, min_chunk) in cases {
            let ranges = partition(n, parts, min_chunk);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "gap/overlap in {ranges:?}");
                assert!(r.end > r.start, "empty range in {ranges:?}");
                next = r.end;
            }
            assert_eq!(next, n, "partition of {n} into {ranges:?} incomplete");
            assert!(ranges.len() <= parts.max(1));
        }
    }

    #[test]
    fn partition_respects_min_chunk() {
        let ranges = partition(100, 16, 32);
        // 100 items / 32-minimum => at most 3 chunks
        assert!(ranges.len() <= 3, "{ranges:?}");
        assert!(ranges.iter().all(|r| r.end - r.start >= 32), "{ranges:?}");
        // below a single min_chunk everything collapses to one part
        let ranges = partition(7, 16, 32);
        assert_eq!(ranges, vec![0..7]);
        // every chunk >= min_chunk across a spread of shapes
        for (n, parts, min_chunk) in [(127usize, 16usize, 32usize), (513, 8, 64), (96, 3, 32)] {
            let ranges = partition(n, parts, min_chunk);
            assert!(
                ranges.iter().all(|r| r.end - r.start >= min_chunk),
                "partition({n}, {parts}, {min_chunk}) = {ranges:?}"
            );
        }
    }

    #[test]
    fn partition_is_deterministic() {
        assert_eq!(partition(513, 4, 32), partition(513, 4, 32));
    }

    #[test]
    fn run_chunks_writes_every_slot_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0u32; 257];
            pool.run_chunks(&mut out, 8, |start, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v += (start + k) as u32 + 1;
                }
            });
            for (k, &v) in out.iter().enumerate() {
                assert_eq!(v, k as u32 + 1, "slot {k} written {v} times/wrong");
            }
        }
    }

    #[test]
    fn run_jobs_inline_when_single() {
        // threads = 1 must not spawn: a !Send-unfriendly sequential
        // side effect (order-sensitive accumulation) stays in order.
        let pool = WorkerPool::single();
        let order = std::sync::Mutex::new(Vec::new());
        pool.run_jobs(vec![1, 2, 3], |j| order.lock().unwrap().push(j));
        assert_eq!(*order.lock().unwrap(), vec![1, 2, 3]);
        assert_eq!(pool.spawn_events(), 0);
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        assert_eq!(WorkerPool::new(0).threads(), 1);
    }

    #[test]
    fn workers_are_spawned_once_and_reused() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.spawn_events(), 2, "N-way pool spawns N-1 workers at construction");
        for round in 0..200 {
            let mut out = vec![0u64; 97];
            pool.run_chunks(&mut out, 4, |start, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (start + k + round) as u64;
                }
            });
            for (k, &v) in out.iter().enumerate() {
                assert_eq!(v, (k + round) as u64);
            }
        }
        // 200 batch passes later: not a single additional OS thread
        assert_eq!(pool.spawn_events(), 2);
    }

    #[test]
    fn clones_share_workers_and_results_match_inline() {
        let pool = WorkerPool::new(4);
        let clone = pool.clone();
        assert_eq!(clone.spawn_events(), 3);
        let mut a = vec![0.0f64; 321];
        let mut b = vec![0.0f64; 321];
        let fill = |start: usize, chunk: &mut [f64]| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = ((start + k) as f64).sin();
            }
        };
        clone.run_chunks(&mut a, 8, fill);
        WorkerPool::single().run_chunks(&mut b, 8, fill);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        drop(clone); // workers survive: the original still owns them
        let mut c = vec![0.0f64; 321];
        pool.run_chunks(&mut c, 8, fill);
        assert_eq!(c, b);
        assert_eq!(pool.spawn_events(), 3);
    }

    #[test]
    fn nested_run_jobs_from_inside_a_job_runs_inline() {
        // A job closure calling run_jobs on the same pool must degrade
        // to inline execution (it would deadlock the hand-off slot).
        let pool = WorkerPool::new(2);
        let total = std::sync::atomic::AtomicUsize::new(0);
        let (pool_ref, total_ref) = (&pool, &total);
        pool.run_jobs(vec![(); 4], |()| {
            pool_ref.run_jobs(vec![10usize, 20], |v| {
                total_ref.fetch_add(v, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * 30);
        // and the pool still works normally afterwards
        let mut out = vec![0u8; 64];
        pool.run_chunks(&mut out, 4, |_, chunk| chunk.fill(3));
        assert!(out.iter().all(|&v| v == 3));
    }

    #[test]
    fn resized_pool_keeps_accumulating_spawns() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.spawn_events(), 3);
        let pool = pool.resized(2); // +1 worker, counter carries over
        assert_eq!(pool.threads(), 2);
        assert_eq!(pool.spawn_events(), 4);
        let pool = pool.resized(1); // inline pool: no new spawns
        assert_eq!(pool.spawn_events(), 4);
    }

    #[test]
    fn pool_drop_joins_workers_cleanly() {
        // Just exercising construct → use → drop; a hung join would
        // wedge the test binary, which is the failure signal.
        for _ in 0..20 {
            let pool = WorkerPool::new(3);
            let mut out = vec![0u8; 64];
            pool.run_chunks(&mut out, 4, |_, chunk| chunk.fill(1));
            assert!(out.iter().all(|&v| v == 1));
        }
    }

    #[test]
    fn job_panic_propagates_after_batch_completes() {
        let pool = WorkerPool::new(2);
        let hits = std::sync::atomic::AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_jobs(vec![0usize, 1, 2, 3], |j| {
                if j == 1 {
                    panic!("job 1 exploded");
                }
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }));
        assert!(result.is_err(), "panic must reach the caller");
        // every non-panicking job still ran (the batch completes)
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        // and the pool is still usable afterwards
        let mut out = vec![0u8; 32];
        pool.run_chunks(&mut out, 2, |_, chunk| chunk.fill(7));
        assert!(out.iter().all(|&v| v == 7));
    }
}

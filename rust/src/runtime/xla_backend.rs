//! PJRT-backed compute: load AOT artifacts, compile once, execute on the
//! hot path.
//!
//! `python/compile/aot.py` lowers each L2 entry point to HLO **text**
//! (xla_extension 0.5.1 rejects jax≥0.5 serialized protos — 64-bit ids;
//! the text parser reassigns ids) for a lattice of padded shapes, and
//! writes `manifest.json`.  [`ArtifactRegistry`] indexes the manifest;
//! [`XlaBackend`] picks the smallest fitting variant per call, pads and
//! masks the inputs, and executes through the PJRT CPU client.
//!
//! Executables are compiled lazily on first use and cached for the
//! process lifetime (compilation is seconds; execution is micro- to
//! milliseconds).  Padded marshalling buffers are reused across calls.

use super::artifacts::{ArtifactInfo, ArtifactRegistry};
use super::{Backend, MergeScores};
use crate::data::DenseMatrix;
use crate::model::SvStore;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT-backed [`Backend`].
pub struct XlaBackend {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// (calls, compile) counters for perf reporting.
    pub exec_calls: u64,
    pub compiles: u64,
}

impl XlaBackend {
    /// Create from an artifact directory (compiles nothing yet).
    pub fn new(dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self { client, registry, executables: HashMap::new(), exec_calls: 0, compiles: 0 })
    }

    /// Create from the default artifact directory.
    pub fn from_default_dir() -> Result<Self> {
        Self::new(&ArtifactRegistry::default_dir())
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    fn executable(&mut self, info: &ArtifactInfo) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(&info.name) {
            let proto = xla::HloModuleProto::from_text_file(&info.file)
                .map_err(|e| anyhow!("loading {}: {e:?}", info.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", info.name))?;
            self.compiles += 1;
            self.executables.insert(info.name.clone(), exe);
        }
        Ok(&self.executables[&info.name])
    }

    /// Execute an artifact on literal inputs; returns the output tuple.
    fn run(&mut self, info: &ArtifactInfo, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let name = info.name.clone();
        let exe = self.executable(info)?;
        let out = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.exec_calls += 1;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Pad the SV store into (b_pad × d_pad) points + alpha + mask literals.
    fn sv_literals(
        svs: &SvStore,
        b_pad: usize,
        d_pad: usize,
        masked_lane: Option<usize>,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
        let b = svs.len();
        let d = svs.dim();
        assert!(b <= b_pad && d <= d_pad, "store {b}x{d} exceeds pad {b_pad}x{d_pad}");
        let mut pts = vec![0.0f32; b_pad * d_pad];
        for j in 0..b {
            pts[j * d_pad..j * d_pad + d].copy_from_slice(svs.point(j));
        }
        let mut alpha = vec![0.0f32; b_pad];
        let mut mask = vec![0.0f32; b_pad];
        for j in 0..b {
            alpha[j] = svs.alpha(j) as f32;
            mask[j] = 1.0;
        }
        if let Some(i) = masked_lane {
            mask[i] = 0.0;
        }
        let pts = xla::Literal::vec1(&pts)
            .reshape(&[b_pad as i64, d_pad as i64])
            .map_err(|e| anyhow!("reshape points: {e:?}"))?;
        Ok((pts, xla::Literal::vec1(&alpha), xla::Literal::vec1(&mask)))
    }

    fn margins_chunk(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        rows: &[&[f32]],
    ) -> Result<Vec<f64>> {
        let d = svs.dim();
        let nb_art = if rows.len() == 1 { 1 } else { 256 };
        let info = self
            .registry
            .find_margins(svs.len(), d, nb_art)
            .with_context(|| {
                format!("no margins artifact for B={} d={d} nb={nb_art}", svs.len())
            })?
            .clone();
        let (pts, alpha, mask) = Self::sv_literals(svs, info.b_pad, info.d_pad, None)?;
        let mut q = vec![0.0f32; info.nb * info.d_pad];
        for (r, row) in rows.iter().enumerate() {
            q[r * info.d_pad..r * info.d_pad + d].copy_from_slice(row);
        }
        let q = xla::Literal::vec1(&q)
            .reshape(&[info.nb as i64, info.d_pad as i64])
            .map_err(|e| anyhow!("reshape queries: {e:?}"))?;
        let g = xla::Literal::vec1(&[gamma as f32]);
        let outs = self.run(&info, &[pts, alpha, mask, q, g])?;
        let m: Vec<f32> = outs[0]
            .to_vec()
            .map_err(|e| anyhow!("margins to_vec: {e:?}"))?;
        Ok(m[..rows.len()].iter().map(|&v| v as f64).collect())
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn margins(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix) -> Vec<f64> {
        let mut out = Vec::with_capacity(queries.rows());
        let rows: Vec<&[f32]> = (0..queries.rows()).map(|r| queries.row(r)).collect();
        for chunk in rows.chunks(256) {
            out.extend(
                self.margins_chunk(svs, gamma, chunk)
                    .expect("xla margins failed"),
            );
        }
        out
    }

    fn margin1(&mut self, svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
        self.margins_chunk(svs, gamma, &[x]).expect("xla margin1 failed")[0]
    }

    fn merge_scores(&mut self, svs: &SvStore, gamma: f64, i: usize) -> MergeScores {
        self.try_merge_scores(svs, gamma, i).expect("xla merge_scores failed")
    }

    fn merge_gd(&mut self, points: &[(&[f32], f64)], gamma: f64) -> (Vec<f32>, f64, f64) {
        self.try_merge_gd(points, gamma).expect("xla merge_gd failed")
    }
}

impl XlaBackend {
    pub fn try_merge_scores(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        i: usize,
    ) -> Result<MergeScores> {
        let d = svs.dim();
        let info = self
            .registry
            .find_merge_scores(svs.len(), d)
            .with_context(|| format!("no merge_scores artifact for B={} d={d}", svs.len()))?
            .clone();
        let (pts, alpha, mask) = Self::sv_literals(svs, info.b_pad, info.d_pad, Some(i))?;
        let mut xi = vec![0.0f32; info.d_pad];
        xi[..d].copy_from_slice(svs.point(i));
        let xi = xla::Literal::vec1(&xi);
        let ai = xla::Literal::vec1(&[svs.alpha(i) as f32]);
        let g = xla::Literal::vec1(&[gamma as f32]);
        let outs = self.run(&info, &[pts, alpha, mask, xi, ai, g])?;
        let take = |idx: usize| -> Result<Vec<f64>> {
            let v: Vec<f32> = outs[idx]
                .to_vec()
                .map_err(|e| anyhow!("merge_scores output {idx}: {e:?}"))?;
            Ok(v[..svs.len()].iter().map(|&x| x as f64).collect())
        };
        let mut ms = MergeScores { wd: take(0)?, h: take(1)?, a_z: take(2)?, d2: take(3)? };
        // The kernel uses a huge-finite sentinel; normalize to +inf, and
        // re-assert lane i (belt and braces).
        for w in &mut ms.wd {
            if *w >= 1.0e38 {
                *w = f64::INFINITY;
            }
        }
        ms.wd[i] = f64::INFINITY;
        Ok(ms)
    }

    pub fn try_merge_gd(
        &mut self,
        points: &[(&[f32], f64)],
        gamma: f64,
    ) -> Result<(Vec<f32>, f64, f64)> {
        let d = points[0].0.len();
        let info = self
            .registry
            .find_merge_gd(d)
            .with_context(|| format!("no merge_gd artifact for d={d}"))?
            .clone();
        let m_pad = info.m_pad;
        if points.len() > m_pad {
            bail!("merge_gd supports at most {m_pad} points, got {}", points.len());
        }
        let mut xm = vec![0.0f32; m_pad * info.d_pad];
        let mut am = vec![0.0f32; m_pad];
        let mut mm = vec![0.0f32; m_pad];
        for (r, (x, a)) in points.iter().enumerate() {
            xm[r * info.d_pad..r * info.d_pad + d].copy_from_slice(x);
            am[r] = *a as f32;
            mm[r] = 1.0;
        }
        let xm = xla::Literal::vec1(&xm)
            .reshape(&[m_pad as i64, info.d_pad as i64])
            .map_err(|e| anyhow!("reshape merge set: {e:?}"))?;
        let g = xla::Literal::vec1(&[gamma as f32]);
        let outs = self.run(
            &info,
            &[xm, xla::Literal::vec1(&am), xla::Literal::vec1(&mm), g],
        )?;
        let z: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("merge_gd z: {e:?}"))?;
        let a_z: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("merge_gd a_z: {e:?}"))?;
        let z = z[..d].to_vec();
        // Recompute wd exactly in f64 (artifact returns f32 wd; the exact
        // value is cheap and the solvers log it).
        let wd = super::exact_multi_wd(points, &z, a_z[0] as f64, gamma);
        Ok((z, a_z[0] as f64, wd))
    }
}

//! Pure-rust compute backend — the native mirror of the AOT artifacts.
//!
//! Keeps the exact same math as the L1 kernels (same golden-section
//! constants, same MM-GD iteration scheme) so the two backends are
//! numerically interchangeable in `exact` scoring mode.  In the default
//! `lut` mode the merge scorer consults the precomputed golden-section
//! table ([`crate::budget::MergeLut`]) instead of iterating —
//! Θ(B·K + B) instead of Θ(B·K·G) per scoring pass.
//!
//! All distance computations go through the store's norm cache:
//! `d² = ‖x‖² + ‖q‖² − 2⟨x,q⟩` with the query norm hoisted out of the
//! B-loop, so the inner loop is a pure dot product — executed by the
//! explicitly vectorized, runtime-dispatched SIMD block micro-kernel
//! (`crate::kernel::simd`, bit-identical across AVX2/SSE2/NEON/scalar)
//! rather than left to autovectorization (EXPERIMENTS.md §Perf).  The
//! batch paths (margins, merge scoring) run through the cache-blocked
//! [`tile`] engine with backend-owned scratch — no allocation after
//! warm-up — and shard across a persistent deterministic
//! [`WorkerPool`]; the per-step [`margin1_native`] loop stays
//! single-threaded (threading a Θ(B·K) scan would cost more in hand-off
//! latency than it saves) but runs the same blocked inner kernel.

use super::pool::WorkerPool;
use super::tile::{self, TileScratch};
use super::{Backend, MergeScores, ScoredPair};
use crate::budget::lut::MergeScoreMode;
use crate::data::DenseMatrix;
use crate::kernel::{sq_norm, Gaussian, Kernel};
use crate::model::SvStore;

/// MM-GD fixed iteration count / initial step (mirrors
/// `python/compile/model.py` GD_ITERS / GD_LR).
pub const GD_ITERS: usize = 50;
pub const GD_LR: f64 = 0.5;

/// Pure-rust backend.  All batch paths (margins, merge scoring) run
/// through the blocked [`tile`] engine with scratch owned here, sharded
/// across a deterministic [`WorkerPool`] (1 worker unless
/// [`Backend::set_threads`] raises it).
pub struct NativeBackend {
    mode: MergeScoreMode,
    pool: WorkerPool,
    scratch: TileScratch,
}

impl NativeBackend {
    /// Deployment default: LUT-accelerated merge scoring, single worker.
    pub fn new() -> Self {
        Self::with_mode(MergeScoreMode::Lut)
    }

    /// Exact golden-section scoring — the reference the LUT (and the
    /// XLA artifact kernel) are validated against.
    pub fn exact() -> Self {
        Self::with_mode(MergeScoreMode::Exact)
    }

    pub fn with_mode(mode: MergeScoreMode) -> Self {
        Self { mode, pool: WorkerPool::single(), scratch: TileScratch::new() }
    }

    pub fn mode(&self) -> MergeScoreMode {
        self.mode
    }

    /// Worker threads currently sharding the batch paths.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn set_merge_score_mode(&mut self, mode: MergeScoreMode) -> MergeScoreMode {
        self.mode = mode;
        mode
    }

    fn set_threads(&mut self, threads: usize) -> usize {
        // A persistent pool owns parked OS threads: only rebuild when
        // the width actually changes (a redundant call must not churn
        // workers), and a resize carries the spawn counter forward so
        // `worker_spawns` stays the monotone ever-created count.
        if threads.max(1) != self.pool.threads() {
            self.pool = self.pool.resized(threads);
        }
        self.pool.threads()
    }

    fn worker_spawns(&self) -> u64 {
        self.pool.spawn_events()
    }

    fn margins(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix) -> Vec<f64> {
        let mut out = vec![0.0; queries.rows()];
        tile::margins_into(svs, gamma, queries, &mut self.scratch, &self.pool, &mut out);
        out
    }

    fn margins_into(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix, out: &mut [f64]) {
        tile::margins_into(svs, gamma, queries, &mut self.scratch, &self.pool, out);
    }

    fn margins_bounded_into(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        queries: &DenseMatrix,
        bounds: &tile::TileBounds,
        out: &mut [f64],
    ) {
        tile::margins_bounded_into(svs, gamma, queries, bounds, &self.pool, out);
    }

    #[inline]
    fn margin1(&mut self, svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
        margin1_native(svs, gamma, x)
    }

    fn merge_scores(&mut self, svs: &SvStore, gamma: f64, i: usize) -> MergeScores {
        let mut out = MergeScores::default();
        self.merge_scores_into(svs, gamma, i, &mut out);
        out
    }

    fn merge_scores_into(&mut self, svs: &SvStore, gamma: f64, i: usize, out: &mut MergeScores) {
        tile::merge_scores_into(svs, gamma, i, self.mode, &self.pool, out);
    }

    fn merge_scores_batch(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        cands: &[usize],
    ) -> Vec<MergeScores> {
        tile::merge_scores_batch(svs, gamma, cands, self.mode, &self.pool)
    }

    fn merge_score_pair(&mut self, svs: &SvStore, gamma: f64, i: usize, j: usize) -> ScoredPair {
        let (pm, d2) = tile::score_pair(svs, gamma, self.mode, i, j);
        ScoredPair { wd: pm.wd, h: pm.h, a_z: pm.a_z, d2 }
    }

    fn has_cheap_pair_scoring(&self) -> bool {
        true
    }

    fn merge_gd(&mut self, points: &[(&[f32], f64)], gamma: f64) -> (Vec<f32>, f64, f64) {
        merge_gd_native(points, gamma, GD_ITERS, GD_LR)
    }
}

/// The Θ(B·K) per-step margin — the single hottest loop in training.
///
/// Perf notes (EXPERIMENTS.md §Perf):
/// * norm-cached distances: `‖q‖²` computed once per query, `‖x_j‖²`
///   read from the store cache, so the inner loop is a pure dot
///   product — computed for whole runs of SV rows by the
///   runtime-dispatched SIMD block micro-kernel
///   (`kernel::simd::dot_block`: explicit AVX2/SSE2/NEON lanes, query
///   chunks loaded once per row block, bit-identical to the scalar
///   reference on every ISA);
/// * far SVs (γd² > `kernel::EXP_NEG_CUTOFF`) contribute < e⁻⁴⁰ ≈ 4e-18
///   and are dropped before the exponent stage; the survivors'
///   exponents are staged contiguously and evaluated in one stripped
///   `exp` loop (`tile::accumulate_rows` — the same inner kernel the
///   batch paths run, so single-query and batched margins share their
///   bits by construction);
/// * contiguous row iteration over the flat point storage.
#[inline]
pub fn margin1_native(svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
    let n_q = sq_norm(x);
    // Thread-local scratch: this runs once per SGD step, and a fresh
    // zeroed 3 KiB RowAccum per call would tax the smallest budgets.
    tile::with_margin1_scratch(|scratch| {
        tile::accumulate_rows(svs, gamma, x, n_q, 0..svs.len(), scratch, 0.0)
    })
}

/// MM-GD in pure rust (mirrors `kernels/ref.py::merge_gd`): maximize
/// |g(z)| with g(z) = Σ a_i k(x_i, z) by sign-corrected gradient ascent
/// with multiplicative step adaptation; fixed trip count.
pub fn merge_gd_native(
    points: &[(&[f32], f64)],
    gamma: f64,
    iters: usize,
    lr: f64,
) -> (Vec<f32>, f64, f64) {
    assert!(!points.is_empty());
    let d = points[0].0.len();
    let kern = Gaussian::new(gamma);

    // Centroid seed: α-weighted; fall back to |α|-weighted when the
    // coefficients nearly cancel.
    let denom: f64 = points.iter().map(|(_, a)| a).sum();
    let mut z = vec![0.0f64; d];
    if denom.abs() > 1e-12 {
        for (x, a) in points {
            for (zi, &xi) in z.iter_mut().zip(*x) {
                *zi += a * xi as f64;
            }
        }
        for zi in &mut z {
            *zi /= denom;
        }
    } else {
        let wsum: f64 = points.iter().map(|(_, a)| a.abs()).sum::<f64>().max(1e-12);
        for (x, a) in points {
            for (zi, &xi) in z.iter_mut().zip(*x) {
                *zi += a.abs() * xi as f64;
            }
        }
        for zi in &mut z {
            *zi /= wsum;
        }
    }

    let zf32 = |z: &[f64]| z.iter().map(|&v| v as f32).collect::<Vec<f32>>();
    let g = |z: &[f64]| -> f64 {
        let zf = zf32(z);
        points.iter().map(|(x, a)| a * kern.eval(x, &zf)).sum()
    };

    let mut step = lr;
    let mut best = g(&z).abs();
    let mut grad = vec![0.0f64; d];
    let mut z_new = vec![0.0f64; d];
    for _ in 0..iters {
        let gz = g(&z);
        // ∇g(z) = Σ a_i k(x_i,z) · (−2γ)(z − x_i); ascent on |g|.
        grad.iter_mut().for_each(|v| *v = 0.0);
        let zf = zf32(&z);
        for (x, a) in points {
            let k = a * kern.eval(x, &zf);
            for (gi, (&zi, &xi)) in grad.iter_mut().zip(z.iter().zip(*x)) {
                *gi += -2.0 * gamma * k * (zi - xi as f64);
            }
        }
        let sign = if gz >= 0.0 { 1.0 } else { -1.0 };
        for ((zn, &zi), &gi) in z_new.iter_mut().zip(&z).zip(&grad) {
            *zn = zi + step * sign * gi;
        }
        let g_new = g(&z_new).abs();
        if g_new >= best {
            z.copy_from_slice(&z_new);
            best = g_new;
            step *= 1.1;
        } else {
            step *= 0.5;
        }
    }
    let a_z = g(&z);
    let zf = zf32(&z);
    let wd = super::exact_multi_wd(points, &zf, a_z, gamma);
    (zf, a_z, wd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::golden::{self, GS_ITERS};

    fn store(points: &[(&[f32], f64)], dim: usize) -> SvStore {
        let mut s = SvStore::new(dim);
        for (x, a) in points {
            s.push(x, *a);
        }
        s
    }

    #[test]
    fn margin1_matches_margins() {
        let a = [0.0f32, 1.0];
        let b = [1.0f32, 0.0];
        let svs = store(&[(&a, 0.5), (&b, -0.3)], 2);
        let mut be = NativeBackend::new();
        let q = DenseMatrix::from_rows(vec![vec![0.5, 0.5], vec![2.0, -1.0]]);
        let batch = be.margins(&svs, 0.8, &q);
        for r in 0..2 {
            assert!((batch[r] - be.margin1(&svs, 0.8, q.row(r))).abs() < 1e-12);
        }
    }

    #[test]
    fn margin1_matches_naive_kernel_sum() {
        // the norm-cached loop must agree with a direct Σ α_j k(x_j, q)
        let a = [0.3f32, -1.2, 0.8];
        let b = [2.0f32, 0.1, -0.5];
        let svs = store(&[(&a, 0.7), (&b, -0.4)], 3);
        let q = [0.9f32, 0.9, 0.9];
        let kern = Gaussian::new(1.3);
        let naive = 0.7 * kern.eval(&a, &q) - 0.4 * kern.eval(&b, &q);
        let f = margin1_native(&svs, 1.3, &q);
        assert!((f - naive).abs() < 1e-9, "{f} vs {naive}");
    }

    #[test]
    fn merge_scores_masks_self_and_scores_rest() {
        let a = [0.0f32];
        let b = [0.5f32];
        let c = [4.0f32];
        let svs = store(&[(&a, 0.1), (&b, 0.5), (&c, 0.9)], 1);
        for mut be in [NativeBackend::exact(), NativeBackend::new()] {
            let ms = be.merge_scores(&svs, 1.0, 0);
            assert!(ms.wd[0].is_infinite());
            assert!(ms.wd[1].is_finite() && ms.wd[2].is_finite());
            // near partner cheaper than far partner
            assert!(ms.wd[1] < ms.wd[2]);
            assert!((ms.d2[2] - 16.0).abs() < 1e-6);
        }
    }

    #[test]
    fn lut_scores_match_exact_scores() {
        let mut svs = SvStore::new(4);
        let mut rng = crate::rng::Xoshiro256::new(42);
        for _ in 0..24 {
            let x: Vec<f32> = (0..4).map(|_| rng.next_gaussian() as f32 * 0.8).collect();
            let mut a = 0.05 + rng.next_f64();
            if rng.next_f64() < 0.4 {
                a = -a;
            }
            svs.push(&x, a);
        }
        let i = svs.min_abs_alpha().unwrap();
        let exact = NativeBackend::exact().merge_scores(&svs, 0.7, i);
        let lut = NativeBackend::new().merge_scores(&svs, 0.7, i);
        for j in 0..svs.len() {
            if j == i {
                continue;
            }
            let norm2 = svs.alpha(i).powi(2) + svs.alpha(j).powi(2);
            assert!(
                (exact.wd[j] - lut.wd[j]).abs() <= 1e-4 * norm2 + 1e-9,
                "lane {j}: wd {} vs {}",
                lut.wd[j],
                exact.wd[j]
            );
            assert!((exact.d2[j] - lut.d2[j]).abs() < 1e-9);
        }
    }

    #[test]
    fn set_merge_score_mode_switches_scorer() {
        let mut be = NativeBackend::new();
        assert_eq!(be.mode(), MergeScoreMode::Lut);
        let effective = be.set_merge_score_mode(MergeScoreMode::Exact);
        assert_eq!(effective, MergeScoreMode::Exact);
        assert_eq!(be.mode(), MergeScoreMode::Exact);
    }

    #[test]
    fn merge_gd_two_identical_points() {
        let x = [1.0f32, -1.0];
        let pts: Vec<(&[f32], f64)> = vec![(&x, 0.4), (&x, 0.6)];
        let (z, a_z, wd) = merge_gd_native(&pts, 2.0, GD_ITERS, GD_LR);
        assert!((z[0] - 1.0).abs() < 1e-4 && (z[1] + 1.0).abs() < 1e-4);
        assert!((a_z - 1.0).abs() < 1e-4);
        assert!(wd < 1e-8);
    }

    #[test]
    fn merge_gd_not_worse_than_cascade_pairwise() {
        // 3 -> 1: GD joint merge should be <= sequential binary merges
        // in weight degradation (paper Table 1 shows them comparable;
        // GD is the joint optimizer so it should not be much worse).
        let x0 = [0.0f32, 0.0];
        let x1 = [0.4f32, 0.1];
        let x2 = [0.2f32, -0.3];
        let pts: Vec<(&[f32], f64)> = vec![(&x0, 0.3), (&x1, 0.5), (&x2, 0.4)];
        let gamma = 1.0;
        let (_z, _a_z, wd_gd) = merge_gd_native(&pts, gamma, GD_ITERS, GD_LR);

        // cascade: merge (x0,x1) -> z01, then (z01, x2)
        let (z01, a01, _) = golden::merge_pair(&x0, 0.3, &x1, 0.5, gamma, GS_ITERS);
        let (z, a_z, _) = golden::merge_pair(&z01, a01, &x2, 0.4, gamma, GS_ITERS);
        let wd_cascade = super::super::exact_multi_wd(&pts, &z, a_z, gamma);
        assert!(
            wd_gd <= wd_cascade * 1.5 + 1e-6,
            "wd_gd={wd_gd} much worse than cascade={wd_cascade}"
        );
    }

    #[test]
    fn merge_gd_cancelling_coefficients_finite() {
        let x0 = [0.0f32];
        let x1 = [1.0f32];
        let pts: Vec<(&[f32], f64)> = vec![(&x0, 0.5), (&x1, -0.5)];
        let (z, a_z, wd) = merge_gd_native(&pts, 1.0, GD_ITERS, GD_LR);
        assert!(z[0].is_finite() && a_z.is_finite() && wd.is_finite());
        assert!(wd >= -1e-9);
    }
}

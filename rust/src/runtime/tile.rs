//! Cache-blocked kernel-tile engine — the batch mirror of
//! [`super::margin1_native`], and the scoring core behind
//! `Backend::{merge_scores, merge_scores_batch}`.
//!
//! The batch hot paths (evaluation margins, serving, merge-partner
//! scoring) all reduce to the same primitive: a queries × SVs Gaussian
//! kernel block over the flat [`SvStore`] storage.  This module
//! computes that block in L1-sized tiles:
//!
//! * **SV tiles** of [`sv_tile_len`] rows (sized so one tile of point
//!   data fits the L1 budget) stream in ascending-index order; a tile
//!   is re-used across a whole block of [`TILE_Q`] queries before the
//!   next tile is touched, so SV data crosses the cache hierarchy once
//!   per query *block* instead of once per query.
//! * **Norm-cached distances through the SIMD block micro-kernel**:
//!   `d² = ‖x‖² + ‖q‖² − 2⟨x,q⟩` with the SV norms read from the store
//!   cache, the query norms hoisted once per block, and the dots for a
//!   whole run of SV rows computed by one
//!   [`crate::kernel::simd::dot_block`] call — the runtime-dispatched
//!   (AVX2/SSE2/NEON/scalar, bit-identical) multi-row kernel that loads
//!   each query chunk once and streams the rows against it.  Every dot
//!   feeds [`crate::kernel::sq_dist_cached_with_dot`], so the expansion
//!   and its cancellation guard make exactly the per-pair decision the
//!   scalar path makes.
//! * **Batched exponents**: each chunk's surviving `γd²` values are
//!   staged into a contiguous buffer (`RowAccum`) and evaluated in one
//!   stripped accumulation loop — no skip branch inside the `exp`
//!   loop, survivors added in the same ascending-`j` order the scalar
//!   path uses, so the restructuring is invisible to the bits.
//! * **Fused γd² cutoff, per pair and per tile**: each pair keeps the
//!   scalar path's exact far-pair `exp` skip, and a whole (query, tile)
//!   pair is skipped up front when the norm bound
//!   `d ≥ |‖q‖ − ‖x_j‖|` proves every lane is past the cutoff.  The
//!   tile test is conservative by `FAR_TILE_SLACK` plus a norm- and
//!   dimension-scaled `DOT_ABS_EPS` rounding allowance, so it only
//!   skips terms the scalar path would have skipped too — even on
//!   unnormalized large-magnitude data — and blocked results stay
//!   **bit-identical** to [`super::margin1_native`].
//! * **No per-call allocation**: scratch ([`TileScratch`]) is owned by
//!   the backend; per-block state lives in fixed stack arrays.
//!
//! **Determinism.**  Each query's accumulator consumes SV terms in
//! ascending `j` exactly like the scalar loop, and the worker pool
//! shards whole query rows (or score lanes) with a fixed partition, so
//! results are bit-identical for every thread count
//! (`rust/tests/tile_engine.rs` pins both properties).

use super::pool::{partition, WorkerPool};
use super::MergeScores;
use crate::budget::golden::{self, PairMerge, GS_ITERS};
use crate::budget::lut::{MergeLut, MergeScoreMode};
use crate::data::DenseMatrix;
use crate::kernel::{simd, sq_dist_cached, sq_dist_cached_with_dot, sq_norm, EXP_NEG_CUTOFF};
use crate::model::SvStore;

/// Queries per row block.  32 query rows of accumulator + norm state
/// live in stack arrays; at d = 128 a block of query data is 16 KB —
/// it shares L1 with one SV tile.
pub const TILE_Q: usize = 32;

/// Cache budget for one SV tile of point data (half a typical 64 KB
/// L1d — the other half belongs to the query block streaming over it).
const TILE_BYTES: usize = 32 * 1024;

/// Relative safety slack on the per-tile far-skip: the tile bound must
/// beat the cutoff by 0.1% before a tile is skipped.  The norm bound
/// `d² ≥ (‖q‖ − ‖x‖)²` holds exactly in real arithmetic but the
/// f32-lane dot products carry rounding error, so a pair whose
/// *computed* γd² lands epsilon-under the cutoff (and which the scalar
/// path would therefore include) must never be tile-skipped.  The
/// relative slack alone is not enough on large-magnitude data: the
/// f32-accumulated dot's *absolute* error scales with the operand
/// norms (and with dimension), so the skip test also charges the
/// [`DOT_ABS_EPS`] allowance (see [`margins_rows`]).
const FAR_TILE_SLACK: f64 = 1.001;

/// Absolute-error model for the f32-lane dot product behind
/// [`crate::kernel::sq_dist_cached`]: each of the 8 accumulator lanes
/// in [`crate::kernel::dot`] sums `d/8` products of magnitude up to
/// `(nq + nx)/2` for vectors of squared norms `nq`, `nx`, so the
/// worst-case absolute error grows like `(d/8)·ε_f32·(nq + nx)`.  The
/// tile far-skip therefore widens its margin by
/// `DOT_ABS_EPS · (1 + d/8) · (nq + max‖x‖²)` — with ε_f32 ≈ 1.2e-7,
/// `1e-6` leaves ≳8× headroom at every dimension — so no pair whose
/// computed γd² rounds under [`EXP_NEG_CUTOFF`] is ever tile-skipped,
/// even for unnormalized high-dimensional data with huge norms,
/// keeping blocked results bit-identical to the scalar path.
const DOT_ABS_EPS: f64 = 1e-6;

/// Minimum score lanes per worker job (below this, sharding overhead
/// beats the win).
const MIN_LANES: usize = 128;

/// SV rows staged per [`accumulate_rows`] / scoring chunk: enough to
/// amortize the block micro-kernel's dispatch and keep the `exp` loop
/// long, small enough that the four f64 staging buffers (4 KiB) are
/// L1-resident next to the tile data.
const ACC_CHUNK: usize = 128;

/// Staging buffers for the chunked margin accumulation: block-kernel
/// dots, then the surviving coefficients + exponents of one chunk,
/// evaluated by a single stripped `exp` loop.  Stack-allocated once per
/// worker job (or per `margin1` call) and reused across every chunk, so
/// the hot loops never touch the allocator.
pub(crate) struct RowAccum {
    dots: [f64; ACC_CHUNK],
    coef: [f64; ACC_CHUNK],
    args: [f64; ACC_CHUNK],
    exps: [f64; ACC_CHUNK],
}

impl RowAccum {
    pub(crate) fn new() -> Self {
        Self {
            dots: [0.0; ACC_CHUNK],
            coef: [0.0; ACC_CHUNK],
            args: [0.0; ACC_CHUNK],
            exps: [0.0; ACC_CHUNK],
        }
    }
}

impl Default for RowAccum {
    fn default() -> Self {
        Self::new()
    }
}

std::thread_local! {
    /// Per-thread [`RowAccum`] for the *single-query* entry points
    /// (`margin1_native`, [`margin1_bounded`]), which are called once
    /// per SGD step / serve request and have no backend scratch to
    /// borrow: constructing a fresh 3 KiB zeroed RowAccum per call
    /// would be a measurable tax on the smallest budgets.  Reuse is
    /// invisible to results — every slot read is written first within
    /// the same call.  (The batch paths keep a local RowAccum per
    /// worker job instead: one init amortized over the whole job, and
    /// no thread_local traffic from pool workers.)
    static MARGIN1_SCRATCH: std::cell::RefCell<RowAccum> =
        std::cell::RefCell::new(RowAccum::new());
}

/// Run `f` with this thread's reusable [`RowAccum`] — the scratch of
/// the single-query margin paths.
pub(crate) fn with_margin1_scratch<R>(f: impl FnOnce(&mut RowAccum) -> R) -> R {
    MARGIN1_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Accumulate `Σ_j α_j k(x_j, q)` over SV rows `range` into `acc` — the
/// one inner kernel behind [`margins_rows`], [`margin1_bounded`], and
/// [`super::margin1_native`].  Per [`ACC_CHUNK`]-row chunk: one
/// [`simd::dot_block`] pass (query chunks loaded once, rows streamed),
/// the norm expansion + cancellation guard per pair
/// ([`sq_dist_cached_with_dot`] — same decision as the per-pair scalar
/// path), far pairs dropped by the exact `γd² <` [`EXP_NEG_CUTOFF`]
/// test, and one branch-free `exp` accumulation over the survivors in
/// ascending-`j` order.  Under the default `exp_mode = libm` this is
/// bit-identical to the pre-SIMD per-pair loop on every dispatch
/// target; under `exp_mode = vector` the survivors' exponents come from
/// [`simd::exp_neg_block`] instead — bit-identical across ISAs and
/// thread counts (element-wise exp + scalar ascending-`j` sum), within
/// rel err 1e-6 of the libm path.
pub(crate) fn accumulate_rows(
    svs: &SvStore,
    gamma: f64,
    q: &[f32],
    n_q: f64,
    range: std::ops::Range<usize>,
    scratch: &mut RowAccum,
    mut acc: f64,
) -> f64 {
    let dim = svs.dim();
    let pts = svs.points_flat();
    let mut j = range.start;
    while j < range.end {
        let m = (range.end - j).min(ACC_CHUNK);
        simd::dot_block(q, &pts[j * dim..(j + m) * dim], dim, &mut scratch.dots[..m]);
        let mut live = 0;
        for (k, &d) in scratch.dots[..m].iter().enumerate() {
            let jj = j + k;
            let d2 = sq_dist_cached_with_dot(q, n_q, svs.point(jj), svs.norm2(jj), d);
            let e = gamma * d2;
            if e < EXP_NEG_CUTOFF {
                scratch.coef[live] = svs.alpha(jj);
                scratch.args[live] = e;
                live += 1;
            }
        }
        // The staged exp pass: no skip branch, survivors only,
        // ascending-j accumulation order preserved.  Under
        // `exp_mode = vector` the exponents come from the ISA-dispatched
        // polynomial block kernel; the multiply-accumulate stays scalar
        // sequential either way — vectorizing the *sum* would make the
        // reduction order depend on lane width and break cross-ISA
        // bit-identity, which element-wise exponents cannot.
        if simd::exp_mode() == simd::ExpMode::Vector {
            simd::exp_neg_block(&scratch.args[..live], &mut scratch.exps[..live]);
            for (c, x) in scratch.coef[..live].iter().zip(&scratch.exps[..live]) {
                acc += c * x;
            }
        } else {
            for (c, e) in scratch.coef[..live].iter().zip(&scratch.args[..live]) {
                acc += c * (-e).exp();
            }
        }
        j += m;
    }
    acc
}

/// SVs per tile for feature dimension `dim`: as many rows as fit the
/// `TILE_BYTES` L1 budget, clamped to `[16, 512]` so tiny dimensions
/// don't degenerate into per-row bookkeeping and huge ones still
/// amortize the tile-bound test.
pub fn sv_tile_len(dim: usize) -> usize {
    if dim == 0 {
        return 512;
    }
    (TILE_BYTES / (4 * dim)).clamp(16, 512)
}

/// Per-tile (min ‖x_j‖, max ‖x_j‖) norm bounds over an [`SvStore`] —
/// the precondition data of the tile far-skip test.  The training batch
/// paths rebuild them into backend scratch on every call (the store
/// mutates between maintenance events); serving paths, whose store is
/// frozen inside a predictor, build them **once** at load time and
/// reuse them for every request, so even a single-query `decision1`
/// gets the per-tile far-skip without paying the Θ(B) bound scan.
///
/// The bounds are valid only for the exact store state they were built
/// from (they depend on the SV count and the norm cache); rebuild after
/// any mutation.
#[derive(Clone, Debug, Default)]
pub struct TileBounds {
    /// SV rows per tile ([`sv_tile_len`] of the store's dimension).
    ts: usize,
    lo_hi: Vec<(f64, f64)>,
}

impl TileBounds {
    /// Bounds for the current state of `svs`.
    pub fn of(svs: &SvStore) -> Self {
        let mut b = Self::default();
        b.rebuild(svs);
        b
    }

    /// Recompute in place (keeps capacity — the backend scratch path).
    pub fn rebuild(&mut self, svs: &SvStore) {
        self.ts = sv_tile_len(svs.dim());
        self.lo_hi.clear();
        for tile in svs.norms2().chunks(self.ts) {
            let mut lo = f64::INFINITY;
            let mut hi = 0.0f64;
            for &n2 in tile {
                let s = n2.sqrt();
                lo = lo.min(s);
                hi = hi.max(s);
            }
            self.lo_hi.push((lo, hi));
        }
    }

    /// Do these bounds describe a store of `n` SVs?  (Necessary, not
    /// sufficient — the caller owns the no-mutation contract.)
    fn covers(&self, n: usize) -> bool {
        self.lo_hi.len() == if n == 0 { 0 } else { (n - 1) / self.ts.max(1) + 1 }
    }
}

/// Reusable per-call scratch, owned by the backend so the steady-state
/// batch paths allocate nothing.
#[derive(Clone, Debug, Default)]
pub struct TileScratch {
    /// Per-tile far-skip bounds, rebuilt for the store of each call.
    bounds: TileBounds,
}

impl TileScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Batch margins through the tile engine: `out[r] = Σ_j α_j k(x_j, q_r)`
/// (no bias), bit-identical to [`super::margin1_native`] per row.
/// Query rows are sharded across the pool's workers.
pub fn margins_into(
    svs: &SvStore,
    gamma: f64,
    queries: &DenseMatrix,
    scratch: &mut TileScratch,
    pool: &WorkerPool,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), queries.rows());
    if out.is_empty() {
        return;
    }
    if svs.is_empty() {
        out.fill(0.0);
        return;
    }
    scratch.bounds.rebuild(svs);
    let bounds = &scratch.bounds;
    pool.run_chunks(out, TILE_Q, |row0, chunk| {
        margins_rows(svs, gamma, queries, bounds, row0, chunk);
    });
}

/// [`margins_into`] with caller-prebuilt [`TileBounds`] — the serving
/// path, where the store is frozen and the bounds are computed once at
/// model-load time instead of per request batch.
pub fn margins_bounded_into(
    svs: &SvStore,
    gamma: f64,
    queries: &DenseMatrix,
    bounds: &TileBounds,
    pool: &WorkerPool,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), queries.rows());
    debug_assert!(bounds.covers(svs.len()), "stale TileBounds for this store");
    if out.is_empty() {
        return;
    }
    if svs.is_empty() {
        out.fill(0.0);
        return;
    }
    pool.run_chunks(out, TILE_Q, |row0, chunk| {
        margins_rows(svs, gamma, queries, bounds, row0, chunk);
    });
}

/// Single-query margin with the per-tile far-skip: bit-identical to
/// [`super::margin1_native`] (ascending-`j` accumulation; a tile is
/// only skipped when its norm bound proves every lane past the scalar
/// path's own cutoff — the same `margins_rows` test, slack and all),
/// but whole far tiles cost one bound test instead of a norm-cached
/// distance per SV.  This is
/// the single-query serving path (`Predictor::decision1`): the bounds
/// are prebuilt once for the frozen store, so a size-1 request enjoys
/// the same far-skip treatment as a batch row.
pub fn margin1_bounded(svs: &SvStore, gamma: f64, x: &[f32], bounds: &TileBounds) -> f64 {
    let b = svs.len();
    if b == 0 {
        return 0.0;
    }
    debug_assert!(bounds.covers(b), "stale TileBounds for this store");
    let n_q = sq_norm(x);
    let s_q = n_q.sqrt();
    let dim_eps = DOT_ABS_EPS * (1.0 + svs.dim() as f64 / 8.0);
    let ts = bounds.ts;
    with_margin1_scratch(|scratch| {
        let mut f = 0.0;
        for (t, &(lo, hi)) in bounds.lo_hi.iter().enumerate() {
            let j0 = t * ts;
            let j1 = (j0 + ts).min(b);
            let gap = if s_q < lo {
                lo - s_q
            } else if s_q > hi {
                s_q - hi
            } else {
                0.0
            };
            if gamma * gap * gap
                > EXP_NEG_CUTOFF * FAR_TILE_SLACK + gamma * dim_eps * (n_q + hi * hi)
            {
                continue;
            }
            f = accumulate_rows(svs, gamma, x, n_q, j0..j1, scratch, f);
        }
        f
    })
}

/// Convenience wrapper: single-threaded tiled margins with local
/// scratch (model-side evaluation, tests).
pub fn margins(svs: &SvStore, gamma: f64, queries: &DenseMatrix) -> Vec<f64> {
    let mut out = vec![0.0; queries.rows()];
    margins_into(svs, gamma, queries, &mut TileScratch::new(), &WorkerPool::single(), &mut out);
    out
}

/// One worker's share of query rows: blocks of [`TILE_Q`] queries, SV
/// tiles streamed in ascending order within each block.
fn margins_rows(
    svs: &SvStore,
    gamma: f64,
    queries: &DenseMatrix,
    bounds: &TileBounds,
    row0: usize,
    out: &mut [f64],
) {
    let b = svs.len();
    let ts = bounds.ts;
    // Rounding allowance of the computed γd² (see DOT_ABS_EPS): the
    // f32 dot's absolute error grows with both dimension and norms.
    let dim_eps = DOT_ABS_EPS * (1.0 + svs.dim() as f64 / 8.0);
    let mut scratch = RowAccum::new();
    for (blk, out_blk) in out.chunks_mut(TILE_Q).enumerate() {
        let r0 = row0 + blk * TILE_Q;
        // Hoist query norms (and their roots, for the tile bound) once
        // per block — the scalar path computes ‖q‖² once per query too.
        let mut nq = [0.0f64; TILE_Q];
        let mut snq = [0.0f64; TILE_Q];
        for (k, f) in out_blk.iter_mut().enumerate() {
            let n = sq_norm(queries.row(r0 + k));
            nq[k] = n;
            snq[k] = n.sqrt();
            *f = 0.0;
        }
        let mut t = 0;
        let mut j0 = 0;
        while j0 < b {
            let j1 = (j0 + ts).min(b);
            let (lo, hi) = bounds.lo_hi[t];
            for (k, acc) in out_blk.iter_mut().enumerate() {
                // Per-tile fused cutoff: every lane in the tile has
                // d ≥ gap, so γ·gap² conservatively past the cutoff
                // means the scalar path would skip every term anyway.
                // The margin is both relative (FAR_TILE_SLACK) and
                // absolute in the operand norms and dimension
                // (dim_eps): the scalar path tests the *computed* γd²,
                // whose absolute error grows with ‖q‖² + ‖x‖² and with
                // d, so a tile may only be skipped when its bound
                // clears the cutoff by more than that worst-case
                // rounding gap.
                let s = snq[k];
                let gap = if s < lo {
                    lo - s
                } else if s > hi {
                    s - hi
                } else {
                    0.0
                };
                if gamma * gap * gap
                    > EXP_NEG_CUTOFF * FAR_TILE_SLACK + gamma * dim_eps * (nq[k] + hi * hi)
                {
                    continue;
                }
                let q = queries.row(r0 + k);
                *acc = accumulate_rows(svs, gamma, q, nq[k], j0..j1, &mut scratch, *acc);
            }
            j0 = j1;
            t += 1;
        }
    }
}

/// Score one (candidate, lane) pair with the requested scorer — the
/// single-pair unit every scoring path below is built from (and the
/// cache-patch primitive `MultiMerge` uses for freshly merged points).
#[inline]
pub fn score_pair(
    svs: &SvStore,
    gamma: f64,
    mode: MergeScoreMode,
    i: usize,
    j: usize,
) -> (PairMerge, f64) {
    let d2 = sq_dist_cached(svs.point(i), svs.norm2(i), svs.point(j), svs.norm2(j));
    (PairScorer::new(mode).params(svs.alpha(i), svs.alpha(j), gamma * d2), d2)
}

/// Merge scorer resolved once per scoring pass: the LUT lives behind a
/// `OnceLock`, so resolving it (an atomic load) and re-matching the
/// mode per (candidate, lane) pair would put avoidable work in the
/// hottest loops — the lane loops below hoist this instead, like the
/// pre-tile scalar scorer did.
#[derive(Clone, Copy)]
enum PairScorer {
    Lut(&'static MergeLut),
    Exact,
}

impl PairScorer {
    fn new(mode: MergeScoreMode) -> Self {
        match mode {
            MergeScoreMode::Lut => Self::Lut(MergeLut::global()),
            MergeScoreMode::Exact => Self::Exact,
        }
    }

    #[inline]
    fn params(self, a_i: f64, a_j: f64, c: f64) -> PairMerge {
        match self {
            Self::Lut(lut) => lut.merge_pair_params(a_i, a_j, c),
            Self::Exact => golden::merge_pair_params(a_i, a_j, c, GS_ITERS),
        }
    }
}

/// One worker's slice of a candidate's score lanes.
struct LaneJob<'a> {
    start: usize,
    wd: &'a mut [f64],
    h: &'a mut [f64],
    a_z: &'a mut [f64],
    d2: &'a mut [f64],
}

/// Split a [`MergeScores`]' four lane arrays along `ranges` (the
/// borrow is consumed progressively, so the chunks are disjoint).
fn split_lanes<'a>(
    s: &'a mut MergeScores,
    ranges: &[std::ops::Range<usize>],
) -> Vec<LaneJob<'a>> {
    let mut jobs = Vec::with_capacity(ranges.len());
    let (mut wd, mut h, mut a_z, mut d2) =
        (s.wd.as_mut_slice(), s.h.as_mut_slice(), s.a_z.as_mut_slice(), s.d2.as_mut_slice());
    for r in ranges {
        let take = r.end - r.start;
        let (wd0, wd1) = wd.split_at_mut(take);
        let (h0, h1) = h.split_at_mut(take);
        let (az0, az1) = a_z.split_at_mut(take);
        let (d20, d21) = d2.split_at_mut(take);
        jobs.push(LaneJob { start: r.start, wd: wd0, h: h0, a_z: az0, d2: d20 });
        wd = wd1;
        h = h1;
        a_z = az1;
        d2 = d21;
    }
    jobs
}

/// Score merging SV `i` against every other SV, writing into a
/// caller-owned buffer (lane `i` keeps `wd = +inf`).  Lanes are sharded
/// across the pool; each lane is written by exactly one worker with the
/// same per-pair math as the scalar scorer, so the result is
/// bit-identical for every thread count.
pub fn merge_scores_into(
    svs: &SvStore,
    gamma: f64,
    i: usize,
    mode: MergeScoreMode,
    pool: &WorkerPool,
    out: &mut MergeScores,
) {
    let b = svs.len();
    out.reset(b);
    if b == 0 {
        return;
    }
    let ranges = partition(b, pool.threads(), MIN_LANES);
    let jobs = split_lanes(out, &ranges);
    let scorer = PairScorer::new(mode);
    pool.run_jobs(jobs, |mut job| score_lanes(svs, gamma, scorer, i, &mut job));
}

/// Score lanes `j0..j1` of candidate `i` into `job` — the shared inner
/// loop of [`merge_scores_into`] and [`merge_scores_batch`].  Each
/// [`ACC_CHUNK`]-lane run takes one [`simd::dot_block`] pass (the
/// candidate row's chunks loaded once, partner rows streamed), and each
/// dot feeds [`sq_dist_cached_with_dot`] — the identical per-pair d²
/// (expansion + cancellation guard) that [`score_pair`] computes, so
/// cached rows can stand in for per-event rescans bit-for-bit.  The
/// self-lane's dot is computed but discarded (cheaper than fissioning
/// the block around it).
fn score_lane_range(
    svs: &SvStore,
    gamma: f64,
    scorer: PairScorer,
    i: usize,
    range: std::ops::Range<usize>,
    job: &mut LaneJob,
    dots: &mut [f64; ACC_CHUNK],
) {
    let x_i = svs.point(i);
    let a_i = svs.alpha(i);
    let n_i = svs.norm2(i); // candidate norm hoisted out of the lane loop
    let dim = svs.dim();
    let pts = svs.points_flat();
    let mut j = range.start;
    while j < range.end {
        let m = (range.end - j).min(ACC_CHUNK);
        simd::dot_block(x_i, &pts[j * dim..(j + m) * dim], dim, &mut dots[..m]);
        for (k, &d) in dots[..m].iter().enumerate() {
            let jj = j + k;
            if jj == i {
                continue;
            }
            let lane = jj - job.start;
            let d2 = sq_dist_cached_with_dot(x_i, n_i, svs.point(jj), svs.norm2(jj), d);
            let pm = scorer.params(a_i, svs.alpha(jj), gamma * d2);
            job.wd[lane] = pm.wd;
            job.h[lane] = pm.h;
            job.a_z[lane] = pm.a_z;
            job.d2[lane] = d2;
        }
        j += m;
    }
}

fn score_lanes(svs: &SvStore, gamma: f64, scorer: PairScorer, i: usize, job: &mut LaneJob) {
    let mut dots = [0.0f64; ACC_CHUNK];
    let range = job.start..job.start + job.wd.len();
    score_lane_range(svs, gamma, scorer, i, range, job, &mut dots);
}

/// One worker's lane range across *all* candidates of a batch.
struct BatchJob<'a> {
    start: usize,
    len: usize,
    rows: Vec<(usize, LaneJob<'a>)>,
}

/// Score the `cands` merge candidates against every SV in one tiled
/// pass: SV tiles stream in the outer loop and all candidates consume a
/// tile while it is hot, so the store crosses the cache hierarchy once
/// per batch instead of once per candidate (this is how
/// `MultiMerge::maintain` amortizes partner search across consecutive
/// maintenance events).  Every lane carries exactly the per-pair values
/// [`merge_scores_into`] would produce — the cached rows can stand in
/// for a fresh per-event rescan bit-for-bit.
pub fn merge_scores_batch(
    svs: &SvStore,
    gamma: f64,
    cands: &[usize],
    mode: MergeScoreMode,
    pool: &WorkerPool,
) -> Vec<MergeScores> {
    let b = svs.len();
    let mut out: Vec<MergeScores> = cands
        .iter()
        .map(|_| {
            let mut s = MergeScores::default();
            s.reset(b);
            s
        })
        .collect();
    if b == 0 || cands.is_empty() {
        return out;
    }
    let ranges = partition(b, pool.threads(), MIN_LANES);
    let mut jobs: Vec<BatchJob> = ranges
        .iter()
        .map(|r| BatchJob {
            start: r.start,
            len: r.end - r.start,
            rows: Vec::with_capacity(cands.len()),
        })
        .collect();
    for (ci, s) in out.iter_mut().enumerate() {
        for (job, lanes) in jobs.iter_mut().zip(split_lanes(s, &ranges)) {
            job.rows.push((cands[ci], lanes));
        }
    }
    let ts = sv_tile_len(svs.dim());
    let scorer = PairScorer::new(mode);
    pool.run_jobs(jobs, |mut job| {
        let mut dots = [0.0f64; ACC_CHUNK];
        let end = job.start + job.len;
        let mut j0 = job.start;
        while j0 < end {
            // SV tiles stream in the outer loop; every candidate scores
            // a tile (through the block micro-kernel) while it is hot.
            let j1 = (j0 + ts).min(end);
            for (i, lanes) in job.rows.iter_mut() {
                score_lane_range(svs, gamma, scorer, *i, j0..j1, lanes, &mut dots);
            }
            j0 = j1;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::runtime::margin1_native;

    fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
        let mut rng = Xoshiro256::new(seed);
        let mut s = SvStore::new(d);
        for _ in 0..b {
            let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
            let mut a = 0.05 + rng.next_f64();
            if rng.next_f64() < 0.5 {
                a = -a;
            }
            s.push(&x, a);
        }
        s
    }

    fn random_queries(n: usize, d: usize, seed: u64) -> DenseMatrix {
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32 * 1.5).collect())
            .collect();
        DenseMatrix::from_rows(rows)
    }

    #[test]
    fn tiled_margins_bit_match_scalar_rows() {
        for &(b, d) in &[(1usize, 3usize), (7, 8), (65, 17), (513, 3)] {
            let svs = random_store(b, d, b as u64 + 1);
            let q = random_queries(37, d, 99);
            let got = margins(&svs, 0.7, &q);
            for r in 0..q.rows() {
                let want = margin1_native(&svs, 0.7, q.row(r));
                assert_eq!(got[r].to_bits(), want.to_bits(), "row {r} of B={b} d={d}");
            }
        }
    }

    #[test]
    fn empty_store_and_empty_batch() {
        let svs = SvStore::new(4);
        let q = random_queries(5, 4, 1);
        assert_eq!(margins(&svs, 1.0, &q), vec![0.0; 5]);
        let svs = random_store(8, 4, 2);
        let empty = DenseMatrix::zeros(0, 4);
        assert!(margins(&svs, 1.0, &empty).is_empty());
    }

    #[test]
    fn tile_skip_only_drops_sub_cutoff_terms() {
        // Two far clusters: queries near cluster A must still see every
        // A term while the B tile is (correctly) skippable, and the
        // result must equal the scalar path bit-for-bit.
        let d = 8;
        let mut svs = SvStore::new(d);
        let mut rng = Xoshiro256::new(5);
        for j in 0..600 {
            let base = if j % 2 == 0 { 0.0f32 } else { 400.0 };
            let x: Vec<f32> =
                (0..d).map(|_| base + rng.next_gaussian() as f32 * 0.3).collect();
            svs.push(&x, 0.2 + rng.next_f64());
        }
        let q = random_queries(19, d, 6);
        let got = margins(&svs, 0.5, &q);
        for r in 0..q.rows() {
            assert_eq!(got[r].to_bits(), margin1_native(&svs, 0.5, q.row(r)).to_bits());
        }
    }

    #[test]
    fn tile_skip_safe_on_large_magnitude_data() {
        // Unnormalized data with huge norms: the f32 dot's *absolute*
        // error is large here — and grows with dimension — so the
        // norm- and dim-aware slack must keep every near-cutoff pair
        // unskipped.  The per-dim query offsets sweep γ·gap² across
        // the EXP_NEG_CUTOFF boundary band (γ·d·off² ∈ [~25, ~57]) —
        // the regime where a bare relative slack could tile-skip a
        // pair the scalar path includes.
        for &(d, gamma, off0, step) in
            &[(8usize, 0.05f64, 8.0f32, 0.1f32), (128, 3e-4, 26.0, 0.3)]
        {
            let mut svs = SvStore::new(d);
            let mut rng = Xoshiro256::new(11);
            for _ in 0..600 {
                let x: Vec<f32> =
                    (0..d).map(|_| 2000.0 + rng.next_gaussian() as f32 * 0.5).collect();
                svs.push(&x, 0.2 + rng.next_f64());
            }
            let mut qrows = Vec::new();
            for k in 0..40 {
                let off = off0 + step * k as f32;
                qrows.push(
                    (0..d).map(|_| 2000.0 + off + rng.next_gaussian() as f32 * 0.2).collect(),
                );
            }
            let q = DenseMatrix::from_rows(qrows);
            let got = margins(&svs, gamma, &q);
            for r in 0..q.rows() {
                assert_eq!(
                    got[r].to_bits(),
                    margin1_native(&svs, gamma, q.row(r)).to_bits(),
                    "d={d} row {r}"
                );
            }
        }
    }

    #[test]
    fn merge_scores_into_matches_lane_loop() {
        let svs = random_store(97, 6, 3);
        let i = svs.min_abs_alpha().unwrap();
        for mode in [MergeScoreMode::Exact, MergeScoreMode::Lut] {
            let mut out = MergeScores::default();
            merge_scores_into(&svs, 0.8, i, mode, &WorkerPool::single(), &mut out);
            assert!(out.wd[i].is_infinite());
            for j in 0..svs.len() {
                if j == i {
                    continue;
                }
                let (pm, d2) = score_pair(&svs, 0.8, mode, i, j);
                assert_eq!(out.wd[j].to_bits(), pm.wd.to_bits(), "lane {j}");
                assert_eq!(out.d2[j].to_bits(), d2.to_bits(), "lane {j}");
            }
        }
    }

    #[test]
    fn batch_rows_match_single_candidate_scoring() {
        let svs = random_store(140, 5, 4);
        let cands = [0usize, 3, 77, 139];
        for threads in [1usize, 3] {
            let pool = WorkerPool::new(threads);
            let batch = merge_scores_batch(&svs, 1.1, &cands, MergeScoreMode::Lut, &pool);
            for (c, &i) in cands.iter().enumerate() {
                let mut single = MergeScores::default();
                merge_scores_into(&svs, 1.1, i, MergeScoreMode::Lut, &pool, &mut single);
                assert_eq!(batch[c].wd, single.wd, "candidate {i} (threads {threads})");
                assert_eq!(batch[c].h, single.h);
                assert_eq!(batch[c].a_z, single.a_z);
                assert_eq!(batch[c].d2, single.d2);
            }
        }
    }

    #[test]
    fn margin1_bounded_bit_matches_scalar() {
        // Including the two-far-clusters shape where whole tiles are
        // skippable — the skip must only drop sub-cutoff terms.
        for &(b, d, spread) in &[(1usize, 3usize, 1.0f32), (65, 17, 1.0), (600, 8, 400.0)] {
            let mut svs = SvStore::new(d);
            let mut rng = Xoshiro256::new(b as u64 + 3);
            for j in 0..b {
                let base = if j % 2 == 0 { 0.0 } else { spread };
                let x: Vec<f32> =
                    (0..d).map(|_| base + rng.next_gaussian() as f32 * 0.3).collect();
                svs.push(&x, 0.2 + rng.next_f64());
            }
            let bounds = TileBounds::of(&svs);
            let q = random_queries(23, d, 77);
            for r in 0..q.rows() {
                let got = margin1_bounded(&svs, 0.5, q.row(r), &bounds);
                let want = margin1_native(&svs, 0.5, q.row(r));
                assert_eq!(got.to_bits(), want.to_bits(), "B={b} d={d} row {r}");
            }
        }
        // empty store
        let svs = SvStore::new(4);
        let bounds = TileBounds::of(&svs);
        assert_eq!(margin1_bounded(&svs, 1.0, &[0.0; 4], &bounds), 0.0);
    }

    #[test]
    fn margins_bounded_into_matches_margins() {
        let svs = random_store(130, 7, 21);
        let q = random_queries(41, 7, 22);
        let bounds = TileBounds::of(&svs);
        let want = margins(&svs, 0.9, &q);
        let mut got = vec![0.0; q.rows()];
        for threads in [1usize, 3] {
            margins_bounded_into(&svs, 0.9, &q, &bounds, &WorkerPool::new(threads), &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}");
            }
        }
    }

    #[test]
    fn sv_tile_len_tracks_dimension() {
        assert_eq!(sv_tile_len(1), 512);
        assert_eq!(sv_tile_len(128), 64);
        assert_eq!(sv_tile_len(4096), 16);
        // tiles must cover the L1 budget, never exceed the clamp
        for d in [1usize, 3, 300, 10_000] {
            let ts = sv_tile_len(d);
            assert!((16..=512).contains(&ts));
        }
    }
}

//! Compute backends: the numeric services the coordinator calls from the
//! training/serving hot path.
//!
//! Two interchangeable implementations:
//!
//! * [`XlaBackend`] — loads the AOT artifacts (`artifacts/*.hlo.txt`,
//!   lowered from the L1/L2 jax+Pallas code by `python/compile/aot.py`)
//!   and executes them on the PJRT CPU client via the `xla` crate.
//!   Fixed shapes: inputs are padded to the artifact's (B_pad, d_pad)
//!   and masked.  Python never runs at request time.  Gated behind the
//!   off-by-default `xla` cargo feature so the default build carries no
//!   external native deps; without it a stub that fails construction
//!   keeps the API surface intact.
//! * [`NativeBackend`] — a pure-rust mirror of the same math.  Used by
//!   unit tests (no artifacts needed), for tiny budgets where PJRT call
//!   overhead dominates, and as the apples-to-apples perf baseline.
//!
//! The two must agree numerically; `rust/tests/backend_equivalence.rs`
//! enforces it on every artifact shape.

mod artifacts;
mod hybrid;
mod native;
pub mod pool;
pub mod tile;
#[cfg(feature = "xla")]
mod xla_backend;
#[cfg(not(feature = "xla"))]
mod xla_stub;

pub use artifacts::{ArtifactInfo, ArtifactRegistry};
pub use hybrid::HybridBackend;
pub use native::{margin1_native, NativeBackend};
pub use pool::WorkerPool;
pub use tile::{margin1_bounded, TileBounds, TileScratch};
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;
#[cfg(not(feature = "xla"))]
pub use xla_stub::XlaBackend;

use crate::budget::lut::MergeScoreMode;
use crate::data::DenseMatrix;
use crate::model::SvStore;

/// Pairwise merge-scoring output (one lane per budget SV).
#[derive(Clone, Debug, Default)]
pub struct MergeScores {
    /// Weight degradation ‖Δ‖² of the optimal binary merge with lane j.
    pub wd: Vec<f64>,
    /// Optimal line parameter (z = h x_i + (1-h) x_j).
    pub h: Vec<f64>,
    /// Optimal merged coefficient.
    pub a_z: Vec<f64>,
    /// Squared distance ‖x_i − x_j‖².
    pub d2: Vec<f64>,
}

impl MergeScores {
    /// Reset to `b` default lanes (`wd = +inf`, rest `0`) without
    /// releasing capacity — the maintenance loop reuses one buffer per
    /// event, so steady-state scoring allocates nothing.
    pub fn reset(&mut self, b: usize) {
        self.wd.clear();
        self.wd.resize(b, f64::INFINITY);
        self.h.clear();
        self.h.resize(b, 0.0);
        self.a_z.clear();
        self.a_z.resize(b, 0.0);
        self.d2.clear();
        self.d2.resize(b, 0.0);
    }
}

/// One (candidate, lane) merge score — the unit `MultiMerge` uses to
/// patch a cached scoring row when a freshly merged SV appears between
/// consecutive maintenance events.
#[derive(Clone, Copy, Debug)]
pub struct ScoredPair {
    pub wd: f64,
    pub h: f64,
    pub a_z: f64,
    pub d2: f64,
}

/// Numeric services used by solvers and budget maintenance.
///
/// Deliberately NOT `Send`: the PJRT client handle is thread-local, so
/// each coordinator worker constructs its own backend (see
/// `coordinator::run_grid`) — no shared mutable state on the hot path.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Select the merge scorer ([`MergeScoreMode::Lut`] table lookup vs
    /// [`MergeScoreMode::Exact`] per-pair golden section) and return the
    /// mode actually in effect.  Backends whose scorer is fixed ignore
    /// the request — the AOT artifact kernel always runs the exact
    /// search, hence the default — and callers must record the returned
    /// mode, not the requested one, in run provenance.
    fn set_merge_score_mode(&mut self, _mode: MergeScoreMode) -> MergeScoreMode {
        MergeScoreMode::Exact
    }

    /// Worker threads for the tiled batch paths (margins, batch merge
    /// scoring).  Returns the count actually in effect — backends with
    /// no pool (the AOT artifacts run their own parallelism) ignore the
    /// request, and callers must report the returned value, not the
    /// requested one.  Results are bit-identical for every thread count
    /// (see [`pool`]).
    fn set_threads(&mut self, _threads: usize) -> usize {
        1
    }

    /// OS worker threads ever created by this backend's pool — the
    /// `pool_reuse` accounting behind `rust/tests/serve_engine.rs`.
    /// With the persistent [`pool::WorkerPool`] this moves exactly once
    /// per [`Backend::set_threads`] (by `threads − 1`) and stays flat
    /// across every batch pass; backends without a pool report 0.
    fn worker_spawns(&self) -> u64 {
        0
    }

    /// Decision values (no bias) for a batch of query rows.
    fn margins(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix) -> Vec<f64>;

    /// [`Backend::margins`] into a caller-owned buffer (`out.len()`
    /// must equal `queries.rows()`), so a long-lived server can reuse
    /// one answer buffer instead of taking a fresh `Vec` per margins
    /// pass (request packing still allocates on the caller's side).
    /// The default copies through `margins` (source-compatible for
    /// external backends); the native backend overrides it to write
    /// tile-engine results straight into `out`.
    fn margins_into(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix, out: &mut [f64]) {
        out.copy_from_slice(&self.margins(svs, gamma, queries));
    }

    /// [`Backend::margins_into`] with caller-prebuilt [`TileBounds`] —
    /// the serving batch path, where the store is frozen and the
    /// far-skip bounds were computed once at model-load time.  The
    /// contract on `bounds` is the tile engine's: built from exactly
    /// this store state.  The default ignores the bounds and forwards
    /// (backends whose kernels don't consume them stay correct); the
    /// native backend overrides it to skip the per-call Θ(B) bound
    /// rebuild.
    fn margins_bounded_into(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        queries: &DenseMatrix,
        _bounds: &TileBounds,
        out: &mut [f64],
    ) {
        self.margins_into(svs, gamma, queries, out);
    }

    /// Decision value (no bias) for a single query.
    fn margin1(&mut self, svs: &SvStore, gamma: f64, x: &[f32]) -> f64;

    /// Score merging SV `i` against every other SV in the store.
    /// Lane `i` itself gets `wd = +inf`.
    fn merge_scores(&mut self, svs: &SvStore, gamma: f64, i: usize) -> MergeScores;

    /// [`Backend::merge_scores`] into a caller-owned buffer, so a
    /// maintainer holding one scratch [`MergeScores`] runs its
    /// steady-state event loop allocation-free.
    fn merge_scores_into(&mut self, svs: &SvStore, gamma: f64, i: usize, out: &mut MergeScores) {
        *out = self.merge_scores(svs, gamma, i);
    }

    /// Score several merge candidates against the whole store in one
    /// pass (the tile engine streams each SV tile across all candidates
    /// while it is cache-hot).  Row `c` must equal
    /// `merge_scores(svs, gamma, cands[c])` exactly — `MultiMerge`
    /// substitutes cached rows for per-event rescans and the training
    /// stream must not notice.
    fn merge_scores_batch(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        cands: &[usize],
    ) -> Vec<MergeScores> {
        cands.iter().map(|&i| self.merge_scores(svs, gamma, i)).collect()
    }

    /// Score one (candidate `i`, partner `j`) pair with this backend's
    /// scorer — the patch primitive for cached scoring rows.  Must
    /// agree with lane `j` of [`Backend::merge_scores`] *exactly*
    /// (`MultiMerge` splices the result into a cached row that stands
    /// in for a fresh rescan), so the default extracts the lane from a
    /// full scoring pass — correct for every backend by construction.
    /// Backends with a per-pair fast path override it (native: one
    /// norm-cached distance + one LUT/golden solve, O(K)).
    fn merge_score_pair(&mut self, svs: &SvStore, gamma: f64, i: usize, j: usize) -> ScoredPair {
        let row = self.merge_scores(svs, gamma, i);
        ScoredPair { wd: row.wd[j], h: row.h[j], a_z: row.a_z[j], d2: row.d2[j] }
    }

    /// Whether [`Backend::merge_score_pair`] is genuinely O(K) (one
    /// distance + one scorer solve) rather than the trait-default
    /// extract-a-lane-from-a-full-pass.  `MultiMerge` gates its
    /// multi-event prefetch on this: replaying a cached row patches one
    /// lane per freshly merged SV via `merge_score_pair`, which without
    /// the fast path is a full Θ(B·K) scoring pass per lane — making
    /// the "amortized" path asymptotically *slower* than the per-event
    /// rescans it replaces.  Backends that override
    /// `merge_score_pair` with a cheap primitive override this to
    /// `true`.
    fn has_cheap_pair_scoring(&self) -> bool {
        false
    }

    /// MM-GD (paper Alg. 2): merge `points` (with coefficients) into a
    /// single (z, a_z); returns the exact weight degradation as third.
    fn merge_gd(&mut self, points: &[(&[f32], f64)], gamma: f64) -> (Vec<f32>, f64, f64);
}

/// Exact weight degradation of replacing a set of (x, a) terms by a
/// single (z, a_z): ‖Σ a_i φ(x_i) − a_z φ(z)‖².  O(M²) kernel evals —
/// used for reporting and by MM-GD; M is small (≤ 16).
pub fn exact_multi_wd(points: &[(&[f32], f64)], z: &[f32], a_z: f64, gamma: f64) -> f64 {
    use crate::kernel::Kernel;
    let kern = crate::kernel::Gaussian::new(gamma);
    let mut norm2 = 0.0;
    for (i, (xi, ai)) in points.iter().enumerate() {
        norm2 += ai * ai;
        for (xj, aj) in points.iter().skip(i + 1) {
            norm2 += 2.0 * ai * aj * kern.eval(xi, xj);
        }
    }
    let mut cross = 0.0;
    for (xi, ai) in points {
        cross += ai * kern.eval(xi, z);
    }
    norm2 + a_z * a_z - 2.0 * a_z * cross
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_wd_zero_for_identity() {
        let x = [1.0f32, 2.0];
        let pts: Vec<(&[f32], f64)> = vec![(&x, 0.8)];
        let wd = exact_multi_wd(&pts, &x, 0.8, 1.0);
        assert!(wd.abs() < 1e-12);
    }

    #[test]
    fn exact_wd_matches_pair_formula() {
        let a = [0.0f32, 0.0];
        let b = [1.0f32, 0.0];
        let gamma = 0.7;
        let (a_i, a_j) = (0.5f64, 0.3f64);
        let pts: Vec<(&[f32], f64)> = vec![(&a, a_i), (&b, a_j)];
        // degrade to a_z = 0 at z far away -> wd = ||w_pair||^2
        let far = [100.0f32, 100.0];
        let wd = exact_multi_wd(&pts, &far, 0.0, gamma);
        let k = (-gamma * 1.0f64).exp();
        let want = a_i * a_i + a_j * a_j + 2.0 * a_i * a_j * k;
        assert!((wd - want).abs() < 1e-12);
    }
}

//! Hybrid backend: profile-driven routing between the AOT artifacts
//! (PJRT) and the native mirror.
//!
//! Measured on this testbed (EXPERIMENTS.md §Perf, `cargo bench --bench
//! hot_paths`):
//!
//! * **batched evaluation** (`margins`, 256-row chunks): XLA wins
//!   (1.46 ms vs 1.85 ms native at B=512, d=128) — the blocked MXU-style
//!   matmul in the Pallas margin kernel amortizes the PJRT call.
//! * **merge scoring** (`merge_scores`): native wins at every size on
//!   *CPU* (295 µs vs 1.4 ms at B=512) — the interpret-lowered golden
//!   section runs as a sequential HLO while-loop plus ~1 MB of literal
//!   marshalling per call.  On a real TPU the same artifact runs the B
//!   lanes on the VPU in lock-step; the CPU plugin gets no such win
//!   (DESIGN.md §Hardware-Adaptation).
//! * **single-point margin** (`margin1`): native (µs-scale PJRT dispatch
//!   exceeds the entire Θ(B·K) compute).
//! * **MM-GD** (`merge_gd`): native (tiny tile, same marshalling math).
//!
//! Routing below follows those measurements: XLA for batched eval,
//! native for everything per-event.  `XlaBackend` remains available as
//! a full-XLA backend (`--backend xla`) to exercise every artifact.

use super::{Backend, MergeScores, NativeBackend, ScoredPair, XlaBackend};
use crate::budget::lut::MergeScoreMode;
use crate::data::DenseMatrix;
use crate::model::SvStore;
use anyhow::Result;
use std::path::Path;

pub struct HybridBackend {
    native: NativeBackend,
    /// `None` when the AOT artifacts (or the `xla` feature) are absent —
    /// the deployment default must run with no external native deps, so
    /// construction degrades to all-native routing instead of failing.
    xla: Option<XlaBackend>,
}

impl HybridBackend {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let xla = match XlaBackend::new(artifact_dir) {
            Ok(x) => Some(x),
            Err(e) => {
                eprintln!("[hybrid] PJRT unavailable ({e}); routing everything native");
                None
            }
        };
        Ok(Self { native: NativeBackend::new(), xla })
    }

    pub fn from_default_dir() -> Result<Self> {
        Self::new(&super::ArtifactRegistry::default_dir())
    }

    pub fn xla(&self) -> Option<&XlaBackend> {
        self.xla.as_ref()
    }

    /// The artifact batch path, when it applies: batches of ≥ 64 rows
    /// with a matching AOT margins artifact.  The single routing
    /// predicate behind `margins` / `margins_into` /
    /// `margins_bounded_into` — one place to keep the threshold and
    /// the artifact lookup in sync.
    fn artifact_margins(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        queries: &DenseMatrix,
    ) -> Option<Vec<f64>> {
        let xla = self.xla.as_mut()?;
        if queries.rows() >= 64 && xla.registry().find_margins(svs.len(), svs.dim(), 256).is_some()
        {
            Some(xla.margins(svs, gamma, queries))
        } else {
            None
        }
    }
}

impl Backend for HybridBackend {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn set_merge_score_mode(&mut self, mode: MergeScoreMode) -> MergeScoreMode {
        // merge scoring always routes native (see module docs).
        self.native.set_merge_score_mode(mode)
    }

    fn set_threads(&mut self, threads: usize) -> usize {
        // The worker pool shards the native tile engine; the artifact
        // path runs PJRT's own parallelism and ignores the knob.
        self.native.set_threads(threads)
    }

    fn worker_spawns(&self) -> u64 {
        self.native.worker_spawns()
    }

    fn margins(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix) -> Vec<f64> {
        // Batched: the artifact's blocked matmul wins; tiny batches and
        // out-of-lattice budgets fall back to native.
        if let Some(v) = self.artifact_margins(svs, gamma, queries) {
            return v;
        }
        self.native.margins(svs, gamma, queries)
    }

    fn margins_into(&mut self, svs: &SvStore, gamma: f64, queries: &DenseMatrix, out: &mut [f64]) {
        // Same routing as `margins`; the artifact path still returns an
        // owned vector (PJRT owns the output literal), so only the
        // native branch gets the zero-copy write.
        if let Some(v) = self.artifact_margins(svs, gamma, queries) {
            out.copy_from_slice(&v);
            return;
        }
        self.native.margins_into(svs, gamma, queries, out)
    }

    fn margins_bounded_into(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        queries: &DenseMatrix,
        bounds: &crate::runtime::TileBounds,
        out: &mut [f64],
    ) {
        // Same routing again; only the native branch can consume the
        // prebuilt bounds.  NOTE: because big batches may take the
        // artifact path, serving through hybrid trades the native
        // path's load-invariant bit-parity for artifact speed (see
        // serve module docs); `mmbsgd serve` defaults to native.
        if let Some(v) = self.artifact_margins(svs, gamma, queries) {
            out.copy_from_slice(&v);
            return;
        }
        self.native.margins_bounded_into(svs, gamma, queries, bounds, out)
    }

    fn margin1(&mut self, svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
        self.native.margin1(svs, gamma, x)
    }

    fn merge_scores(&mut self, svs: &SvStore, gamma: f64, i: usize) -> MergeScores {
        self.native.merge_scores(svs, gamma, i)
    }

    fn merge_scores_into(&mut self, svs: &SvStore, gamma: f64, i: usize, out: &mut MergeScores) {
        self.native.merge_scores_into(svs, gamma, i, out)
    }

    fn merge_scores_batch(
        &mut self,
        svs: &SvStore,
        gamma: f64,
        cands: &[usize],
    ) -> Vec<MergeScores> {
        self.native.merge_scores_batch(svs, gamma, cands)
    }

    fn merge_score_pair(&mut self, svs: &SvStore, gamma: f64, i: usize, j: usize) -> ScoredPair {
        self.native.merge_score_pair(svs, gamma, i, j)
    }

    fn has_cheap_pair_scoring(&self) -> bool {
        self.native.has_cheap_pair_scoring()
    }

    fn merge_gd(&mut self, points: &[(&[f32], f64)], gamma: f64) -> (Vec<f32>, f64, f64) {
        self.native.merge_gd(points, gamma)
    }
}

//! `mmbsgd` — CLI launcher for multi-merge BSGD SVM training.
//!
//! Subcommands:
//!   train       train a model on a synthetic twin or a LIBSVM file
//!   evaluate    accuracy of a saved model on a dataset
//!   predict     label a LIBSVM file with a saved model
//!   experiment  regenerate a paper table/figure (table1, table2,
//!               fig1, fig2, fig3, fig4, fig5, all)
//!   artifacts   list the AOT artifact registry
//!
//! The argument parser is first-party (offline image: no clap); flags
//! are `--key value` or `--flag`.

use anyhow::{anyhow, bail, Context, Result};
use mmbsgd::budget::{MaintenanceKind, MergeScoreMode};
use mmbsgd::config::{BackendChoice, TomlDoc, TrainConfig};
use mmbsgd::coordinator::{build_backend, ProgressObserver};
use mmbsgd::data::synth::SynthSpec;
use mmbsgd::data::{libsvm, split, Split};
use mmbsgd::exp::{self, ExpOptions};
use mmbsgd::model::SvmModel;
use mmbsgd::runtime::Backend;
use mmbsgd::serve::Predictor;
use mmbsgd::solver::bsgd::{self, TrainOutput};
use mmbsgd::solver::{Checkpoint, TrainSession};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Minimal `--key value` / `--flag` argument map.
struct Args {
    cmd: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = argv[1.min(argv.len())..].iter().peekable();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?;
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    values.insert(key.to_string(), it.next().unwrap().clone());
                }
                _ => flags.push(key.to_string()),
            }
        }
        Ok(Self { cmd, values, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            Some(v) => v.parse::<T>().map_err(|_| anyhow!("bad --{key} value {v:?}")),
            None => Ok(default),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

fn load_split(args: &Args) -> Result<Split> {
    let scale: f64 = args.get_parse("scale", 0.05)?;
    let seed: u64 = args.get_parse("data-seed", 1)?;
    let name = args.get("dataset").unwrap_or("adult");
    if let Some(spec) = SynthSpec::by_name(name, scale) {
        return Ok(mmbsgd::data::synth::dataset(&spec, seed));
    }
    // Otherwise treat as a LIBSVM file path; hold out 25 % for testing
    // unless a --test file is given.
    let ds = libsvm::load(Path::new(name), None)
        .with_context(|| format!("--dataset {name:?} is neither a synth name nor a readable file"))?;
    if ds.is_empty() {
        bail!("--dataset {name:?} holds no samples");
    }
    if let Some(test_path) = args.get("test") {
        let test = libsvm::load(Path::new(test_path), Some(ds.dim()))?;
        Ok(Split { train: ds, test })
    } else {
        let n_test = ds.len() / 4;
        Ok(split::train_test(&ds, n_test, seed))
    }
}

fn train_config(args: &Args, split: &Split) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    // Dataset presets (Table 2 hyperparameters) when the name is synth.
    if let Some(spec) = args
        .get("dataset")
        .and_then(|n| SynthSpec::by_name(n, 1.0))
    {
        cfg.lambda = TrainConfig::lambda_from_c(spec.c, split.train.len());
        cfg.gamma = spec.gamma;
    }
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        cfg.apply_toml(&doc)?;
    }
    // CLI cost flags override a TOML `c = ...` key: clear the pending C
    // so resolve_c() cannot overwrite the explicit value below.
    if let Some(c) = args.get("c") {
        cfg.lambda = TrainConfig::lambda_from_c(c.parse()?, split.train.len());
        cfg.cost_c = None;
    }
    if let Some(l) = args.get("lambda") {
        cfg.lambda = l.parse()?;
        cfg.cost_c = None;
    }
    cfg.gamma = args.get_parse("gamma", cfg.gamma)?;
    cfg.budget = args.get_parse("budget", cfg.budget)?;
    cfg.mergees = args.get_parse("mergees", cfg.mergees)?;
    cfg.epochs = args.get_parse("epochs", cfg.epochs)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.eval_every = args.get_parse("eval-every", cfg.eval_every)?;
    if let Some(m) = args.get("maintenance") {
        cfg.maintenance =
            Some(MaintenanceKind::parse(m).with_context(|| format!("bad --maintenance {m:?}"))?);
    }
    if let Some(b) = args.get("backend") {
        cfg.backend =
            BackendChoice::parse(b).with_context(|| format!("bad --backend {b:?}"))?;
    }
    if let Some(m) = args.get("merge-score-mode") {
        cfg.merge_score_mode = MergeScoreMode::parse(m)
            .with_context(|| format!("bad --merge-score-mode {m:?} (exact|lut)"))?;
    }
    cfg.threads = args.get_parse("threads", cfg.threads)?;
    cfg.resolve_c(split.train.len());
    cfg.validate()?;
    Ok(cfg)
}

/// Report the worker-thread count actually in effect (the perf report
/// attribution line) and warn when the request oversubscribes the
/// machine — results are bit-identical either way, but wall-clock
/// numbers taken that way are not comparable.
fn report_threads(requested: usize, effective: usize) {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("[perf ] effective threads: {effective} (requested {requested}, available {avail})");
    if requested > avail {
        eprintln!(
            "[warn ] --threads {requested} exceeds available parallelism ({avail}); \
             workers will timeshare cores and wall-clock numbers are not attributable"
        );
    }
}

/// Drive a session over its remaining epochs, writing checkpoints to
/// `--checkpoint <path>` at the `--checkpoint-every <steps>` cadence
/// (0 = at epoch boundaries only, when a path is given).
fn run_session(
    mut sess: TrainSession<'_>,
    split: &Split,
    args: &Args,
) -> Result<TrainOutput> {
    let ckpt_path = args.get("checkpoint").map(PathBuf::from);
    let ckpt_every: u64 = args.get_parse("checkpoint-every", 0u64)?;
    if ckpt_every > 0 && ckpt_path.is_none() {
        bail!("--checkpoint-every requires --checkpoint <path>");
    }
    let mut obs = if args.has("quiet") {
        ProgressObserver::quiet()
    } else {
        ProgressObserver::new(1000)
    };
    let total_epochs = sess.config().epochs as u64;
    while sess.epochs_done() < total_epochs {
        let chunk = if ckpt_path.is_some() { ckpt_every } else { 0 };
        sess.run_epoch(&split.train, Some(&split.test), &mut obs, chunk)?;
        if let Some(p) = &ckpt_path {
            std::fs::write(p, sess.checkpoint())
                .with_context(|| format!("writing checkpoint {}", p.display()))?;
        }
    }
    Ok(sess.finish())
}

fn cmd_train(args: &Args) -> Result<()> {
    let split = load_split(args)?;
    let mut backend: Box<dyn Backend>;
    let sess = if let Some(rp) = args.get("resume") {
        let text = std::fs::read_to_string(rp)
            .with_context(|| format!("reading checkpoint {rp}"))?;
        let mut ck = Checkpoint::parse(&text)?;
        // allow extending the run: `--epochs` on resume overrides
        let epochs = args.get_parse("epochs", ck.config().epochs)?;
        ck.config_mut().epochs = epochs;
        // threads are an execution detail, not checkpointed state —
        // resumed results are bit-identical for any worker count
        let threads = args.get_parse("threads", ck.config().threads)?;
        ck.config_mut().threads = threads;
        backend = build_backend(ck.config().backend)?;
        report_threads(threads, backend.set_threads(threads));
        println!(
            "[resume] {rp}: step {} | epoch {}/{} | B={} M={} maint={}",
            ck.step(),
            ck.epochs_done(),
            ck.config().epochs,
            ck.config().budget,
            ck.config().mergees,
            ck.config().maintenance_kind().describe(),
        );
        ck.into_session(backend.as_mut())?
    } else {
        let cfg = train_config(args, &split)?;
        println!(
            "[train] {} train={} test={} d={} | B={} M={} maint={} score={} λ={:.3e} γ={} backend={:?}",
            split.train.name,
            split.train.len(),
            split.test.len(),
            split.train.dim(),
            cfg.budget,
            cfg.mergees,
            cfg.maintenance_kind().describe(),
            cfg.merge_score_mode.describe(),
            cfg.lambda,
            cfg.gamma,
            cfg.backend,
        );
        backend = build_backend(cfg.backend)?;
        report_threads(cfg.threads, backend.set_threads(cfg.threads));
        TrainSession::new(cfg, backend.as_mut())?
    };
    let out = run_session(sess, &split, args)?;
    let acc = bsgd::evaluate(&out.model, backend.as_mut(), &split.test);
    println!();
    println!(
        "[done ] {:.3}s | steps {} | violations {} | maint events {} | mean wd {:.3e}",
        out.train_seconds,
        out.steps,
        out.margin_violations,
        out.maintenance_events,
        out.mean_weight_degradation
    );
    println!(
        "[done ] merge fraction {:.1}% | SVs {} | test accuracy {:.2}%",
        100.0 * out.merge_fraction(),
        out.model.svs.len(),
        100.0 * acc
    );
    if let Some(path) = args.get("save") {
        out.model.save(Path::new(path))?;
        println!("[saved] {path}");
    }
    Ok(())
}

/// Build the serving handle: saved model + the requested backend
/// (`--backend`, default native), with `--threads` applied.  Returns
/// (predictor, requested threads, effective threads); `evaluate`
/// reports them, `predict` stays silent (its stdout is the
/// prediction stream).
fn load_predictor(args: &Args) -> Result<(Predictor, usize, usize)> {
    let model_path = args.get("model").context("--model required")?;
    let model = SvmModel::load(Path::new(model_path))?;
    let choice = match args.get("backend") {
        Some(b) => BackendChoice::parse(b).with_context(|| format!("bad --backend {b:?}"))?,
        None => BackendChoice::Native,
    };
    let mut served = Predictor::new(model, build_backend(choice)?)?;
    let requested: usize = args.get_parse("threads", 1)?;
    let effective = served.set_threads(requested);
    Ok((served, requested, effective))
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let (mut served, requested, effective) = load_predictor(args)?;
    report_threads(requested, effective);
    let split = load_split(args)?;
    let acc = served.accuracy(&split.test)?;
    println!(
        "[eval ] model {} ({} SVs) on {}: accuracy {:.2}%",
        args.get("model").unwrap_or("?"),
        served.n_svs(),
        split.test.name,
        100.0 * acc
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let input = args.get("input").context("--input required")?;
    let (mut served, _requested, _effective) = load_predictor(args)?;
    let ds = libsvm::load(Path::new(input), Some(served.dim()))?;
    // one batched margins call — the serving hot path — not n single-row scans
    let decisions = served.decision_batch(&ds.x)?;
    for f in decisions {
        println!("{} {f:.6}", if f >= 0.0 { "+1" } else { "-1" });
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.get("id").or_else(|| args.get("name")).unwrap_or("all");
    let opts = ExpOptions {
        scale: args.get_parse("scale", 0.05)?,
        threads: args.get_parse("threads", exp::common::default_threads())?,
        out_dir: PathBuf::from(args.get("out-dir").unwrap_or("results")),
        backend: BackendChoice::parse(args.get("backend").unwrap_or("native"))
            .context("bad --backend")?,
        seed: args.get_parse("seed", 1)?,
        epochs: args.get_parse("epochs", 1)?,
    };
    let run = |id: &str| -> Result<()> {
        match id {
            "table1" => exp::table1::run(&opts),
            "table2" => exp::table2::run(&opts),
            "fig1" => exp::fig1::run(&opts),
            "fig2" => exp::fig2_3::run_figure(&opts, 2),
            "fig3" => exp::fig2_3::run_figure(&opts, 3),
            "fig4" => exp::fig4::run(&opts),
            "fig5" => exp::fig5::run(&opts),
            "ablation" => exp::ablation::run(&opts),
            other => bail!("unknown experiment {other:?}"),
        }
    };
    if which == "all" {
        for id in ["table2", "table1", "fig1", "fig2", "fig3", "fig4", "fig5", "ablation"] {
            run(id)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

fn cmd_tune(args: &Args) -> Result<()> {
    let split = load_split(args)?;
    let parse_grid = |key: &str, default: Vec<f64>| -> Result<Vec<f64>> {
        match args.get(key) {
            Some(s) => s
                .split(',')
                .map(|t| t.parse::<f64>().map_err(|_| anyhow!("bad --{key} item {t:?}")))
                .collect(),
            None => Ok(default),
        }
    };
    let params = mmbsgd::solver::tune::TuneParams {
        c_grid: parse_grid("c-grid", vec![1.0, 4.0, 16.0, 64.0])?,
        gamma_grid: parse_grid("gamma-grid", vec![0.01, 0.1, 1.0, 10.0])?,
        folds: args.get_parse("folds", 5)?,
        base: TrainConfig {
            budget: args.get_parse("budget", 128)?,
            mergees: args.get_parse("mergees", 4)?,
            ..TrainConfig::default()
        },
        exact: args.has("exact"),
        seed: args.get_parse("seed", 1)?,
    };
    println!(
        "[tune ] grid {}x{} with {}-fold CV on {} ({} pts)",
        params.c_grid.len(),
        params.gamma_grid.len(),
        params.folds,
        split.train.name,
        split.train.len()
    );
    let cells = mmbsgd::solver::tune::grid_search(&split.train, &params)?;
    for cell in &cells {
        println!("  C={:<8} gamma={:<8} cv acc {:.2}%", cell.c, cell.gamma, 100.0 * cell.cv_accuracy);
    }
    let best = cells.first().context("empty tuning grid")?;
    println!("[best ] C={} gamma={} ({:.2}%)", best.c, best.gamma, 100.0 * best.cv_accuracy);
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> Result<()> {
    let dir = mmbsgd::runtime::ArtifactRegistry::default_dir();
    let reg = mmbsgd::runtime::ArtifactRegistry::load(&dir)?;
    println!("artifact dir: {}", reg.dir.display());
    for a in &reg.artifacts {
        println!(
            "  {:32} entry={:12} b_pad={:5} d_pad={:4} nb={:4} m_pad={}",
            a.name, a.entry, a.b_pad, a.d_pad, a.nb, a.m_pad
        );
    }
    println!("{} artifacts", reg.artifacts.len());
    Ok(())
}

const HELP: &str = "\
mmbsgd — multi-merge budgeted SGD SVM training (Qaadan & Glasmachers 2018)

USAGE: mmbsgd <command> [--flags]

COMMANDS
  train        --dataset <synth-name|libsvm-path> [--scale F] [--budget N]
               [--mergees M] [--maintenance removal|projection|merge[:M]|mergegd[:M]]
               [--backend native|xla|hybrid] [--merge-score-mode lut|exact]
               [--c F | --lambda F] [--gamma F] [--threads N]
               [--epochs N] [--seed N] [--eval-every N] [--config file.toml]
               [--save model.txt] [--test libsvm-path] [--quiet]
               [--checkpoint ckpt.txt] [--checkpoint-every STEPS]
               [--resume ckpt.txt]
               checkpoints capture ALL state (RNG, budget counters, the
               in-flight epoch): a resumed run is bit-identical to an
               uninterrupted one.  --resume reads config + backend from
               the checkpoint (same --dataset flags required; --epochs
               may be raised to extend the run).
  evaluate     --model model.txt --dataset <...> [--scale F] [--backend B]
               [--threads N]
  predict      --model model.txt --input data.libsvm [--backend B] [--threads N]
  experiment   --id table1|table2|fig1|fig2|fig3|fig4|fig5|ablation|all
               [--scale F] [--threads N] [--out-dir DIR] [--backend B] [--seed N]
  tune         --dataset <...> [--c-grid 1,4,16] [--gamma-grid 0.1,1,10]
               [--folds N] [--budget N] [--mergees M] [--exact]
  artifacts    (list the AOT artifact registry)

Synth dataset names: phishing, web, adult, ijcnn, skin (statistical twins
of the paper's LIBSVM datasets; see DESIGN.md §3).
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    let res = match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "evaluate" => cmd_evaluate(&args),
        "predict" => cmd_predict(&args),
        "experiment" => cmd_experiment(&args),
        "tune" => cmd_tune(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

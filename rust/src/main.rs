//! `mmbsgd` — CLI launcher for multi-merge BSGD SVM training.
//!
//! Subcommands:
//!   train       train a model on a synthetic twin or a LIBSVM file
//!   evaluate    accuracy of a saved model on a dataset
//!   predict     label a LIBSVM file with a saved model
//!   experiment  regenerate a paper table/figure (table1, table2,
//!               fig1, fig2, fig3, fig4, fig5, all)
//!   loadgen     sustained-traffic harness against a serve endpoint
//!   artifacts   list the AOT artifact registry
//!   package     wrap a trained model into a versioned fleet artifact
//!   verify      re-check a fleet artifact's checksums and shape
//!   fleet       push | rollback | status | route across replicas
//!
//! The argument parser is first-party (offline image: no clap); flags
//! are `--key value` or `--flag`.

use anyhow::{anyhow, bail, Context, Result};
use mmbsgd::budget::{MaintenanceKind, MergeScoreMode};
use mmbsgd::config::{BackendChoice, FleetConfig, ServeConfig, TomlDoc, TrainConfig};
use mmbsgd::kernel::{simd, ExpMode, SimdMode};
use mmbsgd::coordinator::{build_backend, ProgressObserver};
use mmbsgd::data::synth::SynthSpec;
use mmbsgd::data::{libsvm, split, Split};
use mmbsgd::exp::{self, ExpOptions};
use mmbsgd::fleet::{run_router, Artifact, Controller, Provenance, ReplicaState, RouterOptions};
use mmbsgd::model::SvmModel;
use mmbsgd::runtime::Backend;
use mmbsgd::serve::{self, ModelRegistry, Predictor, RouteSpec, ServeOptions, ShedPolicy};
use mmbsgd::solver::bsgd::{self, TrainOutput};
use mmbsgd::solver::{load_checkpoint, TrainSession};
use mmbsgd::util::{durable, fault};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Minimal `--key value` / `--flag` argument map.  Values keep their
/// command-line order and repeats: `get` returns the last occurrence
/// (later flags override earlier ones), `get_all` every occurrence
/// (`serve` takes one `--model` per loaded model).
struct Args {
    cmd: String,
    values: Vec<(String, String)>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut values = Vec::new();
        let mut flags = Vec::new();
        let mut it = argv[1.min(argv.len())..].iter().peekable();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}"))?;
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    values.push((key.to_string(), it.next().unwrap().clone()));
                }
                _ => flags.push(key.to_string()),
            }
        }
        Ok(Self { cmd, values, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.values.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            Some(v) => v.parse::<T>().map_err(|_| anyhow!("bad --{key} value {v:?}")),
            None => Ok(default),
        }
    }

    fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

fn load_split(args: &Args) -> Result<Split> {
    let scale: f64 = args.get_parse("scale", 0.05)?;
    let seed: u64 = args.get_parse("data-seed", 1)?;
    let name = args.get("dataset").unwrap_or("adult");
    if let Some(spec) = SynthSpec::by_name(name, scale) {
        return Ok(mmbsgd::data::synth::dataset(&spec, seed));
    }
    // Otherwise treat as a LIBSVM file path; hold out 25 % for testing
    // unless a --test file is given.
    let ds = libsvm::load(Path::new(name), None)
        .with_context(|| format!("--dataset {name:?} is neither a synth name nor a readable file"))?;
    if ds.is_empty() {
        bail!("--dataset {name:?} holds no samples");
    }
    if let Some(test_path) = args.get("test") {
        let test = libsvm::load(Path::new(test_path), Some(ds.dim()))?;
        Ok(Split { train: ds, test })
    } else {
        let n_test = ds.len() / 4;
        Ok(split::train_test(&ds, n_test, seed))
    }
}

fn train_config(args: &Args, split: &Split) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    // Dataset presets (Table 2 hyperparameters) when the name is synth.
    if let Some(spec) = args
        .get("dataset")
        .and_then(|n| SynthSpec::by_name(n, 1.0))
    {
        cfg.lambda = TrainConfig::lambda_from_c(spec.c, split.train.len());
        cfg.gamma = spec.gamma;
    }
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        cfg.apply_toml(&doc)?;
        install_fault_plan(&doc)?;
    }
    // CLI cost flags override a TOML `c = ...` key: clear the pending C
    // so resolve_c() cannot overwrite the explicit value below.
    if let Some(c) = args.get("c") {
        cfg.lambda = TrainConfig::lambda_from_c(c.parse()?, split.train.len());
        cfg.cost_c = None;
    }
    if let Some(l) = args.get("lambda") {
        cfg.lambda = l.parse()?;
        cfg.cost_c = None;
    }
    cfg.gamma = args.get_parse("gamma", cfg.gamma)?;
    cfg.budget = args.get_parse("budget", cfg.budget)?;
    cfg.mergees = args.get_parse("mergees", cfg.mergees)?;
    cfg.epochs = args.get_parse("epochs", cfg.epochs)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    cfg.eval_every = args.get_parse("eval-every", cfg.eval_every)?;
    if let Some(m) = args.get("maintenance") {
        cfg.maintenance =
            Some(MaintenanceKind::parse(m).with_context(|| format!("bad --maintenance {m:?}"))?);
    }
    if let Some(b) = args.get("backend") {
        cfg.backend =
            BackendChoice::parse(b).with_context(|| format!("bad --backend {b:?}"))?;
    }
    if let Some(m) = args.get("merge-score-mode") {
        cfg.merge_score_mode = MergeScoreMode::parse(m)
            .with_context(|| format!("bad --merge-score-mode {m:?} (exact|lut)"))?;
    }
    cfg.threads = args.get_parse("threads", cfg.threads)?;
    if let Some(mode) = parse_simd_flag(args)? {
        cfg.simd_mode = mode;
    }
    if let Some(mode) = parse_exp_flag(args)? {
        cfg.exp_mode = mode;
    }
    cfg.resolve_c(split.train.len());
    cfg.validate()?;
    Ok(cfg)
}

/// Parse a `--simd-mode` flag if present (`None` = flag absent) — the
/// single home of the accepted values and the error wording.
fn parse_simd_flag(args: &Args) -> Result<Option<SimdMode>> {
    match args.get("simd-mode") {
        Some(s) => SimdMode::parse(s)
            .map(Some)
            .with_context(|| format!("bad --simd-mode {s:?} (auto|scalar)")),
        None => Ok(None),
    }
}

/// Parse an `--exp-mode` flag if present (`None` = flag absent) —
/// same single-home convention as [`parse_simd_flag`].
fn parse_exp_flag(args: &Args) -> Result<Option<ExpMode>> {
    match args.get("exp-mode") {
        Some(s) => ExpMode::parse(s)
            .map(Some)
            .with_context(|| format!("bad --exp-mode {s:?} (libm|vector)")),
        None => Ok(None),
    }
}

/// Install a `[fault] plan = "site@N=kind[:arg];..."` injection plan
/// from a config file (fault-injection test builds only).  The plan is
/// parsed in every build so typos fail loudly; without the
/// `fault-inject` feature it is then dropped with a warning rather
/// than silently ignored.  `MMBSGD_FAULT_PLAN` in the environment is
/// picked up lazily by the sites themselves and needs no wiring here.
fn install_fault_plan(doc: &TomlDoc) -> Result<()> {
    let Some(v) = doc.get("fault", "plan") else { return Ok(()) };
    let text = v.as_str().context("[fault] plan must be a string")?;
    let plan = fault::FaultPlan::parse(text).map_err(|e| anyhow!("[fault] plan: {e}"))?;
    if fault::ENABLED {
        eprintln!("[fault] plan armed: {text}");
        fault::install(plan);
    } else {
        eprintln!(
            "[warn ] [fault] plan ignored: this binary was built without the \
             `fault-inject` feature (rebuild with --features fault-inject to arm it)"
        );
    }
    Ok(())
}

/// Apply a `--simd-mode` flag (default: the config's value) to the
/// process-wide kernel dispatch.  `MMBSGD_FORCE_SCALAR` overrides both
/// (handled inside the kernel); results are bit-identical either way.
fn apply_simd_mode(args: &Args, default: SimdMode) -> Result<()> {
    simd::set_mode(parse_simd_flag(args)?.unwrap_or(default));
    Ok(())
}

/// Apply an `--exp-mode` flag (default: the config's value) to the
/// process-wide exponent dispatch.  `MMBSGD_FORCE_LIBM` overrides both
/// (handled inside the kernel).
fn apply_exp_mode(args: &Args, default: ExpMode) -> Result<()> {
    simd::set_exp_mode(parse_exp_flag(args)?.unwrap_or(default));
    Ok(())
}

/// Report the worker-thread count actually in effect plus the SIMD ISA
/// and pool dispatch mode (the perf attribution lines), and warn when
/// the request oversubscribes the machine — results are bit-identical
/// either way, but wall-clock numbers taken that way are not
/// comparable.
fn report_threads(requested: usize, effective: usize) {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("[perf ] effective threads: {effective} (requested {requested}, available {avail})");
    let pool = if effective > 1 {
        format!("persistent x{effective} ({} parked workers)", effective - 1)
    } else {
        "inline".to_string()
    };
    println!(
        "[perf ] simd isa: {} (mode {}) | exp: {} | pool: {pool}",
        simd::active_isa().describe(),
        simd::mode().describe(),
        simd::exp_mode().describe(),
    );
    if requested > avail {
        eprintln!(
            "[warn ] --threads {requested} exceeds available parallelism ({avail}); \
             workers will timeshare cores and wall-clock numbers are not attributable"
        );
    }
}

/// Steps between wall-clock checks when only `--checkpoint-secs` sets
/// the cadence: small enough that a due checkpoint is at most a few
/// hundred Θ(B·K) steps late, large enough that `Instant::now` noise
/// never shows.
const CKPT_SECS_PROBE_STEPS: u64 = 512;

/// Drive a session over its remaining epochs, writing checkpoints to
/// `--checkpoint <path>` on two independent cadences: every
/// `--checkpoint-every <steps>` steps and/or every `--checkpoint-secs
/// <secs>` of wall clock, whichever fires first (plus every epoch
/// boundary).  With neither cadence flag, a given path writes at epoch
/// boundaries only.  The wall clock is only consulted at step-chunk
/// boundaries, so a secs-cadence write can be late by up to
/// `min(checkpoint-every, CKPT_SECS_PROBE_STEPS)` steps — cadences are
/// best-effort lower bounds, never mid-step interrupts.
fn run_session(
    mut sess: TrainSession<'_>,
    split: &Split,
    args: &Args,
) -> Result<TrainOutput> {
    let ckpt_path = args.get("checkpoint").map(PathBuf::from);
    let ckpt_every: u64 = args.get_parse("checkpoint-every", 0u64)?;
    let ckpt_secs: u64 = args.get_parse("checkpoint-secs", 0u64)?;
    if (ckpt_every > 0 || ckpt_secs > 0) && ckpt_path.is_none() {
        bail!("--checkpoint-every/--checkpoint-secs require --checkpoint <path>");
    }
    let mut obs = if args.has("quiet") {
        ProgressObserver::quiet()
    } else {
        ProgressObserver::new(1000)
    };
    // Epoch-chunk length: the step cadence when it is the only one;
    // capped by the wall-clock probe when --checkpoint-secs needs the
    // clock checked more often than --checkpoint-every steps.
    let chunk = match (ckpt_every, ckpt_secs) {
        (0, 0) => 0,
        (e, 0) => e,
        (0, _) => CKPT_SECS_PROBE_STEPS,
        (e, _) => e.min(CKPT_SECS_PROBE_STEPS),
    };
    let total_epochs = sess.config().epochs as u64;
    let mut last_write = Instant::now();
    let mut last_write_step = sess.steps();
    while sess.epochs_done() < total_epochs {
        let epoch_done = sess.run_epoch(&split.train, Some(&split.test), &mut obs, chunk)?;
        if let Some(p) = &ckpt_path {
            let due_steps = ckpt_every > 0 && sess.steps() - last_write_step >= ckpt_every;
            let due_secs = ckpt_secs > 0 && last_write.elapsed().as_secs() >= ckpt_secs;
            if epoch_done || due_steps || due_secs {
                // Atomic replace with checksum footer and a `.prev`
                // generation — a crash mid-write can never lose the
                // last good checkpoint.  A failed write is a warning,
                // not a fatal error: training state is intact and the
                // previous generation is still on disk.
                match durable::write_atomic(p, &sess.checkpoint()) {
                    Ok(()) => {
                        last_write = Instant::now();
                        last_write_step = sess.steps();
                    }
                    Err(e) => eprintln!(
                        "[warn ] checkpoint write to {} failed ({e}); training \
                         continues, previous generation kept",
                        p.display()
                    ),
                }
            }
        }
    }
    Ok(sess.finish())
}

fn cmd_train(args: &Args) -> Result<()> {
    let split = load_split(args)?;
    let mut backend: Box<dyn Backend>;
    let sess = if let Some(rp) = args.get("resume") {
        // Verified load: checksum footer checked, automatic fallback
        // to the `.prev` generation when the primary is corrupt, and a
        // typed CorruptCheckpoint (section + byte offset + whether a
        // fallback existed) when both generations fail.
        let loaded = load_checkpoint(Path::new(rp))?;
        if loaded.generation == durable::Generation::Prev {
            eprintln!(
                "[warn ] {rp}: primary checkpoint failed verification ({}); \
                 resuming from the .prev generation — up to one checkpoint \
                 interval of progress is repeated, results stay bit-identical",
                loaded.primary_error.as_deref().unwrap_or("unreadable"),
            );
        }
        let mut ck = loaded.checkpoint;
        // allow extending the run: `--epochs` on resume overrides
        let epochs = args.get_parse("epochs", ck.config().epochs)?;
        ck.config_mut().epochs = epochs;
        // threads and SIMD dispatch are execution details, not
        // checkpointed state — resumed results are bit-identical for
        // any worker count and any ISA (the session re-applies the
        // config values, so the flags go through the config)
        let threads = args.get_parse("threads", ck.config().threads)?;
        ck.config_mut().threads = threads;
        if let Some(mode) = parse_simd_flag(args)? {
            ck.config_mut().simd_mode = mode;
        }
        if let Some(mode) = parse_exp_flag(args)? {
            ck.config_mut().exp_mode = mode;
        }
        simd::set_mode(ck.config().simd_mode);
        simd::set_exp_mode(ck.config().exp_mode);
        backend = build_backend(ck.config().backend)?;
        report_threads(threads, backend.set_threads(threads));
        println!(
            "[resume] {rp}: step {} | epoch {}/{} | B={} M={} maint={}",
            ck.step(),
            ck.epochs_done(),
            ck.config().epochs,
            ck.config().budget,
            ck.config().mergees,
            ck.config().maintenance_kind().describe(),
        );
        ck.into_session(backend.as_mut())?
    } else {
        let cfg = train_config(args, &split)?;
        println!(
            "[train] {} train={} test={} d={} | B={} M={} maint={} score={} λ={:.3e} γ={} backend={:?}",
            split.train.name,
            split.train.len(),
            split.test.len(),
            split.train.dim(),
            cfg.budget,
            cfg.mergees,
            cfg.maintenance_kind().describe(),
            cfg.merge_score_mode.describe(),
            cfg.lambda,
            cfg.gamma,
            cfg.backend,
        );
        simd::set_mode(cfg.simd_mode);
        simd::set_exp_mode(cfg.exp_mode);
        backend = build_backend(cfg.backend)?;
        report_threads(cfg.threads, backend.set_threads(cfg.threads));
        TrainSession::new(cfg, backend.as_mut())?
    };
    let out = run_session(sess, &split, args)?;
    let acc = bsgd::evaluate(&out.model, backend.as_mut(), &split.test);
    println!();
    println!(
        "[done ] {:.3}s | steps {} | violations {} | maint events {} | mean wd {:.3e}",
        out.train_seconds,
        out.steps,
        out.margin_violations,
        out.maintenance_events,
        out.mean_weight_degradation
    );
    println!(
        "[done ] merge fraction {:.1}% | SVs {} | test accuracy {:.2}%",
        100.0 * out.merge_fraction(),
        out.model.svs.len(),
        100.0 * acc
    );
    if let Some(path) = args.get("save") {
        out.model.save(Path::new(path))?;
        println!("[saved] {path}");
    }
    Ok(())
}

/// Build the serving handle: saved model + the requested backend
/// (`--backend`, default native), with `--threads` applied.  Returns
/// (predictor, requested threads, effective threads); `evaluate`
/// reports them, `predict` stays silent (its stdout is the
/// prediction stream).
fn load_predictor(args: &Args) -> Result<(Predictor, usize, usize)> {
    apply_simd_mode(args, SimdMode::Auto)?;
    apply_exp_mode(args, ExpMode::Libm)?;
    let model_path = args.get("model").context("--model required")?;
    let model = SvmModel::load(Path::new(model_path))?;
    let choice = match args.get("backend") {
        Some(b) => BackendChoice::parse(b).with_context(|| format!("bad --backend {b:?}"))?,
        None => BackendChoice::Native,
    };
    let mut served = Predictor::new(model, build_backend(choice)?)?;
    let requested: usize = args.get_parse("threads", 1)?;
    let effective = served.set_threads(requested);
    Ok((served, requested, effective))
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let (mut served, requested, effective) = load_predictor(args)?;
    report_threads(requested, effective);
    let split = load_split(args)?;
    let acc = served.accuracy(&split.test)?;
    println!(
        "[eval ] model {} ({} SVs) on {}: accuracy {:.2}%",
        args.get("model").unwrap_or("?"),
        served.n_svs(),
        split.test.name,
        100.0 * acc
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let input = args.get("input").context("--input required")?;
    let (mut served, _requested, _effective) = load_predictor(args)?;
    let ds = libsvm::load(Path::new(input), Some(served.dim()))?;
    // one batched margins call — the serving hot path — not n single-row scans
    let decisions = served.decision_batch(&ds.x)?;
    for f in decisions {
        println!("{} {f:.6}", if f >= 0.0 { "+1" } else { "-1" });
    }
    Ok(())
}

/// Parse one `--model name=path[:weight]` spec.  The weight suffix is
/// recognized only when the text after the last `:` parses as a u32,
/// so paths containing colons still load (with weight 1).
fn parse_model_spec(spec: &str) -> Result<(String, String, u32)> {
    let (name, rest) = spec
        .split_once('=')
        .with_context(|| format!("--model wants name=path[:weight], got {spec:?}"))?;
    if name.is_empty() {
        bail!("--model {spec:?}: empty model name");
    }
    let (path, weight) = match rest.rsplit_once(':') {
        Some((p, w)) if !p.is_empty() && w.parse::<u32>().is_ok() => {
            (p, w.parse::<u32>().expect("checked"))
        }
        _ => (rest, 1),
    };
    if weight == 0 {
        bail!("--model {spec:?}: weight must be >= 1");
    }
    Ok((name.to_string(), path.to_string(), weight))
}

/// True when `host:port` names a loopback interface.  `0.0.0.0` / `::`
/// bind every interface and are deliberately NOT loopback: they are
/// exactly the case the auth requirement exists for.
fn is_loopback_addr(addr: &str) -> bool {
    let host = match addr.rsplit_once(':') {
        Some((h, _)) => h,
        None => addr,
    };
    let host = host.trim_start_matches('[').trim_end_matches(']');
    host == "localhost" || host == "::1" || host.starts_with("127.")
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut scfg = ServeConfig::default();
    // The replica-side artifact-GC depth comes from the same [fleet]
    // TOML section the controller tools read (`keep`), overridable by
    // --fleet-keep below; only consulted when --fleet-dir is given.
    let mut fleet_keep = FleetConfig::default().keep;
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        scfg.apply_toml(&doc)?;
        let mut fcfg = FleetConfig::default();
        fcfg.apply_toml(&doc)?;
        fleet_keep = fcfg.keep;
        install_fault_plan(&doc)?;
    }
    if let Some(a) = args.get("addr") {
        scfg.addr = a.to_string();
    }
    scfg.batch_max = args.get_parse("batch-max", scfg.batch_max)?;
    scfg.queue_max = args.get_parse("queue-max", scfg.queue_max)?;
    if let Some(s) = args.get("shed") {
        scfg.shed =
            ShedPolicy::parse(s).with_context(|| format!("bad --shed {s:?} (reject|oldest)"))?;
    }
    scfg.monitor_window = args.get_parse("monitor-window", scfg.monitor_window)?;
    scfg.idle_timeout_secs = args.get_parse("idle-timeout-secs", scfg.idle_timeout_secs)?;
    scfg.max_line_bytes = args.get_parse("max-line-bytes", scfg.max_line_bytes)?;
    scfg.max_conns = args.get_parse("max-conns", scfg.max_conns)?;
    scfg.deadline_ms = args.get_parse("deadline-ms", scfg.deadline_ms)?;
    if let Some(a) = args.get("http-addr") {
        scfg.http_addr = a.to_string();
    }
    scfg.max_body_bytes = args.get_parse("max-body-bytes", scfg.max_body_bytes)?;
    if let Some(t) = args.get("auth-token") {
        scfg.auth_token = t.to_string();
    }
    scfg.threads = args.get_parse("threads", scfg.threads)?;
    if let Some(mode) = parse_simd_flag(args)? {
        scfg.simd_mode = mode;
    }
    if let Some(mode) = parse_exp_flag(args)? {
        scfg.exp_mode = mode;
    }
    scfg.seed = args.get_parse("seed", scfg.seed)?;
    scfg.validate()?;
    // Auth gate before any socket binds: a listener on a non-loopback
    // interface is reachable from the network and must not serve
    // unauthenticated traffic.
    if scfg.auth_token.is_empty() {
        for (flag, addr) in [("--addr", &scfg.addr), ("--http-addr", &scfg.http_addr)] {
            if !addr.is_empty() && !is_loopback_addr(addr) {
                bail!(
                    "{flag} {addr} binds a non-loopback interface; set --auth-token (or \
                     [serve] auth_token) so the socket is not open to unauthenticated peers"
                );
            }
        }
    }
    simd::set_mode(scfg.simd_mode);
    simd::set_exp_mode(scfg.exp_mode);

    let fleet_dir = args.get("fleet-dir").map(PathBuf::from);
    fleet_keep = args.get_parse("fleet-keep", fleet_keep)?;
    if fleet_keep == 0 {
        bail!("--fleet-keep must be >= 1 (the active generation is always kept)");
    }
    let specs = args.get_all("model");
    if specs.is_empty() && fleet_dir.is_none() {
        bail!("serve needs at least one --model name=path[:weight] (or --fleet-dir DIR)");
    }
    let choice = match args.get("backend") {
        Some(b) => BackendChoice::parse(b).with_context(|| format!("bad --backend {b:?}"))?,
        None => BackendChoice::Native,
    };
    if choice != BackendChoice::Native {
        eprintln!(
            "[warn ] --backend {choice:?}: backends that route big batches to AOT artifacts \
             answer with artifact arithmetic, so replies are no longer bit-identical across \
             batch sizes (native keeps that guarantee)"
        );
    }
    let mut registry = ModelRegistry::new(build_backend(choice)?, scfg.seed);
    let mut arms = Vec::new();
    for spec in specs {
        let (name, path, weight) = parse_model_spec(spec)?;
        let model = SvmModel::load(Path::new(&path))?;
        let version = registry.insert(&name, model)?;
        println!(
            "[serve] loaded {name}@v{version} from {path} (weight {weight}, {} SVs)",
            registry.n_svs_of(&name)?
        );
        arms.push((name, weight));
    }
    // A fleet replica may boot with no --model at all (artifacts arrive
    // over push-artifact); with no explicit route the registry routes
    // uniformly over whatever is loaded.
    if !arms.is_empty() {
        registry.set_route(RouteSpec::new(arms)?)?;
    }
    let mut replica = match &fleet_dir {
        Some(dir) => {
            let mut rep = ReplicaState::new(dir)?.with_keep(fleet_keep);
            let (recovered, failed) = rep.recover(&mut registry);
            for (name, version) in &recovered {
                println!("[fleet] recovered {name}@v{version} from {}", dir.display());
            }
            for (path, e) in &failed {
                eprintln!("[warn ] {}: unusable artifact skipped: {e}", path.display());
            }
            Some(rep)
        }
        None => None,
    };
    let effective = registry.set_threads(scfg.threads);
    report_threads(scfg.threads, effective);

    let listener = std::net::TcpListener::bind(&scfg.addr)
        .with_context(|| format!("binding {}", scfg.addr))?;
    let http_listener = match scfg.http_addr.as_str() {
        "" => None,
        a => {
            let l =
                std::net::TcpListener::bind(a).with_context(|| format!("binding http {a}"))?;
            println!(
                "[serve] http on {} (POST /predict|/decision, GET /metrics, GET /healthz)",
                l.local_addr()?
            );
            Some(l)
        }
    };
    println!(
        "[serve] listening on {} | batch_max={} queue_max={} shed={} window={} seed={} auth={} \
         (send 'shutdown' to stop)",
        listener.local_addr()?,
        scfg.batch_max,
        scfg.queue_max,
        scfg.shed.describe(),
        scfg.monitor_window,
        scfg.seed,
        if scfg.auth_token.is_empty() { "off" } else { "token" },
    );
    let opts = ServeOptions {
        batch_max: scfg.batch_max,
        queue_max: scfg.queue_max,
        shed: scfg.shed,
        monitor_window: scfg.monitor_window,
        idle_timeout: Duration::from_secs(scfg.idle_timeout_secs),
        max_line_bytes: scfg.max_line_bytes,
        max_conns: scfg.max_conns,
        deadline: Duration::from_millis(scfg.deadline_ms),
        max_artifact_bytes: args
            .get_parse("max-artifact-bytes", ServeOptions::default().max_artifact_bytes)?,
        max_body_bytes: scfg.max_body_bytes,
        auth_token: scfg.auth_token.clone(),
    };
    let report = match replica.as_mut() {
        Some(rep) => serve::serve_fleet_bound(listener, http_listener, registry, &opts, rep)?,
        None => serve::serve_bound(listener, http_listener, registry, &opts)?,
    };
    let mean_batch = if report.engine.batches > 0 {
        report.engine.rows as f64 / report.engine.batches as f64
    } else {
        0.0
    };
    println!(
        "[serve] done: {} connections | served {} | shed {} | {} batches (mean {:.2} rows) | \
         low-margin {:.1}%",
        report.connections,
        report.engine.served,
        report.engine.shed,
        report.engine.batches,
        mean_batch,
        100.0 * report.drift.low_margin_fraction,
    );
    println!(
        "[serve] degrade: expired {} | idle timeouts {} | oversize {} | busy {}",
        report.engine.expired,
        report.proto.idle_timeouts,
        report.proto.oversize_lines,
        report.proto.busy_rejected,
    );
    if let Some(acc) = report.drift.window_accuracy {
        println!(
            "[serve] feedback window: {:.2}% over {} labelled requests",
            100.0 * acc,
            report.drift.feedback_seen
        );
    }
    Ok(())
}

/// Count one loadgen reply line into the ok / shed / error tallies.
/// Shed is the server's explicit load-management answer (queue full /
/// shed); everything else non-`ok` is an error.
fn classify_reply(
    reply: &str,
    ok: &std::sync::atomic::AtomicU64,
    shed: &std::sync::atomic::AtomicU64,
    errs: &std::sync::atomic::AtomicU64,
) {
    use std::sync::atomic::Ordering::Relaxed;
    if reply.starts_with("ok") {
        ok.fetch_add(1, Relaxed);
    } else if reply.contains("queue full") || reply.contains("request shed") {
        shed.fetch_add(1, Relaxed);
    } else {
        errs.fetch_add(1, Relaxed);
    }
}

/// What one loadgen phase (one rate step, or the whole run when no
/// ramp is set) measured.
struct LoadgenPhase {
    ok: u64,
    shed: u64,
    errs: u64,
    elapsed_secs: f64,
    snap: mmbsgd::telemetry::HistogramSnapshot,
}

impl LoadgenPhase {
    fn completed(&self) -> u64 {
        self.ok + self.shed + self.errs
    }

    fn achieved_rps(&self) -> f64 {
        self.completed() as f64 / self.elapsed_secs.max(1e-9)
    }

    fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.completed().max(1) as f64
    }
}

/// `mmbsgd loadgen`: sustained-traffic harness against a running
/// serve endpoint (or the fleet router — `--mode router` speaks the
/// same line protocol but labels its bench rows `router/*`).  M
/// closed-loop workers each own one connection (line protocol or HTTP
/// keep-alive), replay N keyed `decision` requests (optionally paced
/// to a target aggregate rate, or stepped through a
/// `--rate-ramp START:STEP:N` profile), measure per-request
/// round-trip latency into the same [`mmbsgd::telemetry::Histogram`]
/// the server uses, and emit `BENCH_serve.json` in the
/// `mmbsgd-bench-v1` shape `scripts/perf_compare.sh` gates.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use mmbsgd::rng::Xoshiro256;
    use mmbsgd::telemetry::Histogram;
    use mmbsgd::util::json::{obj, to_string, Json};
    use std::fmt::Write as _;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicU64, Ordering};

    let target = args.get("target").context("loadgen needs --target host:port")?.to_string();
    let mode = args.get("mode").unwrap_or("line").to_string();
    if mode != "line" && mode != "http" && mode != "router" {
        bail!("bad --mode {mode:?} (line|http|router)");
    }
    // bench-row family: `router/*` when driving the fleet router so
    // the router artifact never collides with the serve one
    let prefix = if mode == "router" { "router" } else { "serve" };
    let requests: usize = args.get_parse("requests", 10_000)?;
    let workers: usize = args.get_parse("workers", 2)?;
    if requests == 0 || workers == 0 {
        bail!("--requests and --workers must be >= 1");
    }
    let rate: f64 = args.get_parse("rate", 0.0)?;
    if !(rate >= 0.0 && rate.is_finite()) {
        bail!("--rate must be a finite non-negative requests/second");
    }
    // --rate-ramp START:STEP:N — N phases of `--requests` each, phase
    // i paced at START + i*STEP req/s
    let ramp: Option<(f64, f64, usize)> = match args.get("rate-ramp") {
        Some(spec) => {
            let parts: Vec<&str> = spec.split(':').collect();
            let bad = || anyhow!("bad --rate-ramp {spec:?} (want START:STEP:N, e.g. 200:200:4)");
            if parts.len() != 3 {
                return Err(bad());
            }
            let start: f64 = parts[0].parse().map_err(|_| bad())?;
            let step: f64 = parts[1].parse().map_err(|_| bad())?;
            let n: usize = parts[2].parse().map_err(|_| bad())?;
            if !(start > 0.0 && start.is_finite() && step.is_finite() && step >= 0.0 && n >= 1) {
                return Err(bad());
            }
            if rate > 0.0 {
                bail!("--rate and --rate-ramp are mutually exclusive");
            }
            Some((start, step, n))
        }
        None => None,
    };
    let dim: usize = args.get_parse("dim", 0)?;
    if dim == 0 {
        bail!("loadgen needs --dim <feature count> matching the served model");
    }
    let keys: usize = args.get_parse("keys", 64)?.max(1);
    let out = args.get("out").unwrap_or("BENCH_serve.json").to_string();
    let auth = args.get("auth-token").unwrap_or("").to_string();
    if mode == "router" && !auth.is_empty() {
        bail!("--auth-token is a replica-level verb; the router does not authenticate");
    }
    let seed: u64 = args.get_parse("seed", 1)?;

    // the all-phases histogram behind the aggregate rows (each
    // request observes into its phase histogram *and* this one)
    let total_hist = Histogram::new();

    // One complete closed-loop pass: fresh workers, fresh
    // connections, its own histogram — so each ramp step measures a
    // steady state, not a blend with the previous rate.
    let run_phase = |phase_rate: f64, phase_seed: u64| -> Result<LoadgenPhase> {
        let hist = Histogram::new();
        let ok = AtomicU64::new(0);
        let shed = AtomicU64::new(0);
        let errs = AtomicU64::new(0);
        let started = Instant::now();
        // Aggregate pacing split evenly: each worker sends every
        // `workers/rate` seconds, so the fleet of workers sums to
        // `phase_rate`.
        let interval = if phase_rate > 0.0 {
            Duration::from_secs_f64(workers as f64 / phase_rate)
        } else {
            Duration::ZERO
        };
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for w in 0..workers {
                let (hist, total_hist, ok, shed, errs) = (&hist, &total_hist, &ok, &shed, &errs);
                let (target, auth, mode) = (target.clone(), auth.clone(), mode.clone());
                handles.push(s.spawn(move || -> Result<()> {
                    // Worker w owns requests w, w+M, w+2M, ...
                    let n_mine =
                        if w < requests { (requests - w - 1) / workers + 1 } else { 0 };
                    let mut rng = Xoshiro256::new(phase_seed ^ ((w as u64 + 1) * 0x9E37_79B9));
                    let stream = TcpStream::connect(&target)
                        .with_context(|| format!("worker {w}: connecting {target}"))?;
                    let _ = stream.set_nodelay(true);
                    let mut rd = BufReader::new(stream.try_clone()?);
                    let mut wtr = stream;
                    let mut reply = String::new();
                    if mode == "line" && !auth.is_empty() {
                        wtr.write_all(format!("auth {auth}\n").as_bytes())?;
                        reply.clear();
                        rd.read_line(&mut reply)?;
                        if !reply.starts_with("ok") {
                            bail!("worker {w}: auth rejected: {}", reply.trim());
                        }
                    }
                    let mut body = String::new();
                    for i in 0..n_mine {
                        if !interval.is_zero() {
                            let due = started + interval.mul_f64(i as f64)
                                + interval.mul_f64(w as f64 / workers as f64);
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                        }
                        body.clear();
                        write!(body, "key=k{}", (w + i * workers) % keys)
                            .expect("string write");
                        for _ in 0..dim {
                            write!(body, " {:.4}", rng.next_f64() * 2.0 - 1.0)
                                .expect("string write");
                        }
                        body.push('\n');
                        if mode != "http" {
                            let t0 = Instant::now();
                            wtr.write_all(format!("decision {body}").as_bytes())?;
                            reply.clear();
                            if rd.read_line(&mut reply)? == 0 {
                                bail!("worker {w}: server closed the connection");
                            }
                            let dt = t0.elapsed();
                            hist.observe_duration(dt);
                            total_hist.observe_duration(dt);
                            classify_reply(reply.trim(), ok, shed, errs);
                        } else {
                            let auth_hdr = if auth.is_empty() {
                                String::new()
                            } else {
                                format!("Authorization: Bearer {auth}\r\n")
                            };
                            let req = format!(
                                "POST /decision HTTP/1.1\r\nContent-Length: {}\r\n\
                                 {auth_hdr}\r\n{body}",
                                body.len()
                            );
                            let t0 = Instant::now();
                            wtr.write_all(req.as_bytes())?;
                            reply.clear();
                            if rd.read_line(&mut reply)? == 0 {
                                bail!("worker {w}: server closed the connection");
                            }
                            let status: u16 = reply
                                .split_ascii_whitespace()
                                .nth(1)
                                .and_then(|s| s.parse().ok())
                                .with_context(|| {
                                    format!("worker {w}: bad status line {:?}", reply.trim())
                                })?;
                            let mut content_length = 0usize;
                            loop {
                                reply.clear();
                                if rd.read_line(&mut reply)? == 0 {
                                    bail!("worker {w}: connection died mid-headers");
                                }
                                let h = reply.trim();
                                if h.is_empty() {
                                    break;
                                }
                                let lower = h.to_ascii_lowercase();
                                if let Some(v) = lower.strip_prefix("content-length:") {
                                    content_length = v.trim().parse().with_context(|| {
                                        format!("worker {w}: bad content-length {h:?}")
                                    })?;
                                }
                            }
                            let mut resp_body = vec![0u8; content_length];
                            rd.read_exact(&mut resp_body)?;
                            let dt = t0.elapsed();
                            hist.observe_duration(dt);
                            total_hist.observe_duration(dt);
                            match status {
                                200 => classify_reply(
                                    String::from_utf8_lossy(&resp_body).trim(),
                                    ok,
                                    shed,
                                    errs,
                                ),
                                503 => {
                                    shed.fetch_add(1, Ordering::Relaxed);
                                }
                                _ => {
                                    errs.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow!("loadgen worker panicked"))??;
            }
            Ok(())
        })?;
        Ok(LoadgenPhase {
            ok: ok.load(Ordering::Relaxed),
            shed: shed.load(Ordering::Relaxed),
            errs: errs.load(Ordering::Relaxed),
            elapsed_secs: started.elapsed().as_secs_f64(),
            snap: hist.snapshot(),
        })
    };

    let rates: Vec<f64> = match ramp {
        Some((start, step, n)) => (0..n).map(|i| start + step * i as f64).collect(),
        None => vec![rate],
    };
    println!(
        "[loadgen] {requests} {mode} decision requests{} -> {target} | {workers} workers | {} | \
         dim {dim} | {keys} keys",
        if rates.len() > 1 { format!(" x {} ramp steps", rates.len()) } else { String::new() },
        if rates[0] > 0.0 {
            format!("{:.0} req/s target", rates[0])
        } else {
            "unpaced".into()
        },
    );

    let mut phases: Vec<LoadgenPhase> = Vec::with_capacity(rates.len());
    for (i, &r) in rates.iter().enumerate() {
        if rates.len() > 1 {
            println!("[loadgen] ramp step {}/{}: {r:.0} req/s", i + 1, rates.len());
        }
        // distinct seed per step so a ramp never replays identical
        // bodies while staying reproducible from --seed
        let phase = run_phase(r, seed.wrapping_add(i as u64))?;
        if rates.len() > 1 {
            println!(
                "[loadgen]   step {}: {} requests in {:.2}s ({:.0} req/s) | shed {:.2}% | \
                 p50 {:.3}ms p99 {:.3}ms",
                i + 1,
                phase.completed(),
                phase.elapsed_secs,
                phase.achieved_rps(),
                100.0 * phase.shed_rate(),
                phase.snap.quantile(0.50) as f64 / 1e6,
                phase.snap.quantile(0.99) as f64 / 1e6,
            );
        }
        phases.push(phase);
    }

    let (ok, shed, errs) = phases
        .iter()
        .fold((0u64, 0u64, 0u64), |(a, b, c), p| (a + p.ok, b + p.shed, c + p.errs));
    let completed = ok + shed + errs;
    let elapsed_secs: f64 = phases.iter().map(|p| p.elapsed_secs).sum();
    let achieved_rps = completed as f64 / elapsed_secs.max(1e-9);
    let snap = total_hist.snapshot();
    let (p50, p90, p99) = (snap.quantile(0.50), snap.quantile(0.90), snap.quantile(0.99));
    let shed_rate = shed as f64 / completed.max(1) as f64;
    let error_rate = errs as f64 / completed.max(1) as f64;
    println!(
        "[loadgen] done: {completed} requests in {elapsed_secs:.2}s ({achieved_rps:.0} req/s) | \
         ok {ok} | shed {shed} ({:.2}%) | errors {errs} ({:.2}%)",
        100.0 * shed_rate,
        100.0 * error_rate,
    );
    println!(
        "[loadgen] latency: p50 {:.3}ms | p90 {:.3}ms | p99 {:.3}ms (mean {:.3}ms)",
        p50 as f64 / 1e6,
        p90 as f64 / 1e6,
        p99 as f64 / 1e6,
        snap.mean() / 1e6,
    );

    let mut rows: Vec<(String, f64)> = vec![
        (format!("{prefix}/p50_ns"), p50 as f64),
        (format!("{prefix}/p90_ns"), p90 as f64),
        (format!("{prefix}/p99_ns"), p99 as f64),
        (format!("{prefix}/achieved_rps"), achieved_rps),
        (format!("{prefix}/shed_rate"), shed_rate),
        (format!("{prefix}/error_rate"), error_rate),
        (format!("{prefix}/requests"), completed as f64),
        (format!("{prefix}/workers"), workers as f64),
    ];
    if rates.len() > 1 {
        for (i, phase) in phases.iter().enumerate() {
            let step = format!("{prefix}/ramp{}", i + 1);
            rows.push((format!("{step}/p50_ns"), phase.snap.quantile(0.50) as f64));
            rows.push((format!("{step}/p99_ns"), phase.snap.quantile(0.99) as f64));
            rows.push((format!("{step}/shed_rate"), phase.shed_rate()));
            rows.push((format!("{step}/achieved_rps"), phase.achieved_rps()));
        }
    }
    let derived: Vec<Json> = rows
        .into_iter()
        .map(|(k, v)| obj(vec![("name", Json::Str(k)), ("value", Json::Num(v))]))
        .collect();
    let note = match ramp {
        Some((start, step, n)) => format!(
            "mmbsgd loadgen --mode {mode} --rate-ramp {start}:{step}:{n} against {target}"
        ),
        None => format!("mmbsgd loadgen --mode {mode} against {target}"),
    };
    let doc = obj(vec![
        ("schema", Json::Str("mmbsgd-bench-v1".into())),
        ("note", Json::Str(note)),
        ("runs", Json::Arr(Vec::new())),
        ("derived", Json::Arr(derived)),
    ]);
    std::fs::write(&out, to_string(&doc)).with_context(|| format!("writing {out}"))?;
    println!("[loadgen] wrote {out}");
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.get("id").or_else(|| args.get("name")).unwrap_or("all");
    let opts = ExpOptions {
        scale: args.get_parse("scale", 0.05)?,
        threads: args.get_parse("threads", exp::common::default_threads())?,
        out_dir: PathBuf::from(args.get("out-dir").unwrap_or("results")),
        backend: BackendChoice::parse(args.get("backend").unwrap_or("native"))
            .context("bad --backend")?,
        seed: args.get_parse("seed", 1)?,
        epochs: args.get_parse("epochs", 1)?,
    };
    let run = |id: &str| -> Result<()> {
        match id {
            "table1" => exp::table1::run(&opts),
            "table2" => exp::table2::run(&opts),
            "fig1" => exp::fig1::run(&opts),
            "fig2" => exp::fig2_3::run_figure(&opts, 2),
            "fig3" => exp::fig2_3::run_figure(&opts, 3),
            "fig4" => exp::fig4::run(&opts),
            "fig5" => exp::fig5::run(&opts),
            "ablation" => exp::ablation::run(&opts),
            other => bail!("unknown experiment {other:?}"),
        }
    };
    if which == "all" {
        for id in ["table2", "table1", "fig1", "fig2", "fig3", "fig4", "fig5", "ablation"] {
            run(id)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

fn cmd_tune(args: &Args) -> Result<()> {
    let split = load_split(args)?;
    let parse_grid = |key: &str, default: Vec<f64>| -> Result<Vec<f64>> {
        match args.get(key) {
            Some(s) => s
                .split(',')
                .map(|t| t.parse::<f64>().map_err(|_| anyhow!("bad --{key} item {t:?}")))
                .collect(),
            None => Ok(default),
        }
    };
    let params = mmbsgd::solver::tune::TuneParams {
        c_grid: parse_grid("c-grid", vec![1.0, 4.0, 16.0, 64.0])?,
        gamma_grid: parse_grid("gamma-grid", vec![0.01, 0.1, 1.0, 10.0])?,
        folds: args.get_parse("folds", 5)?,
        base: TrainConfig {
            budget: args.get_parse("budget", 128)?,
            mergees: args.get_parse("mergees", 4)?,
            ..TrainConfig::default()
        },
        exact: args.has("exact"),
        seed: args.get_parse("seed", 1)?,
    };
    println!(
        "[tune ] grid {}x{} with {}-fold CV on {} ({} pts)",
        params.c_grid.len(),
        params.gamma_grid.len(),
        params.folds,
        split.train.name,
        split.train.len()
    );
    let cells = mmbsgd::solver::tune::grid_search(&split.train, &params)?;
    for cell in &cells {
        println!("  C={:<8} gamma={:<8} cv acc {:.2}%", cell.c, cell.gamma, 100.0 * cell.cv_accuracy);
    }
    let best = cells.first().context("empty tuning grid")?;
    println!("[best ] C={} gamma={} ({:.2}%)", best.c, best.gamma, 100.0 * best.cv_accuracy);
    Ok(())
}

fn cmd_artifacts(_args: &Args) -> Result<()> {
    let dir = mmbsgd::runtime::ArtifactRegistry::default_dir();
    let reg = mmbsgd::runtime::ArtifactRegistry::load(&dir)?;
    println!("artifact dir: {}", reg.dir.display());
    for a in &reg.artifacts {
        println!(
            "  {:32} entry={:12} b_pad={:5} d_pad={:4} nb={:4} m_pad={}",
            a.name, a.entry, a.b_pad, a.d_pad, a.nb, a.m_pad
        );
    }
    println!("{} artifacts", reg.artifacts.len());
    Ok(())
}

fn cmd_package(args: &Args) -> Result<()> {
    let model_path = args.get("model").context("--model required")?;
    let out = args.get("out").context("--out required")?;
    let name = args.get("name").unwrap_or("champ");
    let version: u64 = args.get_parse("artifact-version", 1u64)?;
    let model = SvmModel::load(Path::new(model_path))?;
    // Provenance records the trained config; --config points at the
    // TOML the model was trained with (defaults otherwise).
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.get("config") {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        cfg.apply_toml(&doc)?;
    }
    let provenance = Provenance::from_config(&cfg);
    let artifact = Artifact::wrap(
        name,
        version,
        &model,
        provenance,
        cfg.merge_score_mode.describe(),
        cfg.simd_mode.describe(),
    )?;
    artifact.save(Path::new(out))?;
    println!(
        "[package] {name}@v{version} -> {out} (dim={} nsv={} {} bytes)",
        artifact.dim,
        artifact.nsv,
        artifact.to_text().len()
    );
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let path = args.get("artifact").context("--artifact required")?;
    let artifact = Artifact::load(Path::new(path))?;
    // full re-verification: durable footer and section checksums were
    // checked by load; cross-check the model against the manifest too
    let _model = artifact.validate_model()?;
    println!(
        "[verify] ok {}@v{} dim={} nsv={} scorer={} simd={}",
        artifact.name, artifact.version, artifact.dim, artifact.nsv, artifact.scorer, artifact.simd
    );
    for (k, v) in &artifact.provenance.pairs {
        println!("[verify]   provenance {k}={v}");
    }
    Ok(())
}

/// The `[fleet]` config: TOML `--config` file first, CLI flags on top.
fn fleet_config(args: &Args) -> Result<FleetConfig> {
    let mut fcfg = FleetConfig::default();
    if let Some(path) = args.get("config") {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        fcfg.apply_toml(&doc)?;
        install_fault_plan(&doc)?;
    }
    if let Some(r) = args.get("replicas") {
        fcfg.replicas = r.to_string();
    }
    if let Some(a) = args.get("addr") {
        fcfg.addr = a.to_string();
    }
    fcfg.seed = args.get_parse("seed", fcfg.seed)?;
    fcfg.vnodes = args.get_parse("vnodes", fcfg.vnodes)?;
    fcfg.probe_secs = args.get_parse("probe-secs", fcfg.probe_secs)?;
    fcfg.push_timeout_ms = args.get_parse("push-timeout-ms", fcfg.push_timeout_ms)?;
    fcfg.min_window_acc = args.get_parse("min-window-acc", fcfg.min_window_acc)?;
    fcfg.keep = args.get_parse("fleet-keep", fcfg.keep)?;
    fcfg.router_pool = args.get_parse("router-pool", fcfg.router_pool)?;
    fcfg.router_threads = args.get_parse("router-threads", fcfg.router_threads)?;
    if let Some(d) = args.get("dir") {
        fcfg.dir = d.to_string();
    }
    fcfg.validate()?;
    Ok(fcfg)
}

/// `mmbsgd fleet <op> [--flags]` — the op is the one bare positional
/// token the CLI accepts, so `fleet` re-parses its own argv.
fn cmd_fleet(argv: &[String]) -> Result<()> {
    let op = argv
        .get(1)
        .map(String::as_str)
        .context("fleet needs an operation: push | rollback | status | route")?;
    let args = Args::parse(&argv[1..])?;
    let fcfg = fleet_config(&args)?;
    let replicas = fcfg.replica_list();
    let timeout = Duration::from_millis(fcfg.push_timeout_ms);
    let need_replicas = || -> Result<()> {
        if replicas.is_empty() {
            bail!("no replicas: set --replicas host:port,host:port (or [fleet] replicas)");
        }
        Ok(())
    };
    // Per-replica outcomes print one line each; any failure exits 1
    // after the whole fleet has been attempted (partial convergence is
    // visible, not hidden behind the first error).
    let mut failures = 0usize;
    match op {
        "push" => {
            need_replicas()?;
            let path = args.get("artifact").context("--artifact required")?;
            let artifact = Artifact::load(Path::new(path))?;
            artifact.validate_model()?;
            let mut ctl = Controller::new(replicas, timeout);
            let activate = args.has("activate");
            for out in ctl.push(&artifact, activate) {
                match out.result {
                    Ok(v) => println!(
                        "[fleet] {}: {} {}@v{v}",
                        out.endpoint,
                        if activate { "active" } else { "staged" },
                        artifact.name
                    ),
                    Err(e) => {
                        failures += 1;
                        eprintln!("[fleet] {}: FAILED: {e}", out.endpoint);
                    }
                }
            }
        }
        "rollback" => {
            need_replicas()?;
            let name = args.get("name").context("--name required")?;
            let mut ctl = Controller::new(replicas, timeout);
            for out in ctl.rollback(name) {
                match out.result {
                    Ok(v) => println!("[fleet] {}: rolled back {name} to v{v}", out.endpoint),
                    Err(e) => {
                        failures += 1;
                        eprintln!("[fleet] {}: FAILED: {e}", out.endpoint);
                    }
                }
            }
        }
        "status" => {
            need_replicas()?;
            let mut ctl = Controller::new(replicas, timeout);
            // unreachable replicas are `dead` rows in the status
            // table (what the router sees), not command failures
            for out in ctl.status() {
                match out.result {
                    Ok(line) => println!("[fleet] {}: {line}", out.endpoint),
                    Err(e) => println!("[fleet] {}: dead ({e})", out.endpoint),
                }
            }
            // the auto-rollback hook: --name + min_window_acc > 0
            if fcfg.min_window_acc > 0.0 {
                if let Some(name) = args.get("name") {
                    match ctl.maybe_auto_rollback(name, fcfg.min_window_acc) {
                        Some(outs) => {
                            eprintln!(
                                "[fleet] accuracy window below {}: auto-rollback of {name}",
                                fcfg.min_window_acc
                            );
                            for out in outs {
                                match out.result {
                                    Ok(v) => println!(
                                        "[fleet] {}: rolled back {name} to v{v}",
                                        out.endpoint
                                    ),
                                    Err(e) => {
                                        failures += 1;
                                        eprintln!("[fleet] {}: FAILED: {e}", out.endpoint);
                                    }
                                }
                            }
                        }
                        None => println!(
                            "[fleet] fleet healthy (window accuracy >= {})",
                            fcfg.min_window_acc
                        ),
                    }
                }
            }
        }
        "route" => {
            need_replicas()?;
            let listener = std::net::TcpListener::bind(&fcfg.addr)
                .with_context(|| format!("binding {}", fcfg.addr))?;
            println!(
                "[fleet] router on {} -> {} replicas (seed={} vnodes={} pool={} threads={}; \
                 send 'shutdown' to stop the router)",
                listener.local_addr()?,
                replicas.len(),
                fcfg.seed,
                fcfg.vnodes,
                fcfg.router_pool,
                fcfg.router_threads,
            );
            let opts = RouterOptions {
                seed: fcfg.seed,
                vnodes: fcfg.vnodes,
                timeout,
                probe_every: Duration::from_secs(fcfg.probe_secs),
                pool: fcfg.router_pool,
                threads: fcfg.router_threads,
            };
            let report = run_router(listener, replicas, &opts)?;
            println!(
                "[fleet] router done: {} connections | forwarded {} | retried {} | rejected {} \
                 | links {} | pool_waits {} | pipelined {}",
                report.connections,
                report.forwarded,
                report.retried,
                report.rejected,
                report.links_opened,
                report.pool_waits,
                report.pipelined,
            );
        }
        other => bail!("unknown fleet operation {other:?} (push | rollback | status | route)"),
    }
    if failures > 0 {
        bail!("{failures} replica operation(s) failed");
    }
    Ok(())
}

const HELP: &str = "\
mmbsgd — multi-merge budgeted SGD SVM training (Qaadan & Glasmachers 2018)

USAGE: mmbsgd <command> [--flags]

COMMANDS
  train        --dataset <synth-name|libsvm-path> [--scale F] [--budget N]
               [--mergees M] [--maintenance removal|projection|merge[:M]|mergegd[:M]]
               [--backend native|xla|hybrid] [--merge-score-mode lut|exact]
               [--c F | --lambda F] [--gamma F] [--threads N]
               [--simd-mode auto|scalar] [--exp-mode libm|vector]
               [--epochs N] [--seed N] [--eval-every N] [--config file.toml]
               [--save model.txt] [--test libsvm-path] [--quiet]
               [--checkpoint ckpt.txt] [--checkpoint-every STEPS]
               [--checkpoint-secs SECS] [--resume ckpt.txt]
               checkpoints capture ALL state (RNG, budget counters, the
               in-flight epoch): a resumed run is bit-identical to an
               uninterrupted one.  --resume reads config + backend from
               the checkpoint (same --dataset flags required; --epochs
               may be raised to extend the run).  --checkpoint-every
               (steps) and --checkpoint-secs (wall clock) are
               independent cadences: whichever fires first writes; the
               clock is checked at step-chunk boundaries.  Writes are
               atomic (temp file + fsync + rename) with a checksum
               footer and a .prev last-good generation; --resume
               verifies the checksum and falls back to .prev when the
               primary is torn or corrupt.
  evaluate     --model model.txt --dataset <...> [--scale F] [--backend B]
               [--threads N] [--simd-mode auto|scalar] [--exp-mode libm|vector]
  predict      --model model.txt --input data.libsvm [--backend B] [--threads N]
               [--simd-mode auto|scalar] [--exp-mode libm|vector]
  serve        --model name=model.txt[:weight] [--model b=other.txt:1 ...]
               [--addr host:port] [--http-addr host:port] [--batch-max N]
               [--queue-max N] [--shed reject|oldest] [--monitor-window N]
               [--threads N] [--idle-timeout-secs N] [--max-line-bytes N]
               [--max-conns N] [--deadline-ms N] [--max-body-bytes N]
               [--auth-token TOKEN]
               [--simd-mode auto|scalar] [--exp-mode libm|vector]
               [--seed N] [--backend B]
               [--config file.toml] [--fleet-dir DIR] [--fleet-keep N]
               [--max-artifact-bytes N]
               long-lived TCP line-protocol server: micro-batched
               predict/decision, weighted deterministic A/B routing
               across the named models (same key => same model),
               swap-model hot reload, stats drift report; newline
               commands, 'shutdown' stops the server (in-flight
               requests are answered before the socket closes).  TOML
               keys live in a [serve] section; flags override the file.
               Degradation guards: idle connections are closed after
               --idle-timeout-secs (0 = never), lines over
               --max-line-bytes answer a typed error, connections past
               --max-conns answer 'err busy', and requests queued
               longer than --deadline-ms (0 = no deadline) answer
               'err deadline'.  A [fault] plan = \"site@N=kind\" TOML
               section (or MMBSGD_FAULT_PLAN) arms deterministic fault
               injection in --features fault-inject builds.
               --http-addr adds an HTTP/1.1 front end on a second port:
               POST /predict|/decision carry line-protocol argument
               bodies (one request per line) through the same batch
               engine, GET /metrics renders the telemetry registry,
               GET /healthz answers 200 ok; bodies over
               --max-body-bytes answer 413.  --auth-token (or [serve]
               auth_token) arms shared-secret auth — line connections
               must open with 'auth <token>', HTTP requests must carry
               'Authorization: Bearer <token>' — and is REQUIRED when
               --addr or --http-addr binds a non-loopback interface.
  loadgen      --target host:port --dim N [--mode line|http|router]
               [--requests N] [--workers M] [--rate RPS] [--keys K]
               [--rate-ramp START:STEP:N] [--auth-token TOKEN]
               [--seed N] [--out BENCH_serve.json]
               sustained-traffic harness: M closed-loop workers replay
               N keyed decision requests against a running serve
               endpoint (line protocol or HTTP keep-alive) or the
               fleet router (--mode router: same line protocol, bench
               rows labelled router/*), paced to an aggregate --rate
               (0 = as fast as replies return) or stepped through
               --rate-ramp (N phases of --requests each at START,
               START+STEP, ... req/s, one ramp<i>/p50_ns,p99_ns,
               shed_rate,achieved_rps row group per step), measure
               per-request round-trip latency, and write p50/p90/p99,
               achieved rps, and shed/error rates to --out in the
               BENCH_hotpaths.json shape so scripts/perf_compare.sh
               can sanity-gate them.
  experiment   --id table1|table2|fig1|fig2|fig3|fig4|fig5|ablation|all
               [--scale F] [--threads N] [--out-dir DIR] [--backend B] [--seed N]
  tune         --dataset <...> [--c-grid 1,4,16] [--gamma-grid 0.1,1,10]
               [--folds N] [--budget N] [--mergees M] [--exact]
  artifacts    (list the AOT artifact registry)
  package      --model model.txt --out champ.artifact [--name NAME]
               [--artifact-version N] [--config file.toml]
               wrap a trained model into a versioned fleet artifact: a
               self-verifying bundle (manifest + per-section checksums
               + durable footer) carrying trained-config provenance.
  verify       --artifact champ.artifact
               re-check an artifact's checksums and manifest-vs-model
               shape; tampered or truncated bundles exit 1 with a typed
               error naming the failing section.
  fleet        push     --artifact A [--activate]
               rollback --name NAME
               status   [--name NAME]  (with min-window-acc > 0: the
                        auto-rollback hook — a replica whose feedback
                        accuracy window degrades below the threshold
                        triggers a fleet-wide rollback to last-good;
                        unreachable replicas print as dead rows, not
                        command failures)
               route    (consistent-hash router in front of the fleet:
                        one worker per client connection, --router-pool
                        links per replica (default 2) with pipelined
                        same-replica runs, --router-threads bounding
                        forwards in flight (0 = unbounded); the
                        router-stats verb answers router_* telemetry)
               shared flags: --replicas host:port,host:port --seed N
               --vnodes N --probe-secs N --push-timeout-ms N
               --min-window-acc F --addr host:port --router-pool N
               --router-threads N --config file.toml
               ([fleet] TOML section; flags override the file).
               Replica side: mmbsgd serve --fleet-dir DIR enables the
               push-artifact/activate/rollback/fleet-status verbs and
               recovers activated artifacts from DIR at startup
               (falling back to the .prev last-good generation when a
               primary is corrupt).  Every activation archives the
               generation as <name>.artifact.v<N>; --fleet-keep N (or
               [fleet] keep, default 3) bounds how many generations
               per model survive garbage collection.

`--exp-mode vector` evaluates e^-x with the fixed-degree polynomial
substrate (bit-identical across ISAs and thread counts, <= 1e-6
relative error vs libm); `libm` (default) keeps the platform exp.
MMBSGD_FORCE_LIBM overrides the flag and the TOML key.

Synth dataset names: phishing, web, adult, ijcnn, skin (statistical twins
of the paper's LIBSVM datasets; see DESIGN.md §3).
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // `fleet <op>` takes one bare positional the strict --flag parser
    // would reject; dispatch it before the general parse.
    if argv.first().map(String::as_str) == Some("fleet") {
        if let Err(e) = cmd_fleet(&argv) {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    let res = match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "evaluate" => cmd_evaluate(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "experiment" => cmd_experiment(&args),
        "tune" => cmd_tune(&args),
        "artifacts" => cmd_artifacts(&args),
        "package" => cmd_package(&args),
        "verify" => cmd_verify(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

//! A small TOML-subset parser (offline substrate — the `toml` crate is
//! not vendored in this image).
//!
//! Supported: `[section]` headers, `key = value` with string
//! (`"..."`), boolean, integer/float, and flat arrays of those.
//! Comments (`# ...`) and blank lines are skipped.  This covers every
//! config file this project ships; anything fancier errors loudly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Section name → ordered (key, value) pairs.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, Vec<(String, TomlValue)>>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut doc = TomlDoc::default();
        let mut current = String::new(); // root section = ""
        for (n, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: n + 1, msg: msg.to_string() };
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            doc.sections.entry(current.clone()).or_default().push((key, value));
        }
        Ok(doc)
    }

    pub fn section(&self, name: &str) -> Option<&[(String, TomlValue)]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections
            .get(section)?
            .iter()
            .rev() // later assignments win
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        if body.contains('"') {
            return Err("embedded quotes unsupported".into());
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = body
            .split(',')
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(TomlValue::Arr(items));
    }
    s.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            "# top comment\nroot_key = 1\n[a]\nx = 1.5 # trailing\ns = \"hi # not comment\"\n\
             flag = true\narr = [1, 2, 3]\n[b]\ny = -2\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "root_key").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("a", "x").unwrap().as_f64(), Some(1.5));
        assert_eq!(doc.get("a", "s").unwrap().as_str(), Some("hi # not comment"));
        assert_eq!(doc.get("a", "flag").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("a", "arr").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(doc.get("b", "y").unwrap().as_f64(), Some(-2.0));
    }

    #[test]
    fn later_assignment_wins() {
        let doc = TomlDoc::parse("[s]\nk = 1\nk = 2\n").unwrap();
        assert_eq!(doc.get("s", "k").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("[ok]\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("k = \"open\n").is_err());
        assert!(TomlDoc::parse("k = [1, 2\n").is_err());
    }

    #[test]
    fn empty_doc_and_empty_array() {
        let doc = TomlDoc::parse("").unwrap();
        assert!(doc.section("x").is_none());
        let doc = TomlDoc::parse("k = []\n").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_arr().unwrap().len(), 0);
    }
}

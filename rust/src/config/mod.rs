//! Typed configuration + a small TOML-subset loader.
//!
//! Runs are configured three ways, later layers overriding earlier ones:
//! built-in dataset presets (Table 2 hyperparameters) → a config file
//! (TOML subset: sections, strings, numbers, booleans) → CLI flags.
//! The experiment drivers construct configs programmatically.

mod toml;
pub use toml::{TomlDoc, TomlError, TomlValue};

use crate::budget::{MaintenanceKind, MergeScoreMode};
use crate::error::TrainError;
use crate::kernel::{ExpMode, SimdMode};
use crate::serve::ShedPolicy;
use anyhow::{bail, Context, Result};

/// Which compute backend executes the numeric hot paths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackendChoice {
    /// Pure-rust mirror (no artifacts needed).
    Native,
    /// AOT artifacts through PJRT.
    Xla,
    /// XLA for the merge-scoring pass (the Θ(B·K·G) artifact) and batch
    /// evaluation; native for per-step single margins, where PJRT call
    /// overhead exceeds the compute.  The deployment default.
    Hybrid,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "native" => Some(Self::Native),
            "xla" => Some(Self::Xla),
            "hybrid" => Some(Self::Hybrid),
            _ => None,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            Self::Native => "native",
            Self::Xla => "xla",
            Self::Hybrid => "hybrid",
        }
    }
}

/// Full training configuration for one BSGD run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Regularization λ of the primal objective (= 1/(n·C)).
    pub lambda: f64,
    /// Gaussian-kernel bandwidth γ.
    pub gamma: f64,
    /// Budget size B.
    pub budget: usize,
    /// Number of mergees M (paper: 2..11; 2 = classic BSGD).
    pub mergees: usize,
    /// Maintenance strategy; `None` derives `Merge { m: mergees }`.
    pub maintenance: Option<MaintenanceKind>,
    /// Passes over the training data (paper uses 1).
    pub epochs: usize,
    /// Learning-rate schedule η_t = 1/(λ·t) (Pegasos).
    pub eta0: f64,
    /// Train the bias term b.  Default OFF: Pegasos and Wang et al.'s
    /// BudgetedSVM reference implementation are bias-free; an
    /// unregularized b under η_t = 1/(λt) random-walks with huge early
    /// steps and measurably destroys single-epoch accuracy (see
    /// EXPERIMENTS.md §Deviations).
    pub use_bias: bool,
    /// RNG seed for presentation order.
    pub seed: u64,
    /// Evaluate on held-out data every k steps (0 = only at the end).
    pub eval_every: usize,
    /// Compute backend.
    pub backend: BackendChoice,
    /// Merge scorer: `lut` (precomputed golden-section table, the
    /// default) or `exact` (per-pair golden-section search — the golden
    /// reference the table is validated against).
    pub merge_score_mode: MergeScoreMode,
    /// Drop SVs with |α| below this after maintenance (0 = off).
    pub prune_eps: f64,
    /// Worker threads for the tiled batch paths (batched margins,
    /// batch merge scoring).  Results are bit-identical for every
    /// value — the pool shards work with a fixed partition — so this
    /// is purely a wall-clock knob (TOML `threads`, CLI `--threads`).
    /// Deliberately NOT serialized into checkpoints: it is an
    /// execution detail of the machine, not training state, and a run
    /// resumed with a different thread count stays bit-identical.
    pub threads: usize,
    /// SIMD dispatch for the kernel inner loops: `auto` (runtime-detect
    /// AVX2/SSE2/NEON — the default) or `scalar` (force the reference
    /// path).  Like `threads`, a pure wall-clock knob — every dispatch
    /// target is bit-identical (`rust/tests/simd_parity.rs`) — and
    /// therefore also NOT serialized into checkpoints.  TOML
    /// `simd_mode`, CLI `--simd-mode`; the `MMBSGD_FORCE_SCALAR`
    /// environment variable overrides both.
    pub simd_mode: SimdMode,
    /// Exponent evaluation for the Gaussian hot paths: `libm` (the
    /// platform `exp`, the default — preserves every libm-pinned
    /// bit-exact invariant) or `vector` (the fixed-degree polynomial
    /// substrate in [`crate::kernel::simd`], bit-identical across ISAs
    /// and thread counts, within 1e-6 relative error of libm).  Like
    /// `threads` and `simd_mode`, an execution knob of the machine —
    /// NOT serialized into checkpoints.  TOML `exp_mode`, CLI
    /// `--exp-mode`; the `MMBSGD_FORCE_LIBM` environment variable
    /// overrides both.
    pub exp_mode: ExpMode,
    /// Pending cost parameter C (paper Table 2 convention λ = 1/(n·C)),
    /// set by the TOML `c = ...` key or experiment specs.  Explicitly
    /// represented — no sentinel encoding in `lambda` — so a config
    /// that was never resolved fails [`TrainConfig::validate`] with a
    /// dedicated [`TrainError::UnresolvedCost`] instead of a baffling
    /// "lambda must be positive" message.  Cleared by
    /// [`TrainConfig::resolve_c`] once the training-set size is known.
    pub cost_c: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            lambda: 1e-4,
            gamma: 1.0,
            budget: 256,
            mergees: 2,
            maintenance: None,
            epochs: 1,
            eta0: 1.0,
            use_bias: false,
            seed: 1,
            eval_every: 0,
            backend: BackendChoice::Native,
            merge_score_mode: MergeScoreMode::Lut,
            prune_eps: 0.0,
            threads: 1,
            simd_mode: SimdMode::Auto,
            exp_mode: ExpMode::Libm,
            cost_c: None,
        }
    }
}

impl TrainConfig {
    /// λ from the C convention used in the paper's Table 2: λ = 1/(n·C).
    pub fn lambda_from_c(c: f64, n: usize) -> f64 {
        1.0 / (c * n as f64)
    }

    /// Maintenance kind in effect.
    pub fn maintenance_kind(&self) -> MaintenanceKind {
        self.maintenance
            .unwrap_or(MaintenanceKind::Merge { m: self.mergees })
    }

    /// Validate invariants; call before training.  Every branch maps to
    /// a typed [`TrainError`] so entry paths never panic on bad input.
    pub fn validate(&self) -> Result<(), TrainError> {
        let bad = |field: &'static str, message: String| {
            Err(TrainError::InvalidConfig { field, message })
        };
        if let Some(c) = self.cost_c {
            return Err(TrainError::UnresolvedCost { c });
        }
        if !(self.lambda > 0.0 && self.lambda.is_finite()) {
            return bad("lambda", format!("must be positive, got {}", self.lambda));
        }
        if !(self.gamma > 0.0 && self.gamma.is_finite()) {
            return bad("gamma", format!("must be positive, got {}", self.gamma));
        }
        if self.budget < 2 {
            return bad("budget", format!("must be >= 2, got {}", self.budget));
        }
        if !(2..=16).contains(&self.mergees) {
            return bad("mergees", format!("must be in 2..=16, got {}", self.mergees));
        }
        if self.epochs == 0 {
            return bad("epochs", "must be >= 1".into());
        }
        if !(self.eta0 > 0.0 && self.eta0.is_finite()) {
            return bad("eta0", format!("must be positive, got {}", self.eta0));
        }
        if !(self.prune_eps >= 0.0 && self.prune_eps.is_finite()) {
            return bad("prune_eps", format!("must be >= 0, got {}", self.prune_eps));
        }
        if self.threads == 0 {
            return bad("threads", "must be >= 1".into());
        }
        Ok(())
    }

    /// Overlay values from a parsed TOML `[train]` section.
    ///
    /// Count-typed keys (`budget`, `threads`, ...) are parsed strictly:
    /// a fractional or negative number fails loudly here instead of
    /// silently truncating (`threads = 2.9` must not train with 2).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let sect = match doc.section("train") {
            Some(s) => s,
            None => return Ok(()),
        };
        for (key, val) in sect {
            match key.as_str() {
                "lambda" => {
                    self.lambda = val.as_f64().context("lambda")?;
                    // an explicit lambda cancels any earlier `c =` key
                    // (last key wins, as TOML readers expect)
                    self.cost_c = None;
                }
                "c" => {
                    // convenience: keep C pending; the caller converts
                    // via resolve_c() once the training-set size is known
                    let c = val.as_f64().context("c")?;
                    if !(c > 0.0 && c.is_finite()) {
                        bail!("c must be positive, got {c}");
                    }
                    self.cost_c = Some(c);
                }
                "gamma" => self.gamma = val.as_f64().context("gamma")?,
                "budget" => self.budget = toml_count_usize(val, "budget")?,
                "mergees" => self.mergees = toml_count_usize(val, "mergees")?,
                "maintenance" => {
                    let s = val.as_str().context("maintenance")?;
                    self.maintenance = Some(
                        MaintenanceKind::parse(s)
                            .with_context(|| format!("bad maintenance {s:?}"))?,
                    );
                }
                "epochs" => self.epochs = toml_count_usize(val, "epochs")?,
                "use_bias" => self.use_bias = val.as_bool().context("use_bias")?,
                "seed" => self.seed = toml_count(val, "seed")?,
                "eval_every" => self.eval_every = toml_count_usize(val, "eval_every")?,
                "backend" => {
                    let s = val.as_str().context("backend")?;
                    self.backend = BackendChoice::parse(s)
                        .with_context(|| format!("bad backend {s:?}"))?;
                }
                "merge_score_mode" => {
                    let s = val.as_str().context("merge_score_mode")?;
                    self.merge_score_mode = MergeScoreMode::parse(s)
                        .with_context(|| format!("bad merge_score_mode {s:?}"))?;
                }
                "prune_eps" => self.prune_eps = val.as_f64().context("prune_eps")?,
                "threads" => self.threads = toml_count_usize(val, "threads")?,
                "simd_mode" => {
                    let s = val.as_str().context("simd_mode")?;
                    self.simd_mode = SimdMode::parse(s)
                        .with_context(|| format!("bad simd_mode {s:?} (auto|scalar)"))?;
                }
                "exp_mode" => {
                    let s = val.as_str().context("exp_mode")?;
                    self.exp_mode = ExpMode::parse(s)
                        .with_context(|| format!("bad exp_mode {s:?} (libm|vector)"))?;
                }
                other => bail!("unknown [train] key {other:?}"),
            }
        }
        Ok(())
    }

    /// Resolve a pending `c = ...` cost parameter once the training-set
    /// size is known; a no-op when no C is pending.
    pub fn resolve_c(&mut self, n: usize) {
        if let Some(c) = self.cost_c.take() {
            self.lambda = Self::lambda_from_c(c, n);
        }
    }
}

/// Configuration of a `mmbsgd serve` deployment: the `[serve]` TOML
/// section, with CLI flags overriding file values (same layering as
/// [`TrainConfig`]).  `--model` specs are deliberately CLI/protocol
/// only — model files are runtime artifacts (hot-swappable via
/// `swap-model`), not configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Max query rows per tiled margins pass.
    pub batch_max: usize,
    /// Max admitted-but-unanswered requests before shedding.
    pub queue_max: usize,
    /// Who loses at a full queue: `reject` (refuse the new request) or
    /// `oldest` (drop the oldest waiter).
    pub shed: ShedPolicy,
    /// Label-feedback accuracy window of the drift monitor.
    pub monitor_window: usize,
    /// Worker threads for the shared backend's batch paths.
    pub threads: usize,
    /// SIMD dispatch for the margins inner loops (`auto` | `scalar`;
    /// same semantics and strict parsing as the `[train]` key — a pure
    /// wall-clock knob, replies are bit-identical either way).
    pub simd_mode: SimdMode,
    /// Exponent evaluation for the margins inner loops (`libm` |
    /// `vector`; same semantics and strict parsing as the `[train]`
    /// key).
    pub exp_mode: ExpMode,
    /// Routing-hash seed: replicas that must agree on A/B assignment
    /// share a seed.
    pub seed: u64,
    /// Close a connection after this many seconds without a request
    /// (0 = never).
    pub idle_timeout_secs: u64,
    /// Longest accepted protocol line in bytes; longer lines answer
    /// `err` and are discarded to the next newline.
    pub max_line_bytes: usize,
    /// Max simultaneously served connections; extras are answered
    /// `err busy` and closed (0 = unlimited).
    pub max_conns: usize,
    /// Per-request deadline in milliseconds: requests queued longer
    /// answer a typed `deadline exceeded` error (0 = none).
    pub deadline_ms: u64,
    /// HTTP/1.1 front-end listen address (`host:port`); empty disables
    /// the HTTP listener and the server speaks line protocol only.
    pub http_addr: String,
    /// Largest accepted HTTP request body in bytes; bigger declared
    /// `Content-Length`s answer `413`.
    pub max_body_bytes: usize,
    /// Shared-secret auth token (empty = auth off).  Required whenever
    /// `addr` or `http_addr` binds a non-loopback interface: the line
    /// protocol then demands an `auth <token>` first line and the HTTP
    /// front end an `Authorization: Bearer <token>` header.
    pub auth_token: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            batch_max: 64,
            queue_max: 256,
            shed: ShedPolicy::Reject,
            monitor_window: 256,
            threads: 1,
            simd_mode: SimdMode::Auto,
            exp_mode: ExpMode::Libm,
            seed: 1,
            idle_timeout_secs: 300,
            max_line_bytes: 64 * 1024,
            max_conns: 1024,
            deadline_ms: 0,
            http_addr: String::new(),
            max_body_bytes: 1024 * 1024,
            auth_token: String::new(),
        }
    }
}

impl ServeConfig {
    /// Validate invariants; call before binding.
    pub fn validate(&self) -> Result<(), TrainError> {
        let bad = |field: &'static str, message: String| {
            Err(TrainError::InvalidConfig { field, message })
        };
        if self.addr.is_empty() {
            return bad("addr", "must be host:port".into());
        }
        if self.batch_max == 0 {
            return bad("batch_max", "must be >= 1".into());
        }
        if self.queue_max == 0 {
            return bad("queue_max", "must be >= 1".into());
        }
        if self.monitor_window == 0 {
            return bad("monitor_window", "must be >= 1".into());
        }
        if self.threads == 0 {
            return bad("threads", "must be >= 1".into());
        }
        if self.max_line_bytes < 16 {
            // even "stats\n" needs a few bytes; a tiny cap would turn
            // every request into an oversize error
            return bad("max_line_bytes", "must be >= 16".into());
        }
        if self.max_body_bytes < 16 {
            return bad("max_body_bytes", "must be >= 16".into());
        }
        Ok(())
    }

    /// Overlay values from a parsed TOML `[serve]` section (same strict
    /// count parsing as the `[train]` overlay).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let sect = match doc.section("serve") {
            Some(s) => s,
            None => return Ok(()),
        };
        for (key, val) in sect {
            match key.as_str() {
                "addr" => self.addr = val.as_str().context("addr")?.to_string(),
                "batch_max" => self.batch_max = toml_count_usize(val, "batch_max")?,
                "queue_max" => self.queue_max = toml_count_usize(val, "queue_max")?,
                "shed" => {
                    let s = val.as_str().context("shed")?;
                    self.shed = ShedPolicy::parse(s)
                        .with_context(|| format!("bad shed {s:?} (reject|oldest)"))?;
                }
                "monitor_window" => {
                    self.monitor_window = toml_count_usize(val, "monitor_window")?
                }
                "threads" => self.threads = toml_count_usize(val, "threads")?,
                "simd_mode" => {
                    let s = val.as_str().context("simd_mode")?;
                    self.simd_mode = SimdMode::parse(s)
                        .with_context(|| format!("bad simd_mode {s:?} (auto|scalar)"))?;
                }
                "exp_mode" => {
                    let s = val.as_str().context("exp_mode")?;
                    self.exp_mode = ExpMode::parse(s)
                        .with_context(|| format!("bad exp_mode {s:?} (libm|vector)"))?;
                }
                "seed" => self.seed = toml_count(val, "seed")?,
                "idle_timeout_secs" => {
                    self.idle_timeout_secs = toml_count(val, "idle_timeout_secs")?
                }
                "max_line_bytes" => self.max_line_bytes = toml_count_usize(val, "max_line_bytes")?,
                "max_conns" => self.max_conns = toml_count_usize(val, "max_conns")?,
                "deadline_ms" => self.deadline_ms = toml_count(val, "deadline_ms")?,
                "http_addr" => self.http_addr = val.as_str().context("http_addr")?.to_string(),
                "max_body_bytes" => {
                    self.max_body_bytes = toml_count_usize(val, "max_body_bytes")?
                }
                "auth_token" => self.auth_token = val.as_str().context("auth_token")?.to_string(),
                other => bail!("unknown [serve] key {other:?}"),
            }
        }
        Ok(())
    }
}

/// Configuration of the fleet tooling: the `[fleet]` TOML section,
/// shared by `mmbsgd fleet push|rollback|status` (controller side) and
/// `mmbsgd fleet route` (router side).  Replica endpoints are a
/// comma-separated string — the TOML subset has no arrays, and a flat
/// string round-trips through CLI flags unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    /// Replica endpoints, comma-separated (`"host:port,host:port"`).
    pub replicas: String,
    /// Router listen address.
    pub addr: String,
    /// Consistent-hash seed: controller and every router must share it
    /// for key→replica agreement (same contract as the serve seed).
    pub seed: u64,
    /// Virtual nodes per replica on the hash ring (more = smoother
    /// balance, slower ring builds).
    pub vnodes: usize,
    /// Dead-replica re-probe interval, seconds.
    pub probe_secs: u64,
    /// Controller push/reply deadline, milliseconds.
    pub push_timeout_ms: u64,
    /// Auto-rollback threshold: a replica whose feedback-accuracy
    /// window drops below this triggers a fleet-wide rollback
    /// (0 = auto-rollback off).
    pub min_window_acc: f64,
    /// Replica artifact directory (`mmbsgd serve --fleet-dir`).
    pub dir: String,
    /// Artifact generations retained per model name in `dir`: the
    /// newest `keep` versioned archives (`<name>.artifact.v<k>`)
    /// survive garbage collection after each activation; older ones
    /// are deleted.  Must be ≥ 1 — the active generation is always
    /// kept.  TOML `keep`, CLI `--fleet-keep`.
    pub keep: usize,
    /// Pooled links per replica in the router's data plane: concurrent
    /// forwards to one replica check out distinct links; past this
    /// many in flight they wait.  Must be ≥ 1.  TOML `router_pool`,
    /// CLI `--router-pool`.
    pub router_pool: usize,
    /// Max forwards in flight across the whole router (0 = unbounded,
    /// one worker per client connection).  TOML `router_threads`, CLI
    /// `--router-threads`.
    pub router_threads: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            replicas: String::new(),
            addr: "127.0.0.1:7979".into(),
            seed: 1,
            vnodes: 128,
            probe_secs: 2,
            push_timeout_ms: 5_000,
            min_window_acc: 0.0,
            dir: "fleet-artifacts".into(),
            keep: 3,
            router_pool: 2,
            router_threads: 0,
        }
    }
}

impl FleetConfig {
    /// The replica list, split and trimmed (empty string = none).
    pub fn replica_list(&self) -> Vec<String> {
        self.replicas
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect()
    }

    /// Validate invariants; call before contacting the fleet.
    pub fn validate(&self) -> Result<(), TrainError> {
        let bad = |field: &'static str, message: String| {
            Err(TrainError::InvalidConfig { field, message })
        };
        if self.addr.is_empty() {
            return bad("addr", "must be host:port".into());
        }
        if self.vnodes == 0 {
            return bad("vnodes", "must be >= 1".into());
        }
        if self.push_timeout_ms == 0 {
            return bad("push_timeout_ms", "must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.min_window_acc) {
            return bad(
                "min_window_acc",
                format!("must be in 0..=1, got {}", self.min_window_acc),
            );
        }
        if self.keep == 0 {
            return bad("keep", "must be >= 1 (the active generation is always kept)".into());
        }
        if self.router_pool == 0 {
            return bad("router_pool", "must be >= 1 link per replica".into());
        }
        Ok(())
    }

    /// Overlay values from a parsed TOML `[fleet]` section (same strict
    /// count parsing as the `[train]` / `[serve]` overlays).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let sect = match doc.section("fleet") {
            Some(s) => s,
            None => return Ok(()),
        };
        for (key, val) in sect {
            match key.as_str() {
                "replicas" => self.replicas = val.as_str().context("replicas")?.to_string(),
                "addr" => self.addr = val.as_str().context("addr")?.to_string(),
                "seed" => self.seed = toml_count(val, "seed")?,
                "vnodes" => self.vnodes = toml_count_usize(val, "vnodes")?,
                "probe_secs" => self.probe_secs = toml_count(val, "probe_secs")?,
                "push_timeout_ms" => {
                    self.push_timeout_ms = toml_count(val, "push_timeout_ms")?
                }
                "min_window_acc" => {
                    self.min_window_acc = val.as_f64().context("min_window_acc")?
                }
                "dir" => self.dir = val.as_str().context("dir")?.to_string(),
                "keep" => self.keep = toml_count_usize(val, "keep")?,
                "router_pool" => self.router_pool = toml_count_usize(val, "router_pool")?,
                "router_threads" => {
                    self.router_threads = toml_count_usize(val, "router_threads")?
                }
                other => bail!("unknown [fleet] key {other:?}"),
            }
        }
        Ok(())
    }
}

/// Parse a TOML number as a non-negative integer count.  The
/// TOML-subset parser stores every number as `f64`, so without this
/// guard `threads = 2.9` would silently truncate to 2 and `threads =
/// -4` would saturate to 0 before `validate()` rejected it with an
/// unrelated message — both must fail at parse time instead.
fn toml_count(val: &TomlValue, key: &'static str) -> Result<u64> {
    let v = val.as_f64().context(key)?;
    if !v.is_finite() || v.fract() != 0.0 {
        bail!("{key} must be an integer, got {v}");
    }
    if v < 0.0 {
        bail!("{key} must be >= 0, got {v}");
    }
    if v >= u64::MAX as f64 {
        bail!("{key} {v} is out of range");
    }
    Ok(v as u64)
}

/// [`toml_count`] narrowed to `usize` with a checked conversion, so a
/// count beyond the platform's pointer width fails loudly instead of
/// wrapping (a 5e9 budget must not silently become ~7e8 on a 32-bit
/// target).
fn toml_count_usize(val: &TomlValue, key: &'static str) -> Result<usize> {
    let v = toml_count(val, key)?;
    usize::try_from(v).with_context(|| format!("{key} {v} overflows usize on this platform"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = TrainConfig::default();
        c.budget = 1;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.mergees = 1;
        assert!(c.validate().is_err());
        let mut c = TrainConfig::default();
        c.gamma = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_errors_are_typed_per_field() {
        use crate::error::TrainError;
        let cases: Vec<(Box<dyn Fn(&mut TrainConfig)>, &str)> = vec![
            (Box::new(|c| c.lambda = -1.0), "lambda"),
            (Box::new(|c| c.lambda = f64::INFINITY), "lambda"),
            (Box::new(|c| c.gamma = 0.0), "gamma"),
            (Box::new(|c| c.budget = 0), "budget"),
            (Box::new(|c| c.mergees = 17), "mergees"),
            (Box::new(|c| c.epochs = 0), "epochs"),
            (Box::new(|c| c.eta0 = 0.0), "eta0"),
            (Box::new(|c| c.prune_eps = -1.0), "prune_eps"),
            (Box::new(|c| c.threads = 0), "threads"),
        ];
        for (mutate, want_field) in cases {
            let mut cfg = TrainConfig::default();
            mutate(&mut cfg);
            match cfg.validate() {
                Err(TrainError::InvalidConfig { field, .. }) => {
                    assert_eq!(field, want_field);
                }
                other => panic!("{want_field}: expected InvalidConfig, got {other:?}"),
            }
        }
    }

    #[test]
    fn unresolved_cost_is_a_dedicated_error() {
        use crate::error::TrainError;
        let mut cfg = TrainConfig::default();
        cfg.cost_c = Some(8.0);
        assert_eq!(cfg.validate(), Err(TrainError::UnresolvedCost { c: 8.0 }));
        // the message tells the caller exactly what to do
        let msg = cfg.validate().unwrap_err().to_string();
        assert!(msg.contains("resolve_c"), "{msg}");
        cfg.resolve_c(100);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn lambda_from_c_matches_convention() {
        assert!((TrainConfig::lambda_from_c(32.0, 1000) - 1.0 / 32_000.0).abs() < 1e-18);
    }

    #[test]
    fn toml_overlay() {
        let doc = TomlDoc::parse(
            "[train]\nlambda = 0.5\ngamma = 2.0\nbudget = 128\nmergees = 4\n\
             maintenance = \"mergegd:4\"\nbackend = \"hybrid\"\nuse_bias = false\n\
             merge_score_mode = \"exact\"\nthreads = 4\nsimd_mode = \"scalar\"\n",
        )
        .unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.lambda, 0.5);
        assert_eq!(cfg.budget, 128);
        assert_eq!(cfg.maintenance, Some(MaintenanceKind::MergeGd { m: 4 }));
        assert_eq!(cfg.backend, BackendChoice::Hybrid);
        assert_eq!(cfg.merge_score_mode, MergeScoreMode::Exact);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.simd_mode, SimdMode::Scalar);
        assert!(!cfg.use_bias);
    }

    #[test]
    fn simd_mode_defaults_to_auto_and_parses_strictly() {
        assert_eq!(TrainConfig::default().simd_mode, SimdMode::Auto);
        assert_eq!(ServeConfig::default().simd_mode, SimdMode::Auto);
        // unknown values fail at parse time in both sections
        for doc in ["[train]\nsimd_mode = \"avx2\"\n", "[serve]\nsimd_mode = \"fast\"\n"] {
            let doc = TomlDoc::parse(doc).unwrap();
            let train_err = TrainConfig::default().apply_toml(&doc).is_err();
            let serve_err = ServeConfig::default().apply_toml(&doc).is_err();
            assert!(train_err || serve_err, "bogus simd_mode must be rejected");
        }
        let doc = TomlDoc::parse("[serve]\nsimd_mode = \"scalar\"\n").unwrap();
        let mut scfg = ServeConfig::default();
        scfg.apply_toml(&doc).unwrap();
        assert_eq!(scfg.simd_mode, SimdMode::Scalar);
    }

    #[test]
    fn exp_mode_defaults_to_libm_and_parses_strictly() {
        assert_eq!(TrainConfig::default().exp_mode, ExpMode::Libm);
        assert_eq!(ServeConfig::default().exp_mode, ExpMode::Libm);
        let doc = TomlDoc::parse("[train]\nexp_mode = \"vector\"\n").unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.exp_mode, ExpMode::Vector);
        let doc = TomlDoc::parse("[serve]\nexp_mode = \"vector\"\n").unwrap();
        let mut scfg = ServeConfig::default();
        scfg.apply_toml(&doc).unwrap();
        assert_eq!(scfg.exp_mode, ExpMode::Vector);
        // unknown values fail at parse time in both sections
        let doc = TomlDoc::parse("[train]\nexp_mode = \"fast\"\n").unwrap();
        assert!(TrainConfig::default().apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[serve]\nexp_mode = \"poly\"\n").unwrap();
        assert!(ServeConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn fleet_keep_defaults_overlays_and_validates() {
        assert_eq!(FleetConfig::default().keep, 3);
        let doc = TomlDoc::parse("[fleet]\nkeep = 5\n").unwrap();
        let mut cfg = FleetConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.keep, 5);
        cfg.validate().unwrap();
        // keep = 0 would delete the active generation; rejected
        use crate::error::TrainError;
        cfg.keep = 0;
        match cfg.validate() {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "keep"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        // fractional counts fail at parse time like every other count key
        let doc = TomlDoc::parse("[fleet]\nkeep = 2.5\n").unwrap();
        assert!(FleetConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn fleet_router_pool_defaults_overlays_and_validates() {
        let d = FleetConfig::default();
        assert_eq!(d.router_pool, 2, "pooled links default to 2 per replica");
        assert_eq!(d.router_threads, 0, "0 = one worker per client, unbounded");
        let doc = TomlDoc::parse("[fleet]\nrouter_pool = 4\nrouter_threads = 8\n").unwrap();
        let mut cfg = FleetConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.router_pool, 4);
        assert_eq!(cfg.router_threads, 8);
        cfg.validate().unwrap();
        // a zero-link pool can forward nothing; rejected
        use crate::error::TrainError;
        cfg.router_pool = 0;
        match cfg.validate() {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "router_pool"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        // strict count parsing applies to both keys
        let doc = TomlDoc::parse("[fleet]\nrouter_pool = 1.5\n").unwrap();
        assert!(FleetConfig::default().apply_toml(&doc).is_err());
        let doc = TomlDoc::parse("[fleet]\nrouter_threads = -1\n").unwrap();
        assert!(FleetConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn merge_score_mode_defaults_to_lut() {
        assert_eq!(TrainConfig::default().merge_score_mode, MergeScoreMode::Lut);
        let doc = TomlDoc::parse("[train]\nmerge_score_mode = \"bogus\"\n").unwrap();
        assert!(TrainConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_c_pends_then_resolves() {
        let doc = TomlDoc::parse("[train]\nc = 8\n").unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.cost_c, Some(8.0));
        cfg.resolve_c(100);
        assert_eq!(cfg.cost_c, None);
        assert!((cfg.lambda - 1.0 / 800.0).abs() < 1e-15);
        // nonpositive C rejected at parse time, not at resolve time
        let doc = TomlDoc::parse("[train]\nc = -8\n").unwrap();
        assert!(TrainConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn toml_last_cost_key_wins() {
        // `c` then `lambda`: the explicit lambda cancels the pending C
        let doc = TomlDoc::parse("[train]\nc = 8\nlambda = 0.25\n").unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.cost_c, None);
        assert_eq!(cfg.lambda, 0.25);
        cfg.resolve_c(100); // no-op: nothing pending
        assert_eq!(cfg.lambda, 0.25);
        // `lambda` then `c`: C pends and wins at resolve time
        let doc = TomlDoc::parse("[train]\nlambda = 0.25\nc = 8\n").unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.cost_c, Some(8.0));
        cfg.resolve_c(100);
        assert!((cfg.lambda - 1.0 / 800.0).abs() < 1e-15);
    }

    #[test]
    fn toml_count_keys_reject_fractional_and_negative() {
        // fractional counts must fail at parse time, not truncate
        for bad in ["threads = 2.9", "budget = 128.5", "epochs = 1.5", "seed = 0.5"] {
            let doc = TomlDoc::parse(&format!("[train]\n{bad}\n")).unwrap();
            let err = TrainConfig::default().apply_toml(&doc).unwrap_err();
            assert!(err.to_string().contains("integer"), "{bad}: {err}");
        }
        // negative counts must fail loudly, not saturate to 0
        for bad in ["threads = -4", "eval_every = -1", "mergees = -2"] {
            let doc = TomlDoc::parse(&format!("[train]\n{bad}\n")).unwrap();
            assert!(TrainConfig::default().apply_toml(&doc).is_err(), "{bad}");
        }
        // out-of-range counts must fail loudly, not wrap or saturate
        for bad in ["seed = 1e300", "budget = 1e300"] {
            let doc = TomlDoc::parse(&format!("[train]\n{bad}\n")).unwrap();
            assert!(TrainConfig::default().apply_toml(&doc).is_err(), "{bad}");
        }
        // whole-valued numbers still parse
        let doc = TomlDoc::parse("[train]\nthreads = 8\nbudget = 64\n").unwrap();
        let mut cfg = TrainConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.budget, 64);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = TomlDoc::parse("[train]\nbogus = 1\n").unwrap();
        assert!(TrainConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn serve_toml_overlay_and_validation() {
        let doc = TomlDoc::parse(
            "[serve]\naddr = \"0.0.0.0:9090\"\nbatch_max = 128\nqueue_max = 512\n\
             shed = \"oldest\"\nmonitor_window = 64\nthreads = 4\nseed = 9\n\
             http_addr = \"0.0.0.0:9091\"\nmax_body_bytes = 4096\nauth_token = \"s3cr3t\"\n",
        )
        .unwrap();
        let mut cfg = ServeConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg.addr, "0.0.0.0:9090");
        assert_eq!(cfg.batch_max, 128);
        assert_eq!(cfg.queue_max, 512);
        assert_eq!(cfg.shed, ShedPolicy::Oldest);
        assert_eq!(cfg.monitor_window, 64);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.http_addr, "0.0.0.0:9091");
        assert_eq!(cfg.max_body_bytes, 4096);
        assert_eq!(cfg.auth_token, "s3cr3t");
        cfg.validate().unwrap();
        // a [train]-only doc leaves serve defaults alone
        let doc = TomlDoc::parse("[train]\nbudget = 64\n").unwrap();
        let mut cfg = ServeConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(cfg, ServeConfig::default());
    }

    #[test]
    fn serve_toml_rejects_bad_keys_and_counts() {
        for bad in [
            "[serve]\nbogus = 1\n",
            "[serve]\nbatch_max = 2.5\n",
            "[serve]\nqueue_max = -4\n",
            "[serve]\nshed = \"newest\"\n",
            "[serve]\nmax_body_bytes = -1\n",
            "[serve]\nhttp_addr = 9091\n",
            "[serve]\nauth_token = 42\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ServeConfig::default().apply_toml(&doc).is_err(), "{bad}");
        }
        use crate::error::TrainError;
        let mut cfg = ServeConfig::default();
        cfg.batch_max = 0;
        match cfg.validate() {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "batch_max"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        let mut cfg = ServeConfig::default();
        cfg.max_body_bytes = 4;
        match cfg.validate() {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "max_body_bytes"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
    }

    #[test]
    fn fleet_toml_overlay_and_replica_list() {
        let doc = TomlDoc::parse(
            "[fleet]\nreplicas = \"10.0.0.1:9000, 10.0.0.2:9000\"\naddr = \"0.0.0.0:7979\"\n\
             seed = 42\nvnodes = 64\nprobe_secs = 5\npush_timeout_ms = 2000\n\
             min_window_acc = 0.8\ndir = \"/var/lib/mmbsgd\"\n\
             router_pool = 3\nrouter_threads = 6\n",
        )
        .unwrap();
        let mut cfg = FleetConfig::default();
        cfg.apply_toml(&doc).unwrap();
        assert_eq!(
            cfg.replica_list(),
            vec!["10.0.0.1:9000".to_string(), "10.0.0.2:9000".to_string()]
        );
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.vnodes, 64);
        assert_eq!(cfg.probe_secs, 5);
        assert_eq!(cfg.push_timeout_ms, 2000);
        assert_eq!(cfg.min_window_acc, 0.8);
        assert_eq!(cfg.dir, "/var/lib/mmbsgd");
        assert_eq!(cfg.router_pool, 3);
        assert_eq!(cfg.router_threads, 6);
        cfg.validate().unwrap();
        // defaults validate, empty replica string means no replicas
        let d = FleetConfig::default();
        d.validate().unwrap();
        assert!(d.replica_list().is_empty());
    }

    #[test]
    fn fleet_toml_rejects_bad_keys_and_values() {
        for bad in [
            "[fleet]\nbogus = 1\n",
            "[fleet]\nvnodes = 2.5\n",
            "[fleet]\nseed = -1\n",
            "[fleet]\npush_timeout_ms = -5\n",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(FleetConfig::default().apply_toml(&doc).is_err(), "{bad}");
        }
        use crate::error::TrainError;
        let mut cfg = FleetConfig::default();
        cfg.min_window_acc = 1.5;
        match cfg.validate() {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "min_window_acc"),
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        let mut cfg = FleetConfig::default();
        cfg.vnodes = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn maintenance_kind_defaults_to_mergees() {
        let mut cfg = TrainConfig::default();
        cfg.mergees = 5;
        assert_eq!(cfg.maintenance_kind(), MaintenanceKind::Merge { m: 5 });
    }
}

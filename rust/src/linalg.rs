//! Small dense linear algebra for the projection baseline.
//!
//! The projection budget-maintenance strategy (Wang et al. 2012 §4.2)
//! removes an SV and projects its feature-space contribution onto the
//! remaining ones: solve `K a = k_r` where `K` is the (B×B) kernel Gram
//! matrix of the survivors and `k_r` the removed point's kernel column.
//! A Cholesky solve with jitter is exactly what LIBSVM-era codes used.

/// Dense column-major symmetric positive (semi-)definite solver state.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Vec<f64>, // lower-triangular factor, row-major n×n
    n: usize,
}

/// Error: the (jittered) Gram matrix was not positive definite.
#[derive(Debug)]
pub struct NotPosDef(pub String);

impl std::fmt::Display for NotPosDef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not positive definite: {}", self.0)
    }
}
impl std::error::Error for NotPosDef {}

impl Cholesky {
    /// Factor a symmetric PSD matrix (row-major n×n), adding `jitter` to
    /// the diagonal (Gram matrices of near-duplicate SVs are rank
    /// deficient; LIBSVM uses the same trick).
    pub fn factor(a: &[f64], n: usize, jitter: f64) -> Result<Self, NotPosDef> {
        assert_eq!(a.len(), n * n);
        let mut l = vec![0.0f64; n * n];
        for j in 0..n {
            let mut diag = a[j * n + j] + jitter;
            for k in 0..j {
                diag -= l[j * n + k] * l[j * n + k];
            }
            if diag <= 0.0 || !diag.is_finite() {
                return Err(NotPosDef(format!("pivot {j}: {diag}")));
            }
            let dsqrt = diag.sqrt();
            l[j * n + j] = dsqrt;
            for i in (j + 1)..n {
                let mut v = a[i * n + j];
                for k in 0..j {
                    v -= l[i * n + k] * l[j * n + k];
                }
                l[i * n + j] = v / dsqrt;
            }
        }
        Ok(Self { l, n })
    }

    /// Solve `A x = b` via forward/back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        let l = &self.l;
        // L y = b
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut v = b[i];
            for k in 0..i {
                v -= l[i * n + k] * y[k];
            }
            y[i] = v / l[i * n + i];
        }
        // L^T x = y
        let mut x = vec![0.0f64; n];
        for i in (0..n).rev() {
            let mut v = y[i];
            for k in (i + 1)..n {
                v -= l[k * n + i] * x[k];
            }
            x[i] = v / l[i * n + i];
        }
        x
    }
}

/// Dense symmetric matvec `y = A x` (row-major n×n).
pub fn symv(a: &[f64], n: usize, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.len(), n * n);
    assert_eq!(x.len(), n);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let row = &a[i * n..(i + 1) * n];
        y[i] = row.iter().zip(x).map(|(&aij, &xj)| aij * xj).sum();
    }
    y
}

/// Quadratic form `x^T A x`.
pub fn quad_form(a: &[f64], n: usize, x: &[f64]) -> f64 {
    symv(a, n, x).iter().zip(x).map(|(&yi, &xi)| yi * xi).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Vec<f64> {
        // A = M^T M + I for M random-ish: guaranteed SPD.
        vec![
            4.0, 1.0, 2.0, //
            1.0, 3.0, 0.5, //
            2.0, 0.5, 5.0,
        ]
    }

    #[test]
    fn solve_recovers_rhs() {
        let a = spd3();
        let ch = Cholesky::factor(&a, 3, 0.0).unwrap();
        let x_true = vec![1.0, -2.0, 0.5];
        let b = symv(&a, 3, &x_true);
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn jitter_rescues_singular() {
        // Rank-1 matrix; fails without jitter, factors with it.
        let a = vec![1.0, 1.0, 1.0, 1.0];
        assert!(Cholesky::factor(&a, 2, 0.0).is_err());
        assert!(Cholesky::factor(&a, 2, 1e-6).is_ok());
    }

    #[test]
    fn quad_form_matches_manual() {
        let a = spd3();
        let x = vec![1.0, 1.0, 1.0];
        // sum of all entries
        let expect: f64 = a.iter().sum();
        assert!((quad_form(&a, 3, &x) - expect).abs() < 1e-12);
    }

    #[test]
    fn identity_solve_is_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let ch = Cholesky::factor(&a, 2, 0.0).unwrap();
        assert_eq!(ch.solve(&[3.0, 4.0]), vec![3.0, 4.0]);
    }
}

//! The data-plane replica: receives artifacts, verifies, hot-swaps.
//!
//! A [`ReplicaState`] is the fleet-side state of one serve process: a
//! staging area for pushed-but-not-yet-activated bundles, the set of
//! activated names with their last-good versions, and the on-disk
//! artifact directory.  It plugs into the line-protocol server as the
//! [`FleetHandler`](crate::serve::proto::FleetHandler) behind the
//! `push-artifact` / `activate` / `rollback` / `fleet-status` verbs,
//! so the ordering guarantees of the engine loop (drain before any
//! control verb) apply to fleet operations exactly as they do to
//! `swap-model`.
//!
//! Activation is the only path that touches the registry or the disk,
//! and it is atomic at both layers: the registry swap either installs
//! the fully-validated model or (e.g. on a dimension change) leaves
//! the serving entry untouched, and the durable write either lands the
//! new bundle with the previous generation rotated to `.prev` — the
//! fleet's last-good — or leaves the old file in place.  A torn push
//! stages nothing; a tampered bundle is refused at parse/validate with
//! a typed [`FleetError`]; in every failure case the replica keeps
//! serving exactly what it served before.

use crate::error::FleetError;
use crate::serve::proto::FleetHandler;
use crate::serve::ModelRegistry;
use crate::util::durable;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::artifact::Artifact;

/// Activation bookkeeping for one model name.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ActiveInfo {
    /// The artifact version currently activated.
    pub version: u64,
    /// The version recoverable from the `.prev` generation, when one
    /// exists.
    pub last_good: Option<u64>,
}

/// Fleet state of one replica process.
pub struct ReplicaState {
    dir: PathBuf,
    staged: BTreeMap<(String, u64), Artifact>,
    active: BTreeMap<String, ActiveInfo>,
    /// Archived generations retained per model name (`--fleet-keep`,
    /// `[fleet] keep`); the newest `keep` versioned archives survive
    /// [`Self::gc`], older ones are deleted.
    keep: usize,
}

impl ReplicaState {
    /// A replica over `dir` (created if absent) — the durable home of
    /// activated bundles and their `.prev` last-good generations.
    pub fn new(dir: &Path) -> Result<ReplicaState, FleetError> {
        std::fs::create_dir_all(dir).map_err(|e| FleetError::Io {
            path: dir.display().to_string(),
            detail: e.to_string(),
        })?;
        Ok(ReplicaState {
            dir: dir.to_path_buf(),
            staged: BTreeMap::new(),
            active: BTreeMap::new(),
            keep: 3,
        })
    }

    /// Override the archived-generation retention depth.  Clamped to a
    /// minimum of 1: the active generation's archive is always kept.
    pub fn with_keep(mut self, keep: usize) -> Self {
        self.keep = keep.max(1);
        self
    }

    /// On-disk path of a name's activated bundle.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.artifact"))
    }

    /// On-disk path of one archived generation (`<name>.artifact.v<k>`).
    /// The `v<k>` extension keeps archives invisible to the
    /// `.artifact`-suffix scan [`Self::recover`] performs.
    pub fn version_path(&self, name: &str, version: u64) -> PathBuf {
        self.dir.join(format!("{name}.artifact.v{version}"))
    }

    /// Archive one generation (idempotent: archives are immutable, an
    /// existing file is left alone) and prune generations beyond the
    /// retention depth.  Best-effort on purpose — the registry already
    /// serves the model and the primary bundle is durably on disk, so
    /// an archival or GC failure must never fail the activation that
    /// triggered it.  Returns the versions GC deleted (for logging and
    /// tests).
    fn archive_and_gc(&self, artifact: &Artifact) -> Vec<u64> {
        let path = self.version_path(&artifact.name, artifact.version);
        if !path.exists() {
            let _ = artifact.save(&path);
        }
        self.gc(&artifact.name)
    }

    /// Delete all but the newest `keep` archived generations of `name`
    /// (each with its `.prev` rotation).  The activated
    /// `<name>.artifact` primary and its `.prev` last-good are never
    /// candidates — GC only ever touches `<name>.artifact.v<k>` files —
    /// and the currently *active* version's archive is exempt even when
    /// it is old (a rollback far back must not eat its own archive).
    fn gc(&self, name: &str) -> Vec<u64> {
        let prefix = format!("{name}.artifact.v");
        let mut versions: Vec<u64> = match std::fs::read_dir(&self.dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .filter_map(|e| e.file_name().into_string().ok())
                .filter_map(|f| {
                    let f = f.strip_suffix(".prev").unwrap_or(&f);
                    f.strip_prefix(&prefix).and_then(|v| v.parse::<u64>().ok())
                })
                .collect(),
            Err(_) => return Vec::new(),
        };
        versions.sort_unstable();
        versions.dedup();
        if versions.len() <= self.keep {
            return Vec::new();
        }
        let active_v = self.active.get(name).map(|a| a.version);
        let cut = versions.len() - self.keep;
        let mut deleted: Vec<u64> =
            versions[..cut].iter().copied().filter(|v| Some(*v) != active_v).collect();
        for &v in &deleted {
            let p = self.version_path(name, v);
            let _ = std::fs::remove_file(durable::prev_path(&p));
            let _ = std::fs::remove_file(p);
        }
        deleted.reverse(); // newest first, like the retention order
        deleted
    }

    /// Activation info for a name.
    pub fn active(&self, name: &str) -> Option<&ActiveInfo> {
        self.active.get(name)
    }

    /// Number of staged (pushed, not yet activated) bundles.
    pub fn staged_count(&self) -> usize {
        self.staged.len()
    }

    /// Pull semantics at startup: scan the artifact directory and
    /// re-activate every bundle found, falling back to the `.prev`
    /// last-good generation when a primary is corrupt (the durable
    /// layer's whole point).  Returns `(name, version)` per recovered
    /// model; bundles with no usable generation are skipped with their
    /// error.
    pub fn recover(
        &mut self,
        registry: &mut ModelRegistry,
    ) -> (Vec<(String, u64)>, Vec<(PathBuf, FleetError)>) {
        let mut recovered = Vec::new();
        let mut failed = Vec::new();
        let mut paths: Vec<PathBuf> = match std::fs::read_dir(&self.dir) {
            Ok(rd) => rd
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("artifact"))
                .collect(),
            Err(_) => Vec::new(),
        };
        paths.sort();
        for path in paths {
            let (artifact, from_prev) = match Artifact::load(&path) {
                Ok(a) => (a, false),
                Err(primary_err) => match Artifact::load(&durable::prev_path(&path)) {
                    Ok(a) => (a, true),
                    Err(_) => {
                        failed.push((path, primary_err));
                        continue;
                    }
                },
            };
            let model = match artifact.validate_model() {
                Ok(m) => m,
                Err(e) => {
                    failed.push((path, e));
                    continue;
                }
            };
            if registry.insert(&artifact.name, model).is_err() {
                failed.push((path, FleetError::Model("registry refused the model".into())));
                continue;
            }
            let last_good = if from_prev {
                None // we *are* serving the last-good generation
            } else {
                Artifact::load(&durable::prev_path(&path)).ok().map(|a| a.version)
            };
            self.active
                .insert(artifact.name.clone(), ActiveInfo { version: artifact.version, last_good });
            recovered.push((artifact.name.clone(), artifact.version));
            // converge the archive set on startup too: a dir written by
            // an older build (or a lowered --fleet-keep) gets its
            // backlog archived and pruned without waiting for a push
            self.archive_and_gc(&artifact);
        }
        (recovered, failed)
    }
}

impl FleetHandler for ReplicaState {
    /// Stage a pushed bundle after full verification (manifest parse,
    /// section checksum, model parse, shape cross-check).  Staging
    /// touches neither the registry nor the disk — a bad push costs
    /// nothing.
    fn push_artifact(&mut self, _registry: &mut ModelRegistry, payload: &str) -> String {
        let artifact = match Artifact::parse(payload) {
            Ok(a) => a,
            Err(e) => return format!("err push-artifact: {e}"),
        };
        if let Err(e) = artifact.validate_model() {
            return format!("err push-artifact: {e}");
        }
        let line = format!(
            "ok staged {}@v{} dim={} nsv={}",
            artifact.name, artifact.version, artifact.dim, artifact.nsv
        );
        self.staged.insert((artifact.name.clone(), artifact.version), artifact);
        line
    }

    /// Activate a staged bundle: swap into the registry (dimension
    /// gate included — see [`ModelRegistry::swap`]), then persist the
    /// bundle durably, rotating the previous generation to `.prev` as
    /// the new last-good.
    fn activate(&mut self, registry: &mut ModelRegistry, name: &str, version: u64) -> String {
        let Some(artifact) = self.staged.get(&(name.to_string(), version)) else {
            return format!(
                "err {}",
                FleetError::Version { detail: format!("no staged artifact {name}@v{version}") }
            );
        };
        let model = match artifact.validate_model() {
            Ok(m) => m,
            Err(e) => return format!("err activate: {e}"),
        };
        let registry_version = if registry.version_of(name).is_ok() {
            match registry.swap(name, model) {
                Ok(v) => v,
                Err(e) => return format!("err activate: {e}"),
            }
        } else {
            match registry.insert(name, model) {
                Ok(v) => v,
                Err(e) => return format!("err activate: {e}"),
            }
        };
        let artifact = self.staged.remove(&(name.to_string(), version)).expect("checked above");
        if let Err(e) = artifact.save(&self.artifact_path(name)) {
            // the registry already serves the new model; say so rather
            // than pretending the activation failed outright
            return format!("err activate: serving v{version} but persist failed: {e}");
        }
        let last_good = self.active.get(name).map(|a| a.version);
        self.active.insert(name.to_string(), ActiveInfo { version, last_good });
        self.archive_and_gc(&artifact);
        format!("ok active {name}@v{version} registry=v{registry_version}")
    }

    /// Fleet-wide last-good restore: load the `.prev` generation,
    /// swap it in, and write it back as the primary (which rotates the
    /// rolled-back-from version to `.prev`, so a rollback can itself
    /// be rolled back).
    fn rollback(&mut self, registry: &mut ModelRegistry, name: &str) -> String {
        let prev = durable::prev_path(&self.artifact_path(name));
        let artifact = match Artifact::load(&prev) {
            Ok(a) => a,
            Err(FleetError::Io { .. }) => {
                return format!(
                    "err {}",
                    FleetError::Version {
                        detail: format!("no last-good generation for {name}")
                    }
                )
            }
            Err(e) => return format!("err rollback: {e}"),
        };
        let model = match artifact.validate_model() {
            Ok(m) => m,
            Err(e) => return format!("err rollback: {e}"),
        };
        let registry_version = if registry.version_of(name).is_ok() {
            match registry.swap(name, model) {
                Ok(v) => v,
                Err(e) => return format!("err rollback: {e}"),
            }
        } else {
            match registry.insert(name, model) {
                Ok(v) => v,
                Err(e) => return format!("err rollback: {e}"),
            }
        };
        let version = artifact.version;
        let rolled_from = self.active.get(name).map(|a| a.version);
        if let Err(e) = artifact.save(&self.artifact_path(name)) {
            return format!("err rollback: serving v{version} but persist failed: {e}");
        }
        self.active.insert(name.to_string(), ActiveInfo { version, last_good: rolled_from });
        self.archive_and_gc(&artifact);
        format!("ok rollback {name}@v{version} registry=v{registry_version}")
    }

    /// One-line replica status: activated versions with their
    /// last-good, staged count, and the monitor's feedback-accuracy
    /// window (the auto-rollback signal).
    fn fleet_status(&self, registry: &ModelRegistry, window_accuracy: Option<f64>) -> String {
        let models: Vec<String> = self
            .active
            .iter()
            .map(|(name, info)| {
                let lg = match info.last_good {
                    Some(v) => format!("{v}"),
                    None => "na".into(),
                };
                let rv = match registry.version_of(name) {
                    Ok(v) => format!("{v}"),
                    Err(_) => "na".into(),
                };
                format!("{name}@v{}:lg={lg}:rv={rv}", info.version)
            })
            .collect();
        let models = if models.is_empty() { "-".to_string() } else { models.join(",") };
        let acc = match window_accuracy {
            Some(a) => format!("{a:.4}"),
            None => "na".into(),
        };
        format!("ok fleet models={models} staged={} acc={acc}", self.staged.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::artifact::Provenance;
    use crate::model::SvmModel;
    use crate::runtime::NativeBackend;

    fn scratch(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mmbsgd_replica_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn model(bias: f64) -> SvmModel {
        let mut m = SvmModel::new(2, 1.0);
        m.svs.push(&[1.0, 0.0], 0.5);
        m.bias = bias;
        m
    }

    fn artifact(version: u64, bias: f64) -> Artifact {
        Artifact::wrap("champ", version, &model(bias), Provenance::default(), "lut", "auto")
            .unwrap()
    }

    fn registry() -> ModelRegistry {
        ModelRegistry::new(Box::new(NativeBackend::new()), 7)
    }

    #[test]
    fn push_activate_rollback_lifecycle() {
        let dir = scratch("lifecycle");
        let mut rep = ReplicaState::new(&dir).unwrap();
        let mut reg = registry();
        // push + activate v1
        let r = rep.push_artifact(&mut reg, &artifact(1, 0.1).to_text());
        assert!(r.starts_with("ok staged champ@v1"), "{r}");
        let r = rep.activate(&mut reg, "champ", 1);
        assert!(r.starts_with("ok active champ@v1"), "{r}");
        assert_eq!(reg.version_of("champ").unwrap(), 1);
        assert_eq!(rep.active("champ").unwrap().version, 1);
        assert_eq!(rep.active("champ").unwrap().last_good, None);
        // push + activate v2: v1 rotates to .prev
        rep.push_artifact(&mut reg, &artifact(2, 0.2).to_text());
        let r = rep.activate(&mut reg, "champ", 2);
        assert!(r.starts_with("ok active champ@v2"), "{r}");
        assert_eq!(rep.active("champ").unwrap().last_good, Some(1));
        assert_eq!(reg.version_of("champ").unwrap(), 2);
        // rollback restores v1 and keeps v2 as the new .prev
        let r = rep.rollback(&mut reg, "champ");
        assert!(r.starts_with("ok rollback champ@v1"), "{r}");
        assert_eq!(rep.active("champ").unwrap().version, 1);
        assert_eq!(rep.active("champ").unwrap().last_good, Some(2));
        let s = rep.fleet_status(&reg, None);
        assert!(s.contains("champ@v1"), "{s}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_push_refused_and_state_untouched() {
        let dir = scratch("tamper");
        let mut rep = ReplicaState::new(&dir).unwrap();
        let mut reg = registry();
        rep.push_artifact(&mut reg, &artifact(1, 0.1).to_text());
        assert!(rep.activate(&mut reg, "champ", 1).starts_with("ok"));
        let tampered = artifact(2, 0.2).to_text().replacen("0.5", "0.9", 1);
        let r = rep.push_artifact(&mut reg, &tampered);
        assert!(r.starts_with("err push-artifact:") && r.contains("checksum"), "{r}");
        assert_eq!(rep.staged_count(), 0);
        assert_eq!(reg.version_of("champ").unwrap(), 1, "replica stays on last-good");
        // activate of a never-staged version is a typed refusal too
        let r = rep.activate(&mut reg, "champ", 9);
        assert!(r.starts_with("err") && r.contains("no staged artifact"), "{r}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rollback_without_prev_is_refused() {
        let dir = scratch("noprev");
        let mut rep = ReplicaState::new(&dir).unwrap();
        let mut reg = registry();
        rep.push_artifact(&mut reg, &artifact(1, 0.1).to_text());
        rep.activate(&mut reg, "champ", 1);
        let r = rep.rollback(&mut reg, "champ");
        assert!(r.starts_with("err") && r.contains("no last-good"), "{r}");
        assert_eq!(reg.version_of("champ").unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Sorted archived versions of `name` present on disk (ignoring
    /// `.prev` rotations).
    fn archived(rep: &ReplicaState, name: &str, upto: u64) -> Vec<u64> {
        (1..=upto).filter(|&v| rep.version_path(name, v).exists()).collect()
    }

    #[test]
    fn activation_archives_generations_and_gc_keeps_newest() {
        let dir = scratch("gc");
        let mut rep = ReplicaState::new(&dir).unwrap().with_keep(3);
        let mut reg = registry();
        for v in 1..=6 {
            rep.push_artifact(&mut reg, &artifact(v, 0.1 * v as f64).to_text());
            let r = rep.activate(&mut reg, "champ", v);
            assert!(r.starts_with("ok active"), "{r}");
        }
        // newest 3 generations survive, the primary is untouched
        assert_eq!(archived(&rep, "champ", 6), vec![4, 5, 6]);
        assert!(rep.artifact_path("champ").exists());
        // archives are loadable bundles, not copies of the primary name
        let a = Artifact::load(&rep.version_path("champ", 5)).unwrap();
        assert_eq!(a.version, 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_never_deletes_the_active_generation_archive() {
        let dir = scratch("gc_active");
        let mut rep = ReplicaState::new(&dir).unwrap().with_keep(1);
        let mut reg = registry();
        rep.push_artifact(&mut reg, &artifact(1, 0.1).to_text());
        rep.activate(&mut reg, "champ", 1);
        rep.push_artifact(&mut reg, &artifact(2, 0.2).to_text());
        rep.activate(&mut reg, "champ", 2);
        assert_eq!(archived(&rep, "champ", 2), vec![2]);
        // rollback to v1: its archive is restored and exempt from GC
        // even though v2's archive is newer
        let r = rep.rollback(&mut reg, "champ");
        assert!(r.starts_with("ok rollback champ@v1"), "{r}");
        assert!(rep.version_path("champ", 1).exists(), "active archive deleted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_ignores_archives_and_prunes_backlog() {
        let dir = scratch("gc_recover");
        {
            let mut rep = ReplicaState::new(&dir).unwrap().with_keep(10);
            let mut reg = registry();
            for v in 1..=5 {
                rep.push_artifact(&mut reg, &artifact(v, 0.1 * v as f64).to_text());
                rep.activate(&mut reg, "champ", v);
            }
            assert_eq!(archived(&rep, "champ", 5), vec![1, 2, 3, 4, 5]);
        }
        // fresh process with a tighter retention: exactly one model is
        // recovered (archives are not re-activated) and the backlog is
        // pruned down to the new depth
        let mut rep = ReplicaState::new(&dir).unwrap().with_keep(2);
        let mut reg = registry();
        let (recovered, failed) = rep.recover(&mut reg);
        assert_eq!(recovered, vec![("champ".to_string(), 5)]);
        assert!(failed.is_empty(), "{failed:?}");
        assert_eq!(archived(&rep, "champ", 5), vec![4, 5]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_reloads_disk_state_and_falls_back_to_prev() {
        let dir = scratch("recover");
        {
            let mut rep = ReplicaState::new(&dir).unwrap();
            let mut reg = registry();
            rep.push_artifact(&mut reg, &artifact(1, 0.1).to_text());
            rep.activate(&mut reg, "champ", 1);
            rep.push_artifact(&mut reg, &artifact(2, 0.2).to_text());
            rep.activate(&mut reg, "champ", 2);
        }
        // fresh process: recover re-activates v2 and sees v1 last-good
        let mut rep = ReplicaState::new(&dir).unwrap();
        let mut reg = registry();
        let (recovered, failed) = rep.recover(&mut reg);
        assert_eq!(recovered, vec![("champ".to_string(), 2)]);
        assert!(failed.is_empty(), "{failed:?}");
        assert_eq!(rep.active("champ").unwrap().last_good, Some(1));
        assert_eq!(reg.version_of("champ").unwrap(), 1); // fresh registry numbering
        // corrupt the primary: recovery serves the .prev last-good
        let p = rep.artifact_path("champ");
        let raw = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, raw.replacen("0.5", "0.9", 1)).unwrap();
        let mut rep2 = ReplicaState::new(&dir).unwrap();
        let mut reg2 = registry();
        let (recovered, failed) = rep2.recover(&mut reg2);
        assert_eq!(recovered, vec![("champ".to_string(), 1)]);
        assert!(failed.is_empty(), "{failed:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

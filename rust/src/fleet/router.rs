//! Consistent-hash request routing across serve replicas.
//!
//! Three layers:
//!
//! * [`Ring`] — the pure consistent-hash ring: each replica endpoint
//!   owns `vnodes` points placed by the same seeded
//!   [`route_hash`](crate::serve::route_hash) that drives A/B routing
//!   inside one registry, generalized from arms to shards.  A request
//!   key hashes to a position and walks clockwise to the first *alive*
//!   point.  Pure function of `(seed, endpoints, vnodes, alive set)`:
//!   same key ⇒ same replica across runs, processes, and machines —
//!   which, with the native backend's bit-identical batched margins,
//!   gives bit-identical answers for a key no matter which router
//!   instance forwarded it.  When a replica dies only the keys on its
//!   arcs move (to the next alive point); every other key keeps its
//!   assignment — the property the rebalance tests pin.
//! * [`LinkPool`] — a per-replica pool of persistent line-protocol
//!   connections.  Concurrent forwards to the same replica check out
//!   *distinct* links (blocking, with `router_pool_waits_total`, once
//!   all `pool` links are in flight); a broken link is discarded and
//!   its slot becomes a lazy reconnect — the next checkout dials a
//!   fresh connection — so one stale socket never marks the replica
//!   dead.
//! * [`Router`] + [`run_router`] — the concurrent I/O front: the
//!   accept loop hands each client connection to its own scoped
//!   reader/writer thread (the `serve/proto.rs` idiom), so N clients
//!   proceed independently; forwards overlap up to
//!   [`RouterOptions::threads`] in flight (0 = unbounded).  Consecutive
//!   already-buffered client lines owned by the same replica are
//!   pipelined over one checked-out link (the line protocol answers in
//!   order, one reply per line, so a write-k/read-k run is safe).
//!   Keyed routing semantics are unchanged from the serial router:
//!   same seeded ring assignment, exactly one *alternate replica*
//!   retry, dead-replica re-probe, and keyless round-robin (now an
//!   atomic ticket) — so keyed answers are bit-identical regardless of
//!   thread count or pool size.  Control-plane verbs are refused —
//!   they go directly to replicas via [`super::Controller`].
//!
//! The router holds no model state: it can restart at any time and
//! (given the same seed and endpoint list) reproduce the exact same
//! key→replica mapping.

use crate::error::FleetError;
use crate::serve::route_hash;
use crate::telemetry::{Counter, Histogram, Registry};
use crate::util::fault;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Accept/read poll interval (mirrors `serve/proto.rs`).
const POLL: Duration = Duration::from_millis(50);

/// Default virtual nodes per endpoint.  128 keeps the arc-length
/// imbalance low (16 shards × 10k keys lands a chi-square statistic
/// around 42 against a uniform target — see the balance test) without
/// making ring rebuilds noticeable.
pub const DEFAULT_VNODES: usize = 128;

/// Default per-replica link-pool size (`--router-pool`).
pub const DEFAULT_POOL: usize = 2;

/// The pure consistent-hash ring.
#[derive(Clone, Debug)]
pub struct Ring {
    seed: u64,
    endpoints: Vec<String>,
    alive: Vec<bool>,
    /// `(point hash, endpoint index)`, sorted by hash (ties broken by
    /// index, deterministically).
    points: Vec<(u64, usize)>,
}

impl Ring {
    /// Place `vnodes` points per endpoint with the seeded route hash.
    /// Point `v` of endpoint `e` hashes the label `"{e}#{v}"`, so the
    /// layout depends only on `(seed, endpoint strings, vnodes)`.
    pub fn new(endpoints: Vec<String>, seed: u64, vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(endpoints.len() * vnodes);
        for (i, ep) in endpoints.iter().enumerate() {
            for v in 0..vnodes {
                points.push((route_hash(seed, format!("{ep}#{v}").as_bytes()), i));
            }
        }
        points.sort_unstable();
        let alive = vec![true; endpoints.len()];
        Ring { seed, endpoints, alive, points }
    }

    pub fn endpoints(&self) -> &[String] {
        &self.endpoints
    }

    pub fn is_alive(&self, idx: usize) -> bool {
        self.alive.get(idx).copied().unwrap_or(false)
    }

    /// Take a replica out of rotation (connection failure).  Keys on
    /// its arcs fall through to the next alive point; nothing else
    /// moves.
    pub fn mark_dead(&mut self, idx: usize) {
        if let Some(a) = self.alive.get_mut(idx) {
            *a = false;
        }
    }

    /// Return a replica to rotation (successful re-probe).  Restores
    /// the exact pre-death mapping — the ring itself never changed.
    pub fn mark_alive(&mut self, idx: usize) {
        if let Some(a) = self.alive.get_mut(idx) {
            *a = true;
        }
    }

    /// Index of the first ring point at or after `hash` (wrapping).
    fn start_of(&self, hash: u64) -> usize {
        self.points.partition_point(|&(h, _)| h < hash) % self.points.len().max(1)
    }

    /// The alive replica owning `key`, walking clockwise past dead
    /// points.  `None` when no replica is alive (or the ring is empty).
    pub fn shard_of(&self, key: &[u8]) -> Option<usize> {
        self.candidates(key, 1).first().copied()
    }

    /// Up to `max` *distinct* alive replicas in ring order from `key`'s
    /// position: the owner first, then the failover targets in the
    /// order a clockwise walk reaches them.  Deterministic, so every
    /// router instance retries the same alternate for the same key.
    pub fn candidates(&self, key: &[u8], max: usize) -> Vec<usize> {
        let mut out = Vec::new();
        if self.points.is_empty() || max == 0 {
            return out;
        }
        let start = self.start_of(route_hash(self.seed, key));
        for off in 0..self.points.len() {
            let idx = self.points[(start + off) % self.points.len()].1;
            if self.alive[idx] && !out.contains(&idx) {
                out.push(idx);
                if out.len() == max {
                    break;
                }
            }
        }
        out
    }

    /// Number of alive replicas.
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }
}

/// Knobs for the I/O router.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterOptions {
    /// Ring seed — must match across router instances (and restarts)
    /// for the fleet-wide same-key-same-replica guarantee.
    pub seed: u64,
    /// Virtual nodes per endpoint.
    pub vnodes: usize,
    /// Per-forward reply deadline.
    pub timeout: Duration,
    /// How often dead replicas are re-probed.
    pub probe_every: Duration,
    /// Links per replica in the connection pool (`--router-pool`,
    /// clamped to ≥ 1).  Concurrent forwards to one replica use
    /// distinct links; past `pool` in flight they wait.
    pub pool: usize,
    /// Max forwards in flight across all client connections
    /// (`--router-threads`); 0 = unbounded (one worker per client).
    pub threads: usize,
}

impl Default for RouterOptions {
    fn default() -> Self {
        Self {
            seed: 1,
            vnodes: DEFAULT_VNODES,
            timeout: Duration::from_secs(5),
            probe_every: Duration::from_secs(2),
            pool: DEFAULT_POOL,
            threads: 0,
        }
    }
}

/// Lifetime counters from a completed [`run_router`] run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterReport {
    pub connections: u64,
    /// Lines successfully forwarded and answered.
    pub forwarded: u64,
    /// Forwards that needed more than their first attempt (a fresh
    /// link to the same replica, or the alternate replica).
    pub retried: u64,
    /// Lines answered locally with `err` (control verbs, no replica).
    pub rejected: u64,
    /// Replica links dialed over the run (pool fills + reconnects) —
    /// the pool-reuse evidence, counted like `worker_spawns`.
    pub links_opened: u64,
    /// Checkouts that had to wait for a pooled link.
    pub pool_waits: u64,
    /// Lines forwarded as part of a pipelined same-replica run.
    pub pipelined: u64,
    /// `mark_dead` events (a replica leaving rotation).
    pub replica_dead: u64,
}

/// Registered handles for the router telemetry (the PR-9 surface; the
/// `router-stats` verb renders these as one line).
struct RouterMetrics {
    registry: Arc<Registry>,
    forwards: Arc<Counter>,
    retries: Arc<Counter>,
    replica_dead: Arc<Counter>,
    pool_waits: Arc<Counter>,
    links_opened: Arc<Counter>,
    pipelined: Arc<Counter>,
    rejected: Arc<Counter>,
    forward_ns: Arc<Histogram>,
}

impl RouterMetrics {
    fn new() -> Self {
        let registry = Registry::new();
        Self {
            forwards: registry.counter("router_forwards_total"),
            retries: registry.counter("router_retries_total"),
            replica_dead: registry.counter("router_replica_dead_total"),
            pool_waits: registry.counter("router_pool_waits_total"),
            links_opened: registry.counter("router_links_opened_total"),
            pipelined: registry.counter("router_pipelined_total"),
            rejected: registry.counter("router_rejected_total"),
            forward_ns: registry.histogram("router_forward_ns"),
            registry,
        }
    }

    /// The `stats`-line view: one greppable reply line, mirroring the
    /// serve `stats` verb's shape.
    fn stats_line(&self) -> String {
        let h = self.forward_ns.snapshot();
        format!(
            "ok router forwards={} retries={} dead={} pool_waits={} connects={} \
             pipelined={} rejected={} p50_ns={} p99_ns={}",
            self.forwards.get(),
            self.retries.get(),
            self.replica_dead.get(),
            self.pool_waits.get(),
            self.links_opened.get(),
            self.pipelined.get(),
            self.rejected.get(),
            h.quantile(0.50),
            h.quantile(0.99),
        )
    }
}

/// One pooled replica link.
type Link = BufReader<TcpStream>;

struct PoolState {
    idle: Vec<Link>,
    /// Links currently checked out *plus* idle.len(): the number of
    /// live slots.  A discarded (broken) link frees its slot, so the
    /// next checkout re-dials — the lazy reconnect queue.
    occupied: usize,
}

/// A per-replica connection pool: at most `cap` links exist at once;
/// checkout hands out idle links first, dials a fresh one while slots
/// remain, and blocks (counting a pool wait) when every link is in
/// flight.
struct LinkPool {
    cap: usize,
    state: Mutex<PoolState>,
    available: Condvar,
}

/// What a checkout handed back.
enum Checkout {
    /// An existing pooled link.
    Reused(Link),
    /// A slot was free but empty: the caller dials the connection.
    Dial,
}

impl LinkPool {
    fn new(cap: usize) -> LinkPool {
        LinkPool {
            cap: cap.max(1),
            state: Mutex::new(PoolState { idle: Vec::new(), occupied: 0 }),
            available: Condvar::new(),
        }
    }

    /// Non-blocking checkout: `None` when every link is in flight.
    /// Used by the dead-replica probe, which must never stall a
    /// forward behind a busy pool.
    fn try_checkout(&self) -> Option<Checkout> {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(link) = st.idle.pop() {
            return Some(Checkout::Reused(link));
        }
        if st.occupied < self.cap {
            st.occupied += 1;
            return Some(Checkout::Dial);
        }
        None
    }

    /// Check out a link slot, blocking while all `cap` links are in
    /// flight.  `waits` counts each block.
    fn checkout(&self, waits: &Counter) -> Checkout {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(link) = st.idle.pop() {
                return Checkout::Reused(link);
            }
            if st.occupied < self.cap {
                st.occupied += 1;
                return Checkout::Dial;
            }
            waits.inc();
            st = self.available.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Return a healthy link to the pool.
    fn checkin(&self, link: Link) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.idle.push(link);
        self.available.notify_one();
    }

    /// Drop a broken link (or an aborted dial): the slot re-opens for
    /// a future reconnect.
    fn discard(&self) {
        let mut st = self.state.lock().unwrap_or_else(|p| p.into_inner());
        st.occupied = st.occupied.saturating_sub(1);
        self.available.notify_one();
    }
}

/// Bounds forwards in flight when [`RouterOptions::threads`] > 0.
struct ForwardGate {
    cap: usize,
    free: Mutex<usize>,
    cv: Condvar,
}

impl ForwardGate {
    fn new(cap: usize) -> ForwardGate {
        ForwardGate { cap, free: Mutex::new(cap), cv: Condvar::new() }
    }

    fn acquire(&self) {
        if self.cap == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap_or_else(|p| p.into_inner());
        while *free == 0 {
            free = self.cv.wait(free).unwrap_or_else(|p| p.into_inner());
        }
        *free -= 1;
    }

    fn release(&self) {
        if self.cap == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap_or_else(|p| p.into_inner());
        *free += 1;
        self.cv.notify_one();
    }
}

/// The concurrent forwarding core: shared by every client worker
/// through `&self` — the ring sits behind one short-critical-section
/// mutex, replica links live in per-replica [`LinkPool`]s, and the
/// round-robin ticket is an atomic.
pub struct Router {
    ring: Mutex<Ring>,
    pools: Vec<LinkPool>,
    timeout: Duration,
    probe_every: Duration,
    last_probe: Mutex<Instant>,
    /// Rotating ticket for unkeyed requests.
    rr: AtomicU64,
    gate: ForwardGate,
    metrics: RouterMetrics,
}

impl Router {
    pub fn new(endpoints: Vec<String>, opts: &RouterOptions) -> Router {
        let n = endpoints.len();
        Router {
            ring: Mutex::new(Ring::new(endpoints, opts.seed, opts.vnodes)),
            pools: (0..n).map(|_| LinkPool::new(opts.pool)).collect(),
            timeout: opts.timeout,
            probe_every: opts.probe_every,
            last_probe: Mutex::new(Instant::now()),
            rr: AtomicU64::new(0),
            gate: ForwardGate::new(opts.threads),
            metrics: RouterMetrics::new(),
        }
    }

    /// Run `f` under the ring lock (candidate selection, liveness).
    fn with_ring<R>(&self, f: impl FnOnce(&mut Ring) -> R) -> R {
        let mut ring = self.ring.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut ring)
    }

    fn endpoint(&self, idx: usize) -> String {
        self.with_ring(|r| r.endpoints()[idx].clone())
    }

    fn mark_dead(&self, idx: usize) {
        let newly = self.with_ring(|r| {
            let was = r.is_alive(idx);
            r.mark_dead(idx);
            was
        });
        if newly {
            self.metrics.replica_dead.inc();
        }
    }

    fn dial(&self, idx: usize) -> std::io::Result<Link> {
        let ep = self.endpoint(idx);
        let stream = TcpStream::connect(&ep)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POLL))?;
        stream.set_write_timeout(Some(self.timeout))?;
        self.metrics.links_opened.inc();
        Ok(BufReader::new(stream))
    }

    /// Periodically try to bring dead replicas back into rotation.
    fn maybe_probe(&self) {
        {
            let mut last = self.last_probe.lock().unwrap_or_else(|p| p.into_inner());
            if last.elapsed() < self.probe_every {
                return;
            }
            *last = Instant::now();
        }
        let dead: Vec<usize> = self.with_ring(|r| {
            (0..r.endpoints().len()).filter(|&i| !r.is_alive(i)).collect()
        });
        for idx in dead {
            if let Ok(link) = self.dial(idx) {
                // seed the revived replica's pool with the probe link
                // if a slot is free; otherwise just drop it
                match self.pools[idx].try_checkout() {
                    Some(Checkout::Reused(old)) => {
                        self.pools[idx].checkin(old);
                        drop(link);
                    }
                    Some(Checkout::Dial) => self.pools[idx].checkin(link),
                    None => drop(link),
                }
                self.with_ring(|r| r.mark_alive(idx));
            }
        }
    }

    /// Write `lines` to `link` and read one reply per line, in order.
    /// The [`fault::site::ROUTER_LINK`] hook fires once per exchange:
    /// `io` breaks the link before any bytes move, `stall:MS` delays
    /// it (a slow replica link).
    fn exchange(&self, link: &mut Link, lines: &[&str]) -> std::io::Result<Vec<String>> {
        match fault::armed(fault::site::ROUTER_LINK) {
            Some(fault::FaultKind::Io) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Other,
                    "injected router link fault",
                ))
            }
            Some(fault::FaultKind::Stall(ms)) => std::thread::sleep(Duration::from_millis(ms)),
            _ => {}
        }
        {
            let stream = link.get_mut();
            for line in lines {
                stream.write_all(line.as_bytes())?;
                stream.write_all(b"\n")?;
            }
            stream.flush()?;
        }
        let start = Instant::now();
        let mut replies = Vec::with_capacity(lines.len());
        let mut buf: Vec<u8> = Vec::new();
        while replies.len() < lines.len() {
            buf.clear();
            loop {
                match link.read_until(b'\n', &mut buf) {
                    Ok(0) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "replica closed the connection",
                        ))
                    }
                    Ok(_) if buf.last() == Some(&b'\n') => {
                        let text = std::str::from_utf8(&buf).map_err(|_| {
                            std::io::Error::new(
                                std::io::ErrorKind::InvalidData,
                                "replica reply is not UTF-8",
                            )
                        })?;
                        replies.push(text.trim_end().to_string());
                        break;
                    }
                    Ok(_) => {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::UnexpectedEof,
                            "replica reply torn mid-line",
                        ))
                    }
                    Err(e)
                        if e.kind() == std::io::ErrorKind::WouldBlock
                            || e.kind() == std::io::ErrorKind::TimedOut
                            || e.kind() == std::io::ErrorKind::Interrupted =>
                    {
                        if start.elapsed() >= self.timeout {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                "replica reply deadline exceeded",
                            ));
                        }
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        Ok(replies)
    }

    /// One attempt pass against replica `idx`: check out a link (or
    /// dial into a free slot), run the exchange, and check the link
    /// back in on success.  A *reused* link that fails is discarded
    /// and retried once over a freshly dialed link before giving up on
    /// the replica — a stale pooled socket is a link failure, not a
    /// replica death.  `Err` here means the replica itself failed.
    fn try_replica(
        &self,
        idx: usize,
        lines: &[&str],
        retried: &mut bool,
    ) -> std::io::Result<Vec<String>> {
        let pool = &self.pools[idx];
        let (mut link, reused) = match pool.checkout(&self.metrics.pool_waits) {
            Checkout::Reused(l) => (l, true),
            Checkout::Dial => match self.dial(idx) {
                Ok(l) => (l, false),
                Err(e) => {
                    pool.discard();
                    return Err(e);
                }
            },
        };
        match self.exchange(&mut link, lines) {
            Ok(replies) => {
                pool.checkin(link);
                return Ok(replies);
            }
            Err(first) => {
                // broken link: free the slot (lazy reconnect queue)
                drop(link);
                pool.discard();
                if !reused {
                    return Err(first);
                }
            }
        }
        // the pooled link was stale; one fresh-link retry on the same
        // replica before declaring it dead
        *retried = true;
        let mut link = match pool.checkout(&self.metrics.pool_waits) {
            Checkout::Reused(l) => l,
            Checkout::Dial => match self.dial(idx) {
                Ok(l) => l,
                Err(e) => {
                    pool.discard();
                    return Err(e);
                }
            },
        };
        match self.exchange(&mut link, lines) {
            Ok(replies) => {
                pool.checkin(link);
                Ok(replies)
            }
            Err(e) => {
                drop(link);
                pool.discard();
                Err(e)
            }
        }
    }

    /// Candidate replicas for one request: the ring walk for keyed
    /// lines, an atomic round-robin ticket (plus one alternate) for
    /// keyless ones.
    fn candidates_for(&self, key: Option<&[u8]>) -> Vec<usize> {
        match key {
            Some(k) => self.with_ring(|r| r.candidates(k, 2)),
            None => self.with_ring(|r| {
                let alive: Vec<usize> =
                    (0..r.endpoints().len()).filter(|&i| r.is_alive(i)).collect();
                if alive.is_empty() {
                    return Vec::new();
                }
                let ticket = self.rr.fetch_add(1, Ordering::Relaxed) as usize;
                let first = alive[ticket % alive.len()];
                let mut c = vec![first];
                if alive.len() > 1 {
                    c.push(alive[(ticket + 1) % alive.len()]);
                }
                c
            }),
        }
    }

    /// Forward `lines` (all owned by the same candidate set) to their
    /// replica, retrying a stale link once and then exactly one
    /// alternate replica, marking failed replicas dead.
    fn forward_to(
        &self,
        candidates: &[usize],
        lines: &[&str],
    ) -> Result<Vec<String>, FleetError> {
        if candidates.is_empty() {
            return Err(FleetError::NoReplica { detail: "every replica is out of rotation".into() });
        }
        let start = Instant::now();
        let mut last_err = String::new();
        for (attempt, &idx) in candidates.iter().enumerate() {
            let mut link_retried = false;
            match self.try_replica(idx, lines, &mut link_retried) {
                Ok(replies) => {
                    if attempt > 0 || link_retried {
                        self.metrics.retries.inc();
                    }
                    self.metrics.forwards.add(lines.len() as u64);
                    if lines.len() > 1 {
                        self.metrics.pipelined.add(lines.len() as u64);
                    }
                    self.metrics.forward_ns.observe_duration(start.elapsed());
                    return Ok(replies);
                }
                Err(e) => {
                    last_err = format!("{}: {e}", self.endpoint(idx));
                    self.mark_dead(idx);
                }
            }
        }
        Err(FleetError::NoReplica {
            detail: format!("primary and alternate both failed (last: {last_err})"),
        })
    }

    /// Forward one request line (see [`Router::forward_to`] for the
    /// retry contract).
    pub fn forward_line(&self, key: Option<&[u8]>, line: &str) -> Result<String, FleetError> {
        self.maybe_probe();
        let candidates = self.candidates_for(key);
        self.forward_to(&candidates, &[line])
            .map(|mut replies| replies.pop().unwrap_or_default())
    }

    /// Lifetime counters (for [`RouterReport`]).
    fn report(&self, connections: u64) -> RouterReport {
        RouterReport {
            connections,
            forwarded: self.metrics.forwards.get(),
            retried: self.metrics.retries.get(),
            rejected: self.metrics.rejected.get(),
            links_opened: self.metrics.links_opened.get(),
            pool_waits: self.metrics.pool_waits.get(),
            pipelined: self.metrics.pipelined.get(),
            replica_dead: self.metrics.replica_dead.get(),
        }
    }

    /// The full telemetry registry behind the `router-stats` line (a
    /// scrape surface for embedders; `run_router` only exposes the
    /// one-line view).
    pub fn render_metrics(&self) -> String {
        self.metrics.registry.render()
    }
}

/// Verbs the router refuses to forward: model distribution goes
/// through the control plane directly to each replica, never through
/// the data-plane front.
fn is_control_verb(cmd: &str) -> bool {
    matches!(cmd, "push-artifact" | "activate" | "rollback" | "fleet-status" | "swap-model")
}

/// Run the data-plane router until a `shutdown` line: accept client
/// connections, hand each to its own worker thread, forward request
/// lines to their consistent-hash replicas over pooled links, relay
/// the replies.  `shutdown` stops the *router* only — replicas are
/// shut down directly (or by the controller).  `router-stats` answers
/// locally with the telemetry line.
pub fn run_router(
    listener: TcpListener,
    endpoints: Vec<String>,
    opts: &RouterOptions,
) -> Result<RouterReport, FleetError> {
    listener
        .set_nonblocking(true)
        .map_err(|e| FleetError::Io { path: "router listener".into(), detail: e.to_string() })?;
    let stop = AtomicBool::new(false);
    let connections = AtomicU64::new(0);
    let core = Router::new(endpoints, opts);
    std::thread::scope(|s| {
        let stop = &stop;
        let core = &core;
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    connections.fetch_add(1, Ordering::Relaxed);
                    s.spawn(move || {
                        client_loop(stream, core, stop);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(e) => {
                    stop.store(true, Ordering::Relaxed);
                    return Err(FleetError::Io {
                        path: "router accept".into(),
                        detail: e.to_string(),
                    });
                }
            }
        }
        Ok(())
    })?;
    Ok(core.report(connections.into_inner()))
}

/// One parsed client request line.
struct Request {
    line: String,
    key: Option<Vec<u8>>,
}

/// Parse the `key=` token (second whitespace field) of a request line.
fn key_of(line: &str) -> Option<Vec<u8>> {
    line.split_ascii_whitespace()
        .nth(1)
        .and_then(|t| t.strip_prefix("key="))
        .map(|k| k.as_bytes().to_vec())
}

/// One client connection worker: reads request lines, answers local
/// verbs (`shutdown`, `router-stats`), refuses control verbs, and
/// forwards the rest — pipelining consecutive already-buffered lines
/// that the ring assigns to the same replica.  Replies always go back
/// in request order.
fn client_loop(stream: TcpStream, core: &Router, stop: &AtomicBool) {
    if stream.set_nonblocking(false).is_err() || stream.set_read_timeout(Some(POLL)).is_err() {
        return;
    }
    let mut write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut rd = BufReader::new(&stream);
    let mut buf: Vec<u8> = Vec::new();
    'conn: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // NB: no clear here — a WouldBlock mid-line leaves the partial
        // bytes in `buf` and the next pass appends the rest; the Ok
        // path empties it via mem::take.
        match rd.read_until(b'\n', &mut buf) {
            Ok(0) => break,
            Ok(_) => {
                let mut replies: Vec<String> = Vec::new();
                let mut pending: Vec<Request> = Vec::new();
                // the line just read, plus every *complete* line the
                // client has already buffered behind it — those are
                // the pipelining candidates
                let mut lines: Vec<Vec<u8>> = vec![std::mem::take(&mut buf)];
                while let Some(nl) = rd.buffer().iter().position(|&b| b == b'\n') {
                    let mut extra = vec![0u8; nl + 1];
                    if std::io::Read::read_exact(&mut rd, &mut extra).is_err() {
                        break;
                    }
                    lines.push(extra);
                }
                for raw in lines {
                    match std::str::from_utf8(&raw) {
                        Ok(text) => {
                            let line = text.trim();
                            if line.is_empty() {
                                continue;
                            }
                            let cmd = line.split_ascii_whitespace().next().unwrap_or("");
                            if cmd == "shutdown" {
                                flush_pending(core, stop, &mut pending, &mut replies);
                                replies.push("ok bye".to_string());
                                send_replies(&mut write_half, &replies);
                                stop.store(true, Ordering::Relaxed);
                                break 'conn;
                            }
                            if cmd == "router-stats" {
                                flush_pending(core, stop, &mut pending, &mut replies);
                                replies.push(core.metrics.stats_line());
                            } else if is_control_verb(cmd) {
                                flush_pending(core, stop, &mut pending, &mut replies);
                                core.metrics.rejected.inc();
                                replies.push(format!(
                                    "err router: {cmd} goes directly to replicas, not the router"
                                ));
                            } else {
                                pending.push(Request {
                                    line: line.to_string(),
                                    key: key_of(line),
                                });
                            }
                        }
                        Err(_) => {
                            flush_pending(core, stop, &mut pending, &mut replies);
                            core.metrics.rejected.inc();
                            replies.push("err line is not valid UTF-8".to_string());
                        }
                    }
                }
                flush_pending(core, stop, &mut pending, &mut replies);
                if !send_replies(&mut write_half, &replies) {
                    break;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
}

/// Forward every pending request, grouping maximal *consecutive* runs
/// whose primary candidate is the same replica into one pipelined
/// exchange (reply order is preserved: the line protocol answers in
/// order on one connection, and runs flush in arrival order).  A
/// failed pipelined run falls back to per-line forwarding, so the
/// retry contract stays per-request.
fn flush_pending(
    core: &Router,
    stop: &AtomicBool,
    pending: &mut Vec<Request>,
    replies: &mut Vec<String>,
) {
    let requests = std::mem::take(pending);
    if requests.is_empty() {
        return;
    }
    core.gate.acquire();
    core.maybe_probe();
    let mut i = 0;
    while i < requests.len() {
        let candidates = core.candidates_for(requests[i].key.as_deref());
        // extend the run while the next line's primary owner matches
        let mut j = i + 1;
        while j < requests.len() {
            let next = core.candidates_for(requests[j].key.as_deref());
            if next.first() != candidates.first() || next != candidates {
                break;
            }
            j += 1;
        }
        let run: Vec<&str> = requests[i..j].iter().map(|r| r.line.as_str()).collect();
        if run.len() == 1 {
            match core.forward_to(&candidates, &run) {
                Ok(mut r) => replies.push(r.pop().unwrap_or_default()),
                Err(e) => {
                    core.metrics.rejected.inc();
                    replies.push(format!("err {e}"));
                }
            }
        } else {
            match core.forward_to(&candidates, &run) {
                Ok(r) => replies.extend(r),
                Err(_) => {
                    // pipelined run failed wholesale: re-forward each
                    // line individually through the full retry path
                    for req in &requests[i..j] {
                        let cands = core.candidates_for(req.key.as_deref());
                        match core.forward_to(&cands, &[req.line.as_str()]) {
                            Ok(mut r) => replies.push(r.pop().unwrap_or_default()),
                            Err(e) => {
                                core.metrics.rejected.inc();
                                replies.push(format!("err {e}"));
                            }
                        }
                    }
                }
            }
        }
        i = j;
    }
    core.gate.release();
}

/// Write reply lines back to the client; false on a broken client.
fn send_replies(write_half: &mut TcpStream, replies: &[String]) -> bool {
    let mut out = String::new();
    for r in replies {
        out.push_str(r);
        out.push('\n');
    }
    write_half.write_all(out.as_bytes()).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eps(prefix: &str, n: usize) -> Vec<String> {
        (0..n).map(|i| format!("{prefix}{i}")).collect()
    }

    /// Satellite: chi-square-style balance over 16 shards × 10k keys.
    /// The exact statistic for this (seed, vnodes) layout is ≈41.7
    /// (computed independently from the hash definition); the bound
    /// leaves room without admitting a broken ring (uniform-on-4-shards
    /// style failures score in the thousands).
    #[test]
    fn balance_16_shards_10k_keys_chi_square_bounded() {
        let ring = Ring::new(eps("replica-", 16), 7, 128);
        let mut counts = [0usize; 16];
        for k in 0..10_000 {
            counts[ring.shard_of(format!("key-{k}").as_bytes()).unwrap()] += 1;
        }
        let expected = 10_000.0 / 16.0;
        let chi2: f64 =
            counts.iter().map(|&c| (c as f64 - expected).powi(2) / expected).sum();
        assert!(chi2 < 120.0, "chi-square {chi2:.1} too large: {counts:?}");
        for (i, &c) in counts.iter().enumerate() {
            assert!((400..=900).contains(&c), "shard {i} got {c} of 10000: {counts:?}");
        }
    }

    /// Satellite: replica-set changes move only the affected arcs.
    #[test]
    fn death_remaps_only_the_dead_replicas_keys() {
        let mut ring = Ring::new(eps("r", 8), 7, 128);
        let keys: Vec<String> = (0..4000).map(|k| format!("k-{k}")).collect();
        let before: Vec<usize> =
            keys.iter().map(|k| ring.shard_of(k.as_bytes()).unwrap()).collect();
        ring.mark_dead(3);
        let mut moved = 0usize;
        for (k, &b) in keys.iter().zip(&before) {
            let a = ring.shard_of(k.as_bytes()).unwrap();
            if b == 3 {
                moved += 1;
                assert_ne!(a, 3, "key {k} still on the dead replica");
            } else {
                assert_eq!(a, b, "unaffected key {k} moved");
            }
        }
        // the dead replica held ~1/8 of the keys (434 for this layout)
        assert!((250..=750).contains(&moved), "moved {moved} of 4000");
        // revival restores the exact original mapping
        ring.mark_alive(3);
        for (k, &b) in keys.iter().zip(&before) {
            assert_eq!(ring.shard_of(k.as_bytes()).unwrap(), b);
        }
    }

    /// Removing an endpoint from the ring entirely (vs marking it
    /// dead) also only remaps its own keys — surviving endpoints keep
    /// their vnode points, so their keys cannot move.
    #[test]
    fn endpoint_removal_keeps_surviving_assignments() {
        let all = eps("node-", 6);
        let ring_all = Ring::new(all.clone(), 9, 128);
        let mut fewer = all.clone();
        fewer.remove(2);
        let ring_fewer = Ring::new(fewer.clone(), 9, 128);
        for k in 0..2000 {
            let key = format!("user-{k}");
            let before = &all[ring_all.shard_of(key.as_bytes()).unwrap()];
            let after = &fewer[ring_fewer.shard_of(key.as_bytes()).unwrap()];
            if before != "node-2" {
                assert_eq!(before, after, "key {key} moved off a surviving endpoint");
            } else {
                assert_ne!(after, "node-2");
            }
        }
    }

    /// Satellite: cross-process determinism.  The expected shard
    /// indices were computed by an independent implementation of the
    /// hash + ring (outside this codebase), so any drift in
    /// `route_hash`, the vnode labeling, or the clockwise walk breaks
    /// this test — same seed ⇒ same mapping, on every build.
    #[test]
    fn golden_mapping_pins_cross_process_determinism() {
        // route_hash itself first
        assert_eq!(route_hash(0, b""), 0xc3817c016ba4ff30);
        assert_eq!(route_hash(7, b"user-0"), 0x757304dd7f0f80b2);
        assert_eq!(route_hash(7, b"user-1"), 0x7acc36fe4d39a59a);
        assert_eq!(route_hash(42, b"abc"), 0xab96b84dcf0484eb);
        assert_eq!(route_hash(0xdead_beef, b"mmbsgd"), 0xb544d24441f1fd6d);
        // then the full ring walk
        let endpoints: Vec<String> = (0..4).map(|i| format!("10.0.0.{i}:9000")).collect();
        let ring = Ring::new(endpoints, 42, 64);
        for (key, shard) in [
            ("alpha", 0usize),
            ("bravo", 0),
            ("charlie", 3),
            ("delta", 0),
            ("echo", 3),
            ("foxtrot", 2),
            ("golf", 3),
            ("hotel", 0),
        ] {
            assert_eq!(ring.shard_of(key.as_bytes()), Some(shard), "key {key:?}");
        }
    }

    #[test]
    fn candidates_are_distinct_alive_and_ordered() {
        let mut ring = Ring::new(eps("r", 4), 3, 64);
        let c = ring.candidates(b"some-key", 4);
        assert_eq!(c.len(), 4);
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "candidates must be distinct: {c:?}");
        // the failover target is the next candidate, skipping the dead
        let primary = c[0];
        ring.mark_dead(primary);
        assert_eq!(ring.shard_of(b"some-key"), Some(c[1]));
        // all dead -> None
        for i in 0..4 {
            ring.mark_dead(i);
        }
        assert_eq!(ring.shard_of(b"some-key"), None);
        assert_eq!(ring.alive_count(), 0);
        // empty ring never panics
        let empty = Ring::new(Vec::new(), 1, 8);
        assert_eq!(empty.shard_of(b"k"), None);
    }

    #[test]
    fn control_verbs_are_refused_at_the_router() {
        for v in ["push-artifact", "activate", "rollback", "fleet-status", "swap-model"] {
            assert!(is_control_verb(v), "{v}");
        }
        for v in ["predict", "decision", "feedback", "stats", "router-stats"] {
            assert!(!is_control_verb(v), "{v}");
        }
    }

    /// The link pool hands out at most `cap` slots, blocks past that,
    /// and re-opens a slot on discard (the lazy reconnect queue).
    #[test]
    fn link_pool_caps_slots_and_recycles_on_discard() {
        let pool = LinkPool::new(2);
        let waits = Counter::default();
        assert!(matches!(pool.checkout(&waits), Checkout::Dial));
        assert!(matches!(pool.checkout(&waits), Checkout::Dial));
        // both slots occupied: a third checkout must wait until one
        // frees — prove it by discarding from another thread
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(50));
                pool.discard();
            });
            assert!(matches!(pool.checkout(&waits), Checkout::Dial));
        });
        assert!(waits.get() >= 1, "the blocked checkout must count a pool wait");
    }

    /// The forward gate bounds in-flight forwards at `cap`, and cap 0
    /// means unbounded (acquire never blocks).
    #[test]
    fn forward_gate_bounds_in_flight() {
        let gate = ForwardGate::new(1);
        gate.acquire();
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(50));
                gate.release();
            });
            // blocks until the release above
            gate.acquire();
        });
        gate.release();
        let open = ForwardGate::new(0);
        open.acquire();
        open.acquire(); // unbounded: never blocks
    }

    #[test]
    fn key_parse_matches_line_protocol_shape() {
        assert_eq!(key_of("decision key=alice 1 2 3"), Some(b"alice".to_vec()));
        assert_eq!(key_of("decision 1 2 3"), None);
        assert_eq!(key_of("stats"), None);
    }
}

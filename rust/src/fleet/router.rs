//! Consistent-hash request routing across serve replicas.
//!
//! Two layers:
//!
//! * [`Ring`] — the pure consistent-hash ring: each replica endpoint
//!   owns `vnodes` points placed by the same seeded
//!   [`route_hash`](crate::serve::route_hash) that drives A/B routing
//!   inside one registry, generalized from arms to shards.  A request
//!   key hashes to a position and walks clockwise to the first *alive*
//!   point.  Pure function of `(seed, endpoints, vnodes, alive set)`:
//!   same key ⇒ same replica across runs, processes, and machines —
//!   which, with the native backend's bit-identical batched margins,
//!   gives bit-identical answers for a key no matter which router
//!   instance forwarded it.  When a replica dies only the keys on its
//!   arcs move (to the next alive point); every other key keeps its
//!   assignment — the property the rebalance tests pin.
//! * [`Router`] + [`run_router`] — the I/O front: a TCP listener that
//!   forwards each keyed request line to its ring replica over a
//!   persistent connection, retries **one** alternate replica on
//!   connection failure (marking the first dead), and re-probes dead
//!   replicas periodically.  Control-plane verbs are refused — they go
//!   directly to replicas via [`super::Controller`].
//!
//! The router holds no model state: it can restart at any time and
//! (given the same seed and endpoint list) reproduce the exact same
//! key→replica mapping.

use crate::error::FleetError;
use crate::serve::route_hash;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Accept/read poll interval (mirrors `serve/proto.rs`).
const POLL: Duration = Duration::from_millis(50);

/// Default virtual nodes per endpoint.  128 keeps the arc-length
/// imbalance low (16 shards × 10k keys lands a chi-square statistic
/// around 42 against a uniform target — see the balance test) without
/// making ring rebuilds noticeable.
pub const DEFAULT_VNODES: usize = 128;

/// The pure consistent-hash ring.
#[derive(Clone, Debug)]
pub struct Ring {
    seed: u64,
    endpoints: Vec<String>,
    alive: Vec<bool>,
    /// `(point hash, endpoint index)`, sorted by hash (ties broken by
    /// index, deterministically).
    points: Vec<(u64, usize)>,
}

impl Ring {
    /// Place `vnodes` points per endpoint with the seeded route hash.
    /// Point `v` of endpoint `e` hashes the label `"{e}#{v}"`, so the
    /// layout depends only on `(seed, endpoint strings, vnodes)`.
    pub fn new(endpoints: Vec<String>, seed: u64, vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(endpoints.len() * vnodes);
        for (i, ep) in endpoints.iter().enumerate() {
            for v in 0..vnodes {
                points.push((route_hash(seed, format!("{ep}#{v}").as_bytes()), i));
            }
        }
        points.sort_unstable();
        let alive = vec![true; endpoints.len()];
        Ring { seed, endpoints, alive, points }
    }

    pub fn endpoints(&self) -> &[String] {
        &self.endpoints
    }

    pub fn is_alive(&self, idx: usize) -> bool {
        self.alive.get(idx).copied().unwrap_or(false)
    }

    /// Take a replica out of rotation (connection failure).  Keys on
    /// its arcs fall through to the next alive point; nothing else
    /// moves.
    pub fn mark_dead(&mut self, idx: usize) {
        if let Some(a) = self.alive.get_mut(idx) {
            *a = false;
        }
    }

    /// Return a replica to rotation (successful re-probe).  Restores
    /// the exact pre-death mapping — the ring itself never changed.
    pub fn mark_alive(&mut self, idx: usize) {
        if let Some(a) = self.alive.get_mut(idx) {
            *a = true;
        }
    }

    /// Index of the first ring point at or after `hash` (wrapping).
    fn start_of(&self, hash: u64) -> usize {
        self.points.partition_point(|&(h, _)| h < hash) % self.points.len().max(1)
    }

    /// The alive replica owning `key`, walking clockwise past dead
    /// points.  `None` when no replica is alive (or the ring is empty).
    pub fn shard_of(&self, key: &[u8]) -> Option<usize> {
        self.candidates(key, 1).first().copied()
    }

    /// Up to `max` *distinct* alive replicas in ring order from `key`'s
    /// position: the owner first, then the failover targets in the
    /// order a clockwise walk reaches them.  Deterministic, so every
    /// router instance retries the same alternate for the same key.
    pub fn candidates(&self, key: &[u8], max: usize) -> Vec<usize> {
        let mut out = Vec::new();
        if self.points.is_empty() || max == 0 {
            return out;
        }
        let start = self.start_of(route_hash(self.seed, key));
        for off in 0..self.points.len() {
            let idx = self.points[(start + off) % self.points.len()].1;
            if self.alive[idx] && !out.contains(&idx) {
                out.push(idx);
                if out.len() == max {
                    break;
                }
            }
        }
        out
    }

    /// Number of alive replicas.
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }
}

/// Knobs for the I/O router.
#[derive(Clone, Debug, PartialEq)]
pub struct RouterOptions {
    /// Ring seed — must match across router instances (and restarts)
    /// for the fleet-wide same-key-same-replica guarantee.
    pub seed: u64,
    /// Virtual nodes per endpoint.
    pub vnodes: usize,
    /// Per-forward reply deadline.
    pub timeout: Duration,
    /// How often dead replicas are re-probed.
    pub probe_every: Duration,
}

impl Default for RouterOptions {
    fn default() -> Self {
        Self {
            seed: 1,
            vnodes: DEFAULT_VNODES,
            timeout: Duration::from_secs(5),
            probe_every: Duration::from_secs(2),
        }
    }
}

/// Lifetime counters from a completed [`run_router`] run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterReport {
    pub connections: u64,
    /// Lines successfully forwarded and answered.
    pub forwarded: u64,
    /// Forwards that succeeded only on the alternate replica.
    pub retried: u64,
    /// Lines answered locally with `err` (control verbs, no replica).
    pub rejected: u64,
}

/// The stateful forwarding core: ring + one persistent connection per
/// replica.  Not thread-safe by itself; [`run_router`] wraps it in a
/// mutex (one in-flight forward at a time — the scale-out story is
/// more router processes, which the ring's determinism makes safe).
pub struct Router {
    ring: Ring,
    conns: Vec<Option<BufReader<TcpStream>>>,
    timeout: Duration,
    probe_every: Duration,
    last_probe: Instant,
    /// Rotating ticket for unkeyed requests.
    rr: u64,
    pub retried: u64,
}

impl Router {
    pub fn new(endpoints: Vec<String>, opts: &RouterOptions) -> Router {
        let n = endpoints.len();
        Router {
            ring: Ring::new(endpoints, opts.seed, opts.vnodes),
            conns: (0..n).map(|_| None).collect(),
            timeout: opts.timeout,
            probe_every: opts.probe_every,
            last_probe: Instant::now(),
            rr: 0,
            retried: 0,
        }
    }

    pub fn ring(&self) -> &Ring {
        &self.ring
    }

    fn connect(&self, idx: usize) -> std::io::Result<BufReader<TcpStream>> {
        let ep = &self.ring.endpoints()[idx];
        let stream = TcpStream::connect(ep)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POLL))?;
        stream.set_write_timeout(Some(self.timeout))?;
        Ok(BufReader::new(stream))
    }

    /// Periodically try to bring dead replicas back into rotation.
    fn maybe_probe(&mut self) {
        if self.last_probe.elapsed() < self.probe_every {
            return;
        }
        self.last_probe = Instant::now();
        for idx in 0..self.ring.endpoints().len() {
            if !self.ring.is_alive(idx) {
                if let Ok(conn) = self.connect(idx) {
                    self.conns[idx] = Some(conn);
                    self.ring.mark_alive(idx);
                }
            }
        }
    }

    /// One request-reply exchange with replica `idx` over its
    /// persistent connection (opened on demand).
    fn send_recv(&mut self, idx: usize, line: &str) -> std::io::Result<String> {
        if self.conns[idx].is_none() {
            self.conns[idx] = Some(self.connect(idx)?);
        }
        let conn = self.conns[idx].as_mut().expect("filled above");
        let stream = conn.get_mut();
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
        let start = Instant::now();
        let mut buf: Vec<u8> = Vec::new();
        loop {
            match conn.read_until(b'\n', &mut buf) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "replica closed the connection",
                    ))
                }
                Ok(_) if buf.last() == Some(&b'\n') => {
                    let text = String::from_utf8(buf).map_err(|_| {
                        std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            "replica reply is not UTF-8",
                        )
                    })?;
                    return Ok(text.trim_end().to_string());
                }
                Ok(_) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::UnexpectedEof,
                        "replica reply torn mid-line",
                    ))
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    if start.elapsed() >= self.timeout {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "replica reply deadline exceeded",
                        ));
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Forward `line` to the replica owning `key` (or the next alive
    /// replica round-robin when unkeyed), retrying exactly one
    /// alternate on failure and marking failed replicas dead.
    pub fn forward_line(&mut self, key: Option<&[u8]>, line: &str) -> Result<String, FleetError> {
        self.maybe_probe();
        let candidates = match key {
            Some(k) => self.ring.candidates(k, 2),
            None => {
                // unkeyed: rotate over alive replicas, one alternate
                let alive: Vec<usize> = (0..self.ring.endpoints().len())
                    .filter(|&i| self.ring.is_alive(i))
                    .collect();
                if alive.is_empty() {
                    Vec::new()
                } else {
                    let first = alive[(self.rr as usize) % alive.len()];
                    self.rr = self.rr.wrapping_add(1);
                    let mut c = vec![first];
                    if alive.len() > 1 {
                        c.push(alive[(self.rr as usize) % alive.len()]);
                    }
                    c
                }
            }
        };
        if candidates.is_empty() {
            return Err(FleetError::NoReplica { detail: "every replica is out of rotation".into() });
        }
        let mut last_err = String::new();
        for (attempt, &idx) in candidates.iter().enumerate() {
            match self.send_recv(idx, line) {
                Ok(reply) => {
                    if attempt > 0 {
                        self.retried += 1;
                    }
                    return Ok(reply);
                }
                Err(e) => {
                    last_err =
                        format!("{}: {e}", self.ring.endpoints()[idx]);
                    self.conns[idx] = None;
                    self.ring.mark_dead(idx);
                }
            }
        }
        Err(FleetError::NoReplica {
            detail: format!("primary and alternate both failed (last: {last_err})"),
        })
    }
}

/// Verbs the router refuses to forward: model distribution goes
/// through the control plane directly to each replica, never through
/// the data-plane front.
fn is_control_verb(cmd: &str) -> bool {
    matches!(cmd, "push-artifact" | "activate" | "rollback" | "fleet-status" | "swap-model")
}

/// Run the data-plane router until a `shutdown` line: accept client
/// connections, forward each request line to its consistent-hash
/// replica, relay the reply.  `shutdown` stops the *router* only —
/// replicas are shut down directly (or by the controller).
pub fn run_router(
    listener: TcpListener,
    endpoints: Vec<String>,
    opts: &RouterOptions,
) -> Result<RouterReport, FleetError> {
    listener
        .set_nonblocking(true)
        .map_err(|e| FleetError::Io { path: "router listener".into(), detail: e.to_string() })?;
    let stop = AtomicBool::new(false);
    let connections = AtomicU64::new(0);
    let forwarded = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let core = Mutex::new(Router::new(endpoints, opts));
    std::thread::scope(|s| {
        let stop = &stop;
        let core = &core;
        let forwarded = &forwarded;
        let rejected = &rejected;
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    connections.fetch_add(1, Ordering::Relaxed);
                    s.spawn(move || {
                        client_loop(stream, core, stop, forwarded, rejected);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(e) => {
                    stop.store(true, Ordering::Relaxed);
                    return Err(FleetError::Io {
                        path: "router accept".into(),
                        detail: e.to_string(),
                    });
                }
            }
        }
        Ok(())
    })?;
    let retried = core.into_inner().unwrap_or_else(|p| p.into_inner()).retried;
    Ok(RouterReport {
        connections: connections.into_inner(),
        forwarded: forwarded.into_inner(),
        retried,
        rejected: rejected.into_inner(),
    })
}

/// One client connection: synchronous line-in/reply-out (the replica
/// round trip happens under the router mutex).
fn client_loop(
    stream: TcpStream,
    core: &Mutex<Router>,
    stop: &AtomicBool,
    forwarded: &AtomicU64,
    rejected: &AtomicU64,
) {
    if stream.set_nonblocking(false).is_err() || stream.set_read_timeout(Some(POLL)).is_err() {
        return;
    }
    let mut write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut rd = BufReader::new(&stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match rd.read_until(b'\n', &mut buf) {
            Ok(0) => break,
            Ok(_) => {
                let reply = match std::str::from_utf8(&buf) {
                    Ok(text) => {
                        let line = text.trim();
                        if line.is_empty() {
                            buf.clear();
                            continue;
                        }
                        let cmd = line.split_ascii_whitespace().next().unwrap_or("");
                        if cmd == "shutdown" {
                            let _ = write_half.write_all(b"ok bye\n");
                            stop.store(true, Ordering::Relaxed);
                            break;
                        }
                        if is_control_verb(cmd) {
                            rejected.fetch_add(1, Ordering::Relaxed);
                            format!("err router: {cmd} goes directly to replicas, not the router")
                        } else {
                            let key = line
                                .split_ascii_whitespace()
                                .nth(1)
                                .and_then(|t| t.strip_prefix("key="))
                                .map(|k| k.as_bytes().to_vec());
                            let mut router = core.lock().unwrap_or_else(|p| p.into_inner());
                            match router.forward_line(key.as_deref(), line) {
                                Ok(r) => {
                                    forwarded.fetch_add(1, Ordering::Relaxed);
                                    r
                                }
                                Err(e) => {
                                    rejected.fetch_add(1, Ordering::Relaxed);
                                    format!("err {e}")
                                }
                            }
                        }
                    }
                    Err(_) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        "err line is not valid UTF-8".to_string()
                    }
                };
                if write_half
                    .write_all(reply.as_bytes())
                    .and_then(|()| write_half.write_all(b"\n"))
                    .is_err()
                {
                    break;
                }
                buf.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eps(prefix: &str, n: usize) -> Vec<String> {
        (0..n).map(|i| format!("{prefix}{i}")).collect()
    }

    /// Satellite: chi-square-style balance over 16 shards × 10k keys.
    /// The exact statistic for this (seed, vnodes) layout is ≈41.7
    /// (computed independently from the hash definition); the bound
    /// leaves room without admitting a broken ring (uniform-on-4-shards
    /// style failures score in the thousands).
    #[test]
    fn balance_16_shards_10k_keys_chi_square_bounded() {
        let ring = Ring::new(eps("replica-", 16), 7, 128);
        let mut counts = [0usize; 16];
        for k in 0..10_000 {
            counts[ring.shard_of(format!("key-{k}").as_bytes()).unwrap()] += 1;
        }
        let expected = 10_000.0 / 16.0;
        let chi2: f64 =
            counts.iter().map(|&c| (c as f64 - expected).powi(2) / expected).sum();
        assert!(chi2 < 120.0, "chi-square {chi2:.1} too large: {counts:?}");
        for (i, &c) in counts.iter().enumerate() {
            assert!((400..=900).contains(&c), "shard {i} got {c} of 10000: {counts:?}");
        }
    }

    /// Satellite: replica-set changes move only the affected arcs.
    #[test]
    fn death_remaps_only_the_dead_replicas_keys() {
        let mut ring = Ring::new(eps("r", 8), 7, 128);
        let keys: Vec<String> = (0..4000).map(|k| format!("k-{k}")).collect();
        let before: Vec<usize> =
            keys.iter().map(|k| ring.shard_of(k.as_bytes()).unwrap()).collect();
        ring.mark_dead(3);
        let mut moved = 0usize;
        for (k, &b) in keys.iter().zip(&before) {
            let a = ring.shard_of(k.as_bytes()).unwrap();
            if b == 3 {
                moved += 1;
                assert_ne!(a, 3, "key {k} still on the dead replica");
            } else {
                assert_eq!(a, b, "unaffected key {k} moved");
            }
        }
        // the dead replica held ~1/8 of the keys (434 for this layout)
        assert!((250..=750).contains(&moved), "moved {moved} of 4000");
        // revival restores the exact original mapping
        ring.mark_alive(3);
        for (k, &b) in keys.iter().zip(&before) {
            assert_eq!(ring.shard_of(k.as_bytes()).unwrap(), b);
        }
    }

    /// Removing an endpoint from the ring entirely (vs marking it
    /// dead) also only remaps its own keys — surviving endpoints keep
    /// their vnode points, so their keys cannot move.
    #[test]
    fn endpoint_removal_keeps_surviving_assignments() {
        let all = eps("node-", 6);
        let ring_all = Ring::new(all.clone(), 9, 128);
        let mut fewer = all.clone();
        fewer.remove(2);
        let ring_fewer = Ring::new(fewer.clone(), 9, 128);
        for k in 0..2000 {
            let key = format!("user-{k}");
            let before = &all[ring_all.shard_of(key.as_bytes()).unwrap()];
            let after = &fewer[ring_fewer.shard_of(key.as_bytes()).unwrap()];
            if before != "node-2" {
                assert_eq!(before, after, "key {key} moved off a surviving endpoint");
            } else {
                assert_ne!(after, "node-2");
            }
        }
    }

    /// Satellite: cross-process determinism.  The expected shard
    /// indices were computed by an independent implementation of the
    /// hash + ring (outside this codebase), so any drift in
    /// `route_hash`, the vnode labeling, or the clockwise walk breaks
    /// this test — same seed ⇒ same mapping, on every build.
    #[test]
    fn golden_mapping_pins_cross_process_determinism() {
        // route_hash itself first
        assert_eq!(route_hash(0, b""), 0xc3817c016ba4ff30);
        assert_eq!(route_hash(7, b"user-0"), 0x757304dd7f0f80b2);
        assert_eq!(route_hash(7, b"user-1"), 0x7acc36fe4d39a59a);
        assert_eq!(route_hash(42, b"abc"), 0xab96b84dcf0484eb);
        assert_eq!(route_hash(0xdead_beef, b"mmbsgd"), 0xb544d24441f1fd6d);
        // then the full ring walk
        let endpoints: Vec<String> = (0..4).map(|i| format!("10.0.0.{i}:9000")).collect();
        let ring = Ring::new(endpoints, 42, 64);
        for (key, shard) in [
            ("alpha", 0usize),
            ("bravo", 0),
            ("charlie", 3),
            ("delta", 0),
            ("echo", 3),
            ("foxtrot", 2),
            ("golf", 3),
            ("hotel", 0),
        ] {
            assert_eq!(ring.shard_of(key.as_bytes()), Some(shard), "key {key:?}");
        }
    }

    #[test]
    fn candidates_are_distinct_alive_and_ordered() {
        let mut ring = Ring::new(eps("r", 4), 3, 64);
        let c = ring.candidates(b"some-key", 4);
        assert_eq!(c.len(), 4);
        let mut sorted = c.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "candidates must be distinct: {c:?}");
        // the failover target is the next candidate, skipping the dead
        let primary = c[0];
        ring.mark_dead(primary);
        assert_eq!(ring.shard_of(b"some-key"), Some(c[1]));
        // all dead -> None
        for i in 0..4 {
            ring.mark_dead(i);
        }
        assert_eq!(ring.shard_of(b"some-key"), None);
        assert_eq!(ring.alive_count(), 0);
        // empty ring never panics
        let empty = Ring::new(Vec::new(), 1, 8);
        assert_eq!(empty.shard_of(b"k"), None);
    }

    #[test]
    fn control_verbs_are_refused_at_the_router() {
        for v in ["push-artifact", "activate", "rollback", "fleet-status", "swap-model"] {
            assert!(is_control_verb(v), "{v}");
        }
        for v in ["predict", "decision", "feedback", "stats"] {
            assert!(!is_control_verb(v), "{v}");
        }
    }
}

//! Versioned on-disk model bundle: a text manifest wrapping the
//! existing model format, in the barbacane manifest idiom (versions,
//! provenance, per-section checksums).
//!
//! Layout of the payload (the durable layer appends its own
//! whole-file footer on top via [`crate::util::durable::write_atomic`]):
//!
//! ```text
//! mmbsgd-fleet-artifact v1
//! name <model name, one token>
//! version <u64>
//! scorer <lut|exact>
//! simd <auto|scalar|...>
//! dim <usize>
//! nsv <usize>
//! provenance <key=value key=value ...>
//! section model len=<bytes> fnv=<16 hex digits>
//! end-manifest
//! <model text, exactly len bytes>
//! ```
//!
//! Two checksum rings guard the bundle: the durable footer covers the
//! whole file (torn writes, bit rot anywhere), and the per-section
//! `fnv=` in the manifest covers the embedded model bytes alone — so a
//! manifest from one model spliced onto another model's bytes is
//! rejected even when the outer footer was recomputed by the attacker
//! or by an honest-but-confused tool.  On top of that,
//! [`Artifact::validate_model`] cross-checks the manifest's declared
//! `dim`/`nsv` against the parsed model.  Every refusal is a typed
//! [`FleetError`]; nothing in this module panics on arbitrary input
//! (the fuzz corpus under `fuzz/corpus/manifest/` holds that line).

use std::path::Path;

use crate::config::TrainConfig;
use crate::error::FleetError;
use crate::model::SvmModel;
use crate::util::durable;

/// Magic first line of every artifact manifest.
pub const ARTIFACT_MAGIC: &str = "mmbsgd-fleet-artifact v1";

/// Trained-config provenance recorded in the manifest: a flat ordered
/// `key=value` list, deliberately schema-free so older controllers can
/// display newer fields.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Provenance {
    pub pairs: Vec<(String, String)>,
}

impl Provenance {
    /// Record the fields of a [`TrainConfig`] that determine what the
    /// packaged model *is* (solver hyperparameters and seed), skipping
    /// pure execution knobs like thread count.
    pub fn from_config(cfg: &TrainConfig) -> Self {
        let pairs = vec![
            ("lambda".to_string(), format!("{}", cfg.lambda)),
            ("gamma".to_string(), format!("{}", cfg.gamma)),
            ("budget".to_string(), format!("{}", cfg.budget)),
            ("mergees".to_string(), format!("{}", cfg.mergees)),
            ("epochs".to_string(), format!("{}", cfg.epochs)),
            ("seed".to_string(), format!("{}", cfg.seed)),
            ("backend".to_string(), format!("{:?}", cfg.backend).to_lowercase()),
            (
                "merge_score_mode".to_string(),
                format!("{:?}", cfg.merge_score_mode).to_lowercase(),
            ),
        ];
        Provenance { pairs }
    }

    /// Look up a recorded key.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// A parsed (or freshly wrapped) model bundle.
#[derive(Clone, Debug, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub version: u64,
    /// Merge scorer the model was trained with (`lut` / `exact`).
    pub scorer: String,
    /// SIMD mode recorded at package time (informational).
    pub simd: String,
    /// Feature dimension the manifest declares for the model.
    pub dim: usize,
    /// Support-vector count the manifest declares.
    pub nsv: usize,
    pub provenance: Provenance,
    /// The embedded model in the standard `mmbsgd-model v1` text format.
    pub model_text: String,
}

fn bad(detail: impl Into<String>) -> FleetError {
    FleetError::Manifest { detail: detail.into() }
}

fn one_token(value: &str, field: &str) -> Result<String, FleetError> {
    let v = value.trim();
    if v.is_empty() || v.split_ascii_whitespace().count() != 1 {
        return Err(bad(format!("{field} must be a single non-empty token, got {value:?}")));
    }
    Ok(v.to_string())
}

impl Artifact {
    /// Wrap a trained model into a bundle.  `scorer` and `simd` are
    /// recorded verbatim; `dim`/`nsv` are taken from the model itself
    /// so the manifest can never disagree with what it wraps.
    pub fn wrap(
        name: &str,
        version: u64,
        model: &SvmModel,
        provenance: Provenance,
        scorer: &str,
        simd: &str,
    ) -> Result<Artifact, FleetError> {
        Ok(Artifact {
            name: one_token(name, "name")?,
            version,
            scorer: one_token(scorer, "scorer")?,
            simd: one_token(simd, "simd")?,
            dim: model.svs.dim(),
            nsv: model.svs.len(),
            provenance,
            model_text: model.to_text(),
        })
    }

    /// Serialize to the manifest + section text (the durable payload).
    pub fn to_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(self.model_text.len() + 256);
        let _ = writeln!(out, "{ARTIFACT_MAGIC}");
        let _ = writeln!(out, "name {}", self.name);
        let _ = writeln!(out, "version {}", self.version);
        let _ = writeln!(out, "scorer {}", self.scorer);
        let _ = writeln!(out, "simd {}", self.simd);
        let _ = writeln!(out, "dim {}", self.dim);
        let _ = writeln!(out, "nsv {}", self.nsv);
        let _ = write!(out, "provenance");
        for (k, v) in &self.provenance.pairs {
            let _ = write!(out, " {k}={v}");
        }
        out.push('\n');
        let _ = writeln!(
            out,
            "section model len={} fnv={:016x}",
            self.model_text.len(),
            durable::checksum(self.model_text.as_bytes())
        );
        let _ = writeln!(out, "end-manifest");
        out.push_str(&self.model_text);
        out
    }

    /// Parse a manifest + section text, verifying the per-section
    /// checksum.  Total function over arbitrary input: every failure
    /// is a typed error, never a panic.
    pub fn parse(text: &str) -> Result<Artifact, FleetError> {
        let mut rest = text;
        let mut next_line = || -> Result<&str, FleetError> {
            if rest.is_empty() {
                return Err(bad("truncated manifest"));
            }
            let (line, tail) = match rest.split_once('\n') {
                Some((l, t)) => (l, t),
                None => (rest, ""),
            };
            rest = tail;
            Ok(line)
        };

        let magic = next_line()?;
        if magic.trim_end() != ARTIFACT_MAGIC {
            return Err(bad(format!("bad magic line {magic:?}")));
        }
        let mut name = None;
        let mut version = None;
        let mut scorer = None;
        let mut simd = None;
        let mut dim = None;
        let mut nsv = None;
        let mut provenance = None;
        let mut section: Option<(usize, u64)> = None;
        loop {
            let line = next_line()?;
            if line.trim_end() == "end-manifest" {
                break;
            }
            let (key, val) = line.split_once(' ').unwrap_or((line, ""));
            match key {
                "name" => name = Some(one_token(val, "name")?),
                "version" => {
                    version = Some(
                        val.trim()
                            .parse::<u64>()
                            .map_err(|_| bad(format!("bad version {val:?}")))?,
                    )
                }
                "scorer" => scorer = Some(one_token(val, "scorer")?),
                "simd" => simd = Some(one_token(val, "simd")?),
                "dim" => {
                    dim = Some(
                        val.trim()
                            .parse::<usize>()
                            .map_err(|_| bad(format!("bad dim {val:?}")))?,
                    )
                }
                "nsv" => {
                    nsv = Some(
                        val.trim()
                            .parse::<usize>()
                            .map_err(|_| bad(format!("bad nsv {val:?}")))?,
                    )
                }
                "provenance" => {
                    let mut pairs = Vec::new();
                    for tok in val.split_ascii_whitespace() {
                        let (k, v) = tok
                            .split_once('=')
                            .ok_or_else(|| bad(format!("provenance token {tok:?} lacks '='")))?;
                        pairs.push((k.to_string(), v.to_string()));
                    }
                    provenance = Some(Provenance { pairs });
                }
                "section" => {
                    let mut words = val.split_ascii_whitespace();
                    let sect = words.next().unwrap_or("");
                    if sect != "model" {
                        return Err(bad(format!("unknown section {sect:?}")));
                    }
                    let mut len = None;
                    let mut fnv = None;
                    for tok in words {
                        if let Some(v) = tok.strip_prefix("len=") {
                            len = v.parse::<usize>().ok();
                        } else if let Some(v) = tok.strip_prefix("fnv=") {
                            fnv = u64::from_str_radix(v, 16).ok();
                        }
                    }
                    match (len, fnv) {
                        (Some(l), Some(f)) => section = Some((l, f)),
                        _ => return Err(bad(format!("malformed section line {line:?}"))),
                    }
                }
                other => return Err(bad(format!("unknown manifest key {other:?}"))),
            }
        }
        let (len, fnv) = section.ok_or_else(|| bad("manifest lacks a 'section model' line"))?;
        let model_text = rest;
        if model_text.len() != len {
            return Err(bad(format!(
                "model section length mismatch: manifest says {len} bytes, \
                 payload carries {}",
                model_text.len()
            )));
        }
        let got = durable::checksum(model_text.as_bytes());
        if got != fnv {
            return Err(FleetError::SectionChecksum {
                section: "model".to_string(),
                expected: fnv,
                got,
            });
        }
        Ok(Artifact {
            name: name.ok_or_else(|| bad("manifest lacks name"))?,
            version: version.ok_or_else(|| bad("manifest lacks version"))?,
            scorer: scorer.ok_or_else(|| bad("manifest lacks scorer"))?,
            simd: simd.ok_or_else(|| bad("manifest lacks simd"))?,
            dim: dim.ok_or_else(|| bad("manifest lacks dim"))?,
            nsv: nsv.ok_or_else(|| bad("manifest lacks nsv"))?,
            provenance: provenance.unwrap_or_default(),
            model_text: model_text.to_string(),
        })
    }

    /// Parse the embedded model and cross-check it against the
    /// manifest's declared shape.  This is the activation gate: a
    /// bundle whose model disagrees with its own manifest — or whose
    /// model fails basic validity (γ must be positive and finite) —
    /// never reaches a registry.
    pub fn validate_model(&self) -> Result<SvmModel, FleetError> {
        let model =
            SvmModel::from_text(&self.model_text).map_err(|e| FleetError::Model(format!("{e:#}")))?;
        if model.svs.dim() != self.dim {
            return Err(FleetError::DimMismatch { manifest: self.dim, model: model.svs.dim() });
        }
        if model.svs.len() != self.nsv {
            return Err(FleetError::Model(format!(
                "nsv mismatch: manifest declares {}, model has {}",
                self.nsv,
                model.svs.len()
            )));
        }
        if !(model.gamma > 0.0 && model.gamma.is_finite()) {
            return Err(FleetError::Model(format!(
                "gamma must be positive and finite, got {}",
                model.gamma
            )));
        }
        Ok(model)
    }

    /// Write the bundle through the durable layer (atomic replace,
    /// whole-file checksum footer, `.prev` last-good generation).
    pub fn save(&self, path: &Path) -> Result<(), FleetError> {
        durable::write_atomic(path, &self.to_text()).map_err(FleetError::from)
    }

    /// Read, checksum-verify (whole file, then the model section), and
    /// shape-validate a bundle from disk.  Goes through
    /// [`durable::read_artifact_verified`], the `artifact.read`
    /// fault-injection site.
    pub fn load(path: &Path) -> Result<Artifact, FleetError> {
        let payload = durable::read_artifact_verified(path)?;
        let artifact = Artifact::parse(&payload)?;
        artifact.validate_model()?;
        Ok(artifact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn toy_model() -> SvmModel {
        let mut m = SvmModel::new(3, 1.5);
        m.svs.push(&[0.5, -1.0, 2.0], 0.75);
        m.svs.push(&[1.0, 0.0, -0.5], -0.25);
        m.bias = 0.125;
        m.meta = "test".into();
        m
    }

    fn toy_artifact() -> Artifact {
        Artifact::wrap(
            "champ",
            3,
            &toy_model(),
            Provenance::from_config(&TrainConfig::default()),
            "lut",
            "auto",
        )
        .unwrap()
    }

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mmbsgd_fleet_artifact_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn text_roundtrip_preserves_everything() {
        let a = toy_artifact();
        let b = Artifact::parse(&a.to_text()).unwrap();
        assert_eq!(a, b);
        assert_eq!(b.name, "champ");
        assert_eq!(b.version, 3);
        assert_eq!(b.dim, 3);
        assert_eq!(b.nsv, 2);
        assert_eq!(b.provenance.get("budget"), Some("256"));
        let m = b.validate_model().unwrap();
        assert_eq!(m.svs.len(), 2);
        assert_eq!(m.bias, 0.125);
    }

    #[test]
    fn disk_roundtrip_and_prev_rotation() {
        let dir = scratch("roundtrip");
        let p = dir.join("champ.artifact");
        let mut a = toy_artifact();
        a.save(&p).unwrap();
        let back = Artifact::load(&p).unwrap();
        assert_eq!(back.version, 3);
        a.version = 4;
        a.save(&p).unwrap();
        assert_eq!(Artifact::load(&p).unwrap().version, 4);
        let prev = Artifact::load(&durable::prev_path(&p)).unwrap();
        assert_eq!(prev.version, 3, "last-good generation kept beside the bundle");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn section_checksum_catches_spliced_model_bytes() {
        let a = toy_artifact();
        // flip a byte inside the model section only; the manifest (and
        // therefore any recomputed outer footer) stays "valid"
        let tampered = a.to_text().replacen("0.75", "0.85", 1);
        match Artifact::parse(&tampered) {
            Err(FleetError::SectionChecksum { section, .. }) => assert_eq!(section, "model"),
            other => panic!("wanted SectionChecksum, got {other:?}"),
        }
    }

    #[test]
    fn load_rejects_whole_file_tamper_with_corrupt() {
        let dir = scratch("tamper");
        let p = dir.join("champ.artifact");
        toy_artifact().save(&p).unwrap();
        let raw = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, raw.replacen("0.75", "0.85", 1)).unwrap();
        assert!(matches!(Artifact::load(&p), Err(FleetError::Corrupt { .. })));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn validate_model_cross_checks_manifest_shape() {
        let mut a = toy_artifact();
        a.dim = 7;
        assert_eq!(
            a.validate_model().unwrap_err(),
            FleetError::DimMismatch { manifest: 7, model: 3 }
        );
        let mut a = toy_artifact();
        a.nsv = 9;
        assert!(matches!(a.validate_model(), Err(FleetError::Model(_))));
    }

    #[test]
    fn parse_rejects_garbage_without_panicking() {
        for bad_text in [
            "",
            "wrong magic\n",
            "mmbsgd-fleet-artifact v1\n",                           // no manifest body
            "mmbsgd-fleet-artifact v1\nname a b\nend-manifest\n",   // multi-token name
            "mmbsgd-fleet-artifact v1\nversion x\nend-manifest\n",  // bad version
            "mmbsgd-fleet-artifact v1\nbogus 1\nend-manifest\n",    // unknown key
            "mmbsgd-fleet-artifact v1\nsection model len=nope fnv=0\nend-manifest\n",
            "mmbsgd-fleet-artifact v1\nsection other len=0 fnv=0\nend-manifest\n",
            "mmbsgd-fleet-artifact v1\nname a\nend-manifest\n",     // no section
            "mmbsgd-fleet-artifact v1\nprovenance seed\nend-manifest\n", // pair lacks '='
        ] {
            assert!(Artifact::parse(bad_text).is_err(), "accepted {bad_text:?}");
        }
        // length mismatch between section line and carried bytes
        let a = toy_artifact();
        let text = a.to_text();
        let truncated = &text[..text.len() - 3];
        assert!(Artifact::parse(truncated).is_err());
    }

    #[test]
    fn wrap_takes_shape_from_the_model() {
        let a = toy_artifact();
        assert_eq!(a.dim, 3);
        assert_eq!(a.nsv, 2);
        assert!(Artifact::wrap("two words", 1, &toy_model(), Provenance::default(), "lut", "auto")
            .is_err());
    }
}

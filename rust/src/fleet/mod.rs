//! Fleet subsystem: versioned model artifacts, a control-plane
//! packager/pusher, and a replicated data plane behind consistent-hash
//! routing.
//!
//! The pieces, bottom-up:
//!
//! * [`artifact`] — a self-verifying on-disk bundle: std-only text
//!   manifest (name, version, trained-config provenance, per-section
//!   FNV-1a checksums) wrapping the existing model text format, all
//!   protected by the durable footer from [`crate::util::durable`].
//!   `mmbsgd package` builds one, `mmbsgd verify` re-checks it, and
//!   loads refuse mismatched checksums or dimensions with typed
//!   [`crate::error::FleetError`] variants.
//! * [`control`] — the fleet controller: pushes artifacts to replica
//!   endpoints over the line protocol (`push-artifact <len>` +
//!   payload, `activate <name>@v<N>`, `rollback <name>`), tracks
//!   per-replica acknowledged versions, and hosts the auto-rollback
//!   hook (accuracy window degrades past threshold → fleet-wide
//!   rollback to last-good).
//! * [`replica`] — server-side state: staged artifacts are verified
//!   on receipt, activation hot-swaps the model atomically into the
//!   [`crate::serve::ModelRegistry`] while keeping the previous
//!   generation on disk (`.prev`-style) for rollback, and `recover`
//!   rebuilds everything from the artifact directory at startup.
//! * [`router`] — the data-plane front door: consistent-hashes
//!   request keys across replica endpoints (generalizing the seeded
//!   [`crate::serve::route_hash`]), hands each client connection to
//!   its own worker thread, multiplexes forwards over a per-replica
//!   pooled-link set (pipelining same-replica runs), retries a stale
//!   link once and then one alternate replica on failure, and marks
//!   dead replicas out with periodic re-probe.  Telemetry
//!   (`router_*` counters + forward-latency histogram) answers on the
//!   `router-stats` verb.
//!
//! Consistency model: an artifact is immutable once packaged (any
//! byte flip is caught by the section checksums), replicas only serve
//! versions they fully verified, and activation/rollback are atomic
//! per replica.  The fleet converges because every operation is
//! idempotent — re-pushing a staged version or re-activating the
//! active one is a no-op with the same reply.

pub mod artifact;
pub mod control;
pub mod replica;
pub mod router;

pub use artifact::{Artifact, Provenance, ARTIFACT_MAGIC};
pub use control::{Controller, Outcome, StatusOutcome};
pub use replica::{ActiveInfo, ReplicaState};
pub use router::{
    run_router, Ring, Router, RouterOptions, RouterReport, DEFAULT_POOL, DEFAULT_VNODES,
};

//! The control plane: validate, push, activate, roll back — fleet-wide.
//!
//! A [`Controller`] is a short-lived client of every replica's
//! line-protocol port (the `mmbsgd fleet` subcommands construct one
//! per invocation; a monitoring daemon can hold one long-term for
//! [`Controller::maybe_auto_rollback`]).  It owns no model state: the
//! artifact on disk is the source of truth, replicas are the
//! distribution targets, and the controller just moves verified bytes
//! and tracks which version each replica has acknowledged.
//!
//! Push is two-phase by protocol design: `push-artifact <len>` +
//! payload *stages* the bundle (full verification, no serving impact),
//! and a separate `activate <name>@v<N>` swaps it live — so a push
//! that dies mid-payload (crash, cable pull, or the injected
//! `fleet.push` fault) leaves every replica serving exactly what it
//! served before.
//!
//! The registry-level auto-rollback hook (PR-4 follow-up) lives here
//! rather than in the replica: a replica seeing its own accuracy
//! window degrade can only fix itself, while the controller can
//! compare the fleet and roll *everyone* back to last-good in one
//! sweep ([`Controller::maybe_auto_rollback`]).

use crate::error::FleetError;
use crate::util::fault;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::artifact::Artifact;

/// Read poll interval while waiting on a reply.
const POLL: Duration = Duration::from_millis(50);

/// Outcome of one control operation against one replica.
#[derive(Clone, Debug, PartialEq)]
pub struct Outcome {
    pub endpoint: String,
    /// The replica's acknowledged version on success.
    pub result: Result<u64, FleetError>,
}

/// Per-endpoint outcome of a `fleet-status` sweep.  An unreachable
/// replica is a *row* in the status table (`Err` — what the router
/// sees as dead), never a failure of the whole sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct StatusOutcome {
    pub endpoint: String,
    /// The replica's raw `fleet-status` line on success.
    pub result: Result<String, FleetError>,
}

impl StatusOutcome {
    /// Whether the replica answered — the status-table liveness bit.
    pub fn is_alive(&self) -> bool {
        self.result.is_ok()
    }
}

/// Fleet-wide control client; see the [module docs](self).
pub struct Controller {
    endpoints: Vec<String>,
    timeout: Duration,
    /// endpoint → model name → last version that endpoint acknowledged
    /// (staged-and-activated, or restored by rollback).
    acked: BTreeMap<String, BTreeMap<String, u64>>,
}

/// Extract `<version>` from a `... <name>@v<version> ...` reply token.
fn parse_ack_version(reply: &str) -> Option<u64> {
    reply
        .split_ascii_whitespace()
        .find_map(|tok| tok.split_once("@v").and_then(|(_, v)| v.parse::<u64>().ok()))
}

/// One reply line with a deadline (the stream has a short read timeout
/// so the loop can give up at `timeout` without blocking forever).
fn read_reply(
    conn: &mut BufReader<TcpStream>,
    timeout: Duration,
    endpoint: &str,
) -> Result<String, FleetError> {
    let replica = |detail: String| FleetError::Replica { endpoint: endpoint.to_string(), detail };
    let start = Instant::now();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        match conn.read_until(b'\n', &mut buf) {
            Ok(0) => return Err(replica("closed the connection mid-exchange".into())),
            Ok(_) if buf.last() == Some(&b'\n') => {
                return String::from_utf8(buf)
                    .map(|s| s.trim_end().to_string())
                    .map_err(|_| replica("reply is not UTF-8".into()))
            }
            Ok(_) => return Err(replica("reply torn mid-line".into())),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                if start.elapsed() >= timeout {
                    return Err(replica("reply deadline exceeded".into()));
                }
            }
            Err(e) => return Err(replica(e.to_string())),
        }
    }
}

impl Controller {
    pub fn new(endpoints: Vec<String>, timeout: Duration) -> Controller {
        Controller { endpoints, timeout, acked: BTreeMap::new() }
    }

    pub fn endpoints(&self) -> &[String] {
        &self.endpoints
    }

    /// The last version `endpoint` acknowledged for `name`.
    pub fn acked(&self, endpoint: &str, name: &str) -> Option<u64> {
        self.acked.get(endpoint).and_then(|m| m.get(name).copied())
    }

    fn connect(&self, endpoint: &str) -> Result<BufReader<TcpStream>, FleetError> {
        let stream = TcpStream::connect(endpoint).map_err(|e| FleetError::Replica {
            endpoint: endpoint.to_string(),
            detail: format!("connect: {e}"),
        })?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POLL)).ok();
        stream.set_write_timeout(Some(self.timeout)).ok();
        Ok(BufReader::new(stream))
    }

    /// Send one line, read one reply; `err ...` replies become typed
    /// [`FleetError::Replica`] errors carrying the replica's reason.
    fn exchange(
        &self,
        conn: &mut BufReader<TcpStream>,
        endpoint: &str,
        line: &str,
    ) -> Result<String, FleetError> {
        let stream = conn.get_mut();
        stream
            .write_all(line.as_bytes())
            .and_then(|()| stream.write_all(b"\n"))
            .and_then(|()| stream.flush())
            .map_err(|e| FleetError::Replica {
                endpoint: endpoint.to_string(),
                detail: format!("write: {e}"),
            })?;
        let reply = read_reply(conn, self.timeout, endpoint)?;
        if let Some(reason) = reply.strip_prefix("err ") {
            return Err(FleetError::Replica {
                endpoint: endpoint.to_string(),
                detail: reason.to_string(),
            });
        }
        Ok(reply)
    }

    /// Push `artifact` to one replica (stage), optionally activating
    /// it in the same connection.
    ///
    /// Injection site [`fault::site::FLEET_PUSH`]: an `io` rule tears
    /// the push mid-payload — header and roughly half the bytes go
    /// out, then the connection drops — modeling a controller crash or
    /// network partition during distribution.  The replica's
    /// length-delimited reader sees EOF before the payload completes
    /// and stages nothing.
    fn push_one(
        &self,
        endpoint: &str,
        artifact: &Artifact,
        activate: bool,
    ) -> Result<u64, FleetError> {
        let mut conn = self.connect(endpoint)?;
        let payload = artifact.to_text();
        let header = format!("push-artifact {}\n", payload.len());
        if let Some(fault::FaultKind::Io) = fault::armed(fault::site::FLEET_PUSH) {
            let stream = conn.get_mut();
            let torn = &payload.as_bytes()[..payload.len() / 2];
            let _ = stream.write_all(header.as_bytes());
            let _ = stream.write_all(torn);
            let _ = stream.flush();
            // dropping `conn` closes the socket mid-payload
            return Err(FleetError::Replica {
                endpoint: endpoint.to_string(),
                detail: "injected push fault: connection torn mid-payload".to_string(),
            });
        }
        {
            let stream = conn.get_mut();
            stream
                .write_all(header.as_bytes())
                .and_then(|()| stream.write_all(payload.as_bytes()))
                .and_then(|()| stream.flush())
                .map_err(|e| FleetError::Replica {
                    endpoint: endpoint.to_string(),
                    detail: format!("push write: {e}"),
                })?;
        }
        let reply = read_reply(&mut conn, self.timeout, endpoint)?;
        if !reply.starts_with("ok staged") {
            return Err(FleetError::Replica {
                endpoint: endpoint.to_string(),
                detail: format!("unexpected push reply: {reply}"),
            });
        }
        if activate {
            let line = format!("activate {}@v{}", artifact.name, artifact.version);
            let reply = self.exchange(&mut conn, endpoint, &line)?;
            if !reply.starts_with("ok active") {
                return Err(FleetError::Replica {
                    endpoint: endpoint.to_string(),
                    detail: format!("unexpected activate reply: {reply}"),
                });
            }
        }
        Ok(artifact.version)
    }

    /// Push (and optionally activate) an artifact on every replica.
    /// Per-replica outcomes — one dead replica does not stop the
    /// others from converging; re-running the push is idempotent.
    pub fn push(&mut self, artifact: &Artifact, activate: bool) -> Vec<Outcome> {
        let endpoints = self.endpoints.clone();
        endpoints
            .iter()
            .map(|ep| {
                let result = self.push_one(ep, artifact, activate);
                if let Ok(v) = result {
                    self.acked
                        .entry(ep.clone())
                        .or_default()
                        .insert(artifact.name.clone(), v);
                }
                Outcome { endpoint: ep.clone(), result }
            })
            .collect()
    }

    /// Roll `name` back to its last-good generation on every replica.
    pub fn rollback(&mut self, name: &str) -> Vec<Outcome> {
        let endpoints = self.endpoints.clone();
        endpoints
            .iter()
            .map(|ep| {
                let result = self.connect(ep).and_then(|mut conn| {
                    let reply = self.exchange(&mut conn, ep, &format!("rollback {name}"))?;
                    parse_ack_version(&reply).ok_or_else(|| FleetError::Replica {
                        endpoint: ep.clone(),
                        detail: format!("unexpected rollback reply: {reply}"),
                    })
                });
                if let Ok(v) = result {
                    self.acked.entry(ep.clone()).or_default().insert(name.to_string(), v);
                }
                Outcome { endpoint: ep.clone(), result }
            })
            .collect()
    }

    /// `fleet-status` from every replica.  Unreachable replicas come
    /// back as `Err` rows (rendered `dead` by the CLI), so one dead
    /// endpoint never hides the rest of the fleet's state.
    pub fn status(&self) -> Vec<StatusOutcome> {
        self.endpoints
            .iter()
            .map(|ep| {
                let result = self
                    .connect(ep)
                    .and_then(|mut conn| self.exchange(&mut conn, ep, "fleet-status"));
                StatusOutcome { endpoint: ep.clone(), result }
            })
            .collect()
    }

    /// The registry-level auto-rollback hook: poll every replica's
    /// accuracy window (`acc=` in `fleet-status`); if any replica has
    /// degraded below `min_accuracy`, issue a fleet-wide rollback of
    /// `name` to last-good.  Returns the rollback outcomes when it
    /// fired, `None` when the fleet is healthy (or no replica reports
    /// a window yet).
    pub fn maybe_auto_rollback(
        &mut self,
        name: &str,
        min_accuracy: f64,
    ) -> Option<Vec<Outcome>> {
        let mut degraded = false;
        for outcome in self.status() {
            let Ok(line) = outcome.result else { continue };
            let acc = line
                .split_ascii_whitespace()
                .find_map(|tok| tok.strip_prefix("acc="))
                .and_then(|v| v.parse::<f64>().ok());
            if let Some(a) = acc {
                if a < min_accuracy {
                    degraded = true;
                }
            }
        }
        if degraded {
            Some(self.rollback(name))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ack_version_parses_fleet_replies() {
        assert_eq!(parse_ack_version("ok staged champ@v3 dim=4 nsv=20"), Some(3));
        assert_eq!(parse_ack_version("ok rollback champ@v1 registry=v5"), Some(1));
        assert_eq!(parse_ack_version("ok bye"), None);
        assert_eq!(parse_ack_version("ok staged champ@vX"), None);
    }

    #[test]
    fn unreachable_replica_is_a_typed_outcome() {
        // a port nothing listens on: connect fails fast
        let mut c = Controller::new(vec!["127.0.0.1:1".to_string()], Duration::from_millis(200));
        let out = c.rollback("champ");
        assert_eq!(out.len(), 1);
        assert!(matches!(&out[0].result, Err(FleetError::Replica { .. })), "{out:?}");
        assert_eq!(c.acked("127.0.0.1:1", "champ"), None);
    }

    #[test]
    fn status_reports_unreachable_replicas_as_dead_rows() {
        // both endpoints unreachable: the sweep still yields one typed
        // row per endpoint instead of failing wholesale
        let c = Controller::new(
            vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()],
            Duration::from_millis(200),
        );
        let rows = c.status();
        assert_eq!(rows.len(), 2);
        for (row, ep) in rows.iter().zip(["127.0.0.1:1", "127.0.0.1:2"]) {
            assert_eq!(row.endpoint, ep);
            assert!(!row.is_alive(), "{row:?}");
            assert!(matches!(&row.result, Err(FleetError::Replica { .. })), "{row:?}");
        }
    }
}

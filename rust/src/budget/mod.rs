//! Budget maintenance — the paper's contribution lives here.
//!
//! When a BSGD step would push the number of support vectors past the
//! budget `B`, a maintenance strategy reduces the store back to `B` with
//! the smallest possible weight degradation `‖Δ‖² = ‖w' − w‖²`:
//!
//! * [`Removal`]     — drop the smallest-|α| SV (Wang et al. baseline;
//!   known to oscillate).
//! * [`Projection`]  — drop + project onto the survivors (O(B³)).
//! * [`MultiMerge`]  — the paper: fix the smallest-|α| SV, score all B
//!   pairs with golden-section search (one Θ(B·K·G) scoring pass — the
//!   bottleneck this paper amortizes), keep the best `M−1` partners, and
//!   merge all `M` points.  `M = 2` is exactly classic BSGD merging;
//!   `M > 2` is multi-merge (Alg. 1 cascade or Alg. 2 gradient descent).
//!
//! All strategies implement [`Maintainer`] and are driven by the solver
//! through [`Budget`].

pub mod golden;
pub mod lut;
mod multimerge;
mod projection;
mod removal;

pub use lut::{MergeLut, MergeScoreMode};
pub use multimerge::{MergeExec, MultiMerge};
pub use projection::Projection;
pub use removal::Removal;

use crate::model::SvStore;
use crate::runtime::Backend;

/// Outcome of one maintenance invocation.
#[derive(Clone, Copy, Debug, Default)]
pub struct MaintStats {
    /// SVs removed from the store (multi-merge removes M−1 per event... plus
    /// adds the merged point: net reduction M−1).
    pub removed: usize,
    /// Exact weight degradation ‖Δ‖² incurred by this event.
    pub weight_degradation: f64,
    /// Number of binary merge (or GD merge) operations executed.
    pub merge_ops: usize,
}

/// A budget maintenance strategy.
pub trait Maintainer {
    /// Reduce `svs` so that `svs.len() <= budget`.  Called by the solver
    /// immediately after an insertion overflows the budget.
    fn maintain(
        &mut self,
        svs: &mut SvStore,
        gamma: f64,
        budget: usize,
        backend: &mut dyn Backend,
    ) -> MaintStats;

    fn name(&self) -> &'static str;
}

/// Which strategy to use (CLI/config surface).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MaintenanceKind {
    Removal,
    Projection,
    /// Multi-merge with `m` mergees via a cascade of binary golden-section
    /// merges (paper Alg. 1).  `m = 2` is the classic BSGD baseline.
    Merge { m: usize },
    /// Multi-merge with `m` mergees via joint gradient descent (Alg. 2).
    MergeGd { m: usize },
}

impl MaintenanceKind {
    pub fn build(self) -> Box<dyn Maintainer> {
        match self {
            MaintenanceKind::Removal => Box::new(Removal),
            MaintenanceKind::Projection => Box::new(Projection::default()),
            MaintenanceKind::Merge { m } => Box::new(MultiMerge::new(m, MergeExec::Cascade)),
            MaintenanceKind::MergeGd { m } => {
                Box::new(MultiMerge::new(m, MergeExec::GradientDescent))
            }
        }
    }

    /// Parse CLI spec: `removal`, `projection`, `merge` (=merge:2),
    /// `merge:M`, `mergegd:M`.
    pub fn parse(s: &str) -> Option<Self> {
        let (head, m) = match s.split_once(':') {
            Some((h, m)) => (h, m.parse::<usize>().ok()?),
            None => (s, 2),
        };
        if m < 2 || m > 16 {
            return None;
        }
        match head {
            "removal" => Some(Self::Removal),
            "projection" => Some(Self::Projection),
            "merge" => Some(Self::Merge { m }),
            "mergegd" => Some(Self::MergeGd { m }),
            _ => None,
        }
    }

    pub fn describe(&self) -> String {
        match self {
            Self::Removal => "removal".into(),
            Self::Projection => "projection".into(),
            Self::Merge { m } => format!("merge:{m}"),
            Self::MergeGd { m } => format!("mergegd:{m}"),
        }
    }
}

/// Budget policy + accumulated maintenance statistics for a run.
pub struct Budget {
    pub size: usize,
    pub maintainer: Box<dyn Maintainer>,
    /// Events triggered, total WD, total removed — the numbers behind
    /// the paper's Fig. 1 and the theory's `E-bar` term.
    pub events: u64,
    pub total_wd: f64,
    pub total_removed: u64,
    pub total_merge_ops: u64,
}

impl Budget {
    pub fn new(size: usize, kind: MaintenanceKind) -> Self {
        assert!(size >= 2, "budget must be at least 2");
        Self {
            size,
            maintainer: kind.build(),
            events: 0,
            total_wd: 0.0,
            total_removed: 0,
            total_merge_ops: 0,
        }
    }

    /// Enforce the budget if exceeded; records stats. Returns true if a
    /// maintenance event ran.
    pub fn enforce(
        &mut self,
        svs: &mut SvStore,
        gamma: f64,
        backend: &mut dyn Backend,
    ) -> bool {
        if svs.len() <= self.size {
            return false;
        }
        let stats = self.maintainer.maintain(svs, gamma, self.size, backend);
        self.events += 1;
        self.total_wd += stats.weight_degradation;
        self.total_removed += stats.removed as u64;
        self.total_merge_ops += stats.merge_ops as u64;
        debug_assert!(svs.len() <= self.size, "maintainer failed to enforce budget");
        true
    }

    /// Mean weight degradation per event (the `E` of Theorem 1 enters
    /// through this).
    pub fn mean_wd(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.total_wd / self.events as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn full_store(n: usize) -> SvStore {
        let mut s = SvStore::new(2);
        for i in 0..n {
            let t = i as f32 * 0.37;
            s.push(&[t.cos(), t.sin()], 0.1 + 0.05 * i as f64);
        }
        s
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(MaintenanceKind::parse("removal"), Some(MaintenanceKind::Removal));
        assert_eq!(MaintenanceKind::parse("merge"), Some(MaintenanceKind::Merge { m: 2 }));
        assert_eq!(MaintenanceKind::parse("merge:5"), Some(MaintenanceKind::Merge { m: 5 }));
        assert_eq!(
            MaintenanceKind::parse("mergegd:3"),
            Some(MaintenanceKind::MergeGd { m: 3 })
        );
        assert_eq!(MaintenanceKind::parse("merge:1"), None);
        assert_eq!(MaintenanceKind::parse("merge:99"), None);
        assert_eq!(MaintenanceKind::parse("bogus"), None);
    }

    #[test]
    fn describe_roundtrips_through_parse() {
        for kind in [
            MaintenanceKind::Removal,
            MaintenanceKind::Projection,
            MaintenanceKind::Merge { m: 4 },
            MaintenanceKind::MergeGd { m: 7 },
        ] {
            assert_eq!(MaintenanceKind::parse(&kind.describe()), Some(kind));
        }
    }

    #[test]
    fn enforce_noop_within_budget() {
        let mut b = Budget::new(10, MaintenanceKind::Merge { m: 2 });
        let mut svs = full_store(5);
        let mut be = NativeBackend::new();
        assert!(!b.enforce(&mut svs, 1.0, &mut be));
        assert_eq!(b.events, 0);
        assert_eq!(svs.len(), 5);
    }

    #[test]
    fn enforce_every_kind_reduces_to_budget() {
        for kind in [
            MaintenanceKind::Removal,
            MaintenanceKind::Projection,
            MaintenanceKind::Merge { m: 2 },
            MaintenanceKind::Merge { m: 4 },
            MaintenanceKind::MergeGd { m: 3 },
        ] {
            let mut b = Budget::new(8, kind);
            let mut svs = full_store(9);
            let mut be = NativeBackend::new();
            assert!(b.enforce(&mut svs, 0.5, &mut be), "{kind:?}");
            assert!(svs.len() <= 8, "{kind:?} left {} SVs", svs.len());
            assert_eq!(b.events, 1);
            assert!(b.total_wd >= -1e-9, "{kind:?} negative wd {}", b.total_wd);
        }
    }

    #[test]
    fn multimerge_reduces_by_m_minus_one() {
        // overflow of 1 with M=4: store drops from 12 to 9 (= 12-(M-1)),
        // still <= budget 11; repeated enforcement not needed.
        let mut b = Budget::new(11, MaintenanceKind::Merge { m: 4 });
        let mut svs = full_store(12);
        let mut be = NativeBackend::new();
        b.enforce(&mut svs, 0.5, &mut be);
        assert_eq!(svs.len(), 9);
    }
}

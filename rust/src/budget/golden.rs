//! Scalar golden-section search for the binary-merge objective.
//!
//! Merging SVs `(x_i, a_i)` and `(x_j, a_j)` under the Gaussian kernel:
//! the merged point lies on the connecting line, `z = h x_i + (1-h) x_j`
//! (paper sec. 2.3).  For fixed `z` the optimal coefficient is the
//! projection `a_z = g(h) = a_i e^{-c(1-h)²} + a_j e^{-c h²}` with
//! `c = γ‖x_i-x_j‖²`, and the weight degradation is
//! `‖Δ‖² = a_i² + a_j² + 2 a_i a_j e^{-c} − g(h)²`, so minimizing `‖Δ‖²`
//! means maximizing `|g(h)|`.
//!
//! This module is the *native* mirror of the L1 Pallas kernel
//! (`python/compile/kernels/merge_score.py`); the constants (interval
//! choice, G=30 iterations, 1/φ) are kept in lock-step — the
//! backend-equivalence test depends on it.

use crate::kernel::simd;

/// 1/φ.
pub const INVPHI: f64 = 0.618_033_988_749_894_9;

/// Fixed golden-section iteration count G (paper sec. 3).
pub const GS_ITERS: usize = 30;

/// g(h): the merged coefficient as a function of the line parameter.
/// Exponents route through the mode-aware [`simd::exp_neg`]
/// (`exp_mode = vector` evaluates the polynomial substrate here too,
/// so merge scoring and margins agree on one exp approximation; the
/// arguments are `c·(1-h)²` and `c·h²` ≥ 0, within the substrate's
/// clamped domain for every probe interval `h ∈ [-1, 2]`).
#[inline]
pub fn merge_objective(h: f64, a_i: f64, a_j: f64, c: f64) -> f64 {
    a_i * simd::exp_neg(c * (1.0 - h) * (1.0 - h)) + a_j * simd::exp_neg(c * h * h)
}

/// Golden-section max of |g| on [lo, hi]; returns (h*, |g(h*)|).
pub fn golden_max(lo: f64, hi: f64, a_i: f64, a_j: f64, c: f64, iters: usize) -> (f64, f64) {
    let obj = |h: f64| merge_objective(h, a_i, a_j, c).abs();
    let (mut lo, mut hi) = (lo, hi);
    let mut x1 = hi - INVPHI * (hi - lo);
    let mut x2 = lo + INVPHI * (hi - lo);
    let mut f1 = obj(x1);
    let mut f2 = obj(x2);
    for _ in 0..iters {
        if f1 > f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - INVPHI * (hi - lo);
            f1 = obj(x1);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + INVPHI * (hi - lo);
            f2 = obj(x2);
        }
    }
    let h = 0.5 * (lo + hi);
    (h, obj(h))
}

/// Result of an optimal binary merge.
#[derive(Clone, Copy, Debug)]
pub struct PairMerge {
    /// Line parameter: z = h x_i + (1-h) x_j.
    pub h: f64,
    /// Merged coefficient.
    pub a_z: f64,
    /// Weight degradation ‖Δ‖².
    pub wd: f64,
}

/// Far-pair closed form (perf, EXPERIMENTS.md §Perf): for c = γd² above
/// [`crate::kernel::EXP_NEG_CUTOFF`], k_ij = e^-c is below f64 noise and
/// the optimal merge degenerates to "keep the bigger-|α| point": h at
/// that endpoint, a_z = its α, wd = min(a_i, a_j)².  Exact to ~e^-80;
/// skips 60+ exp calls for the (dominant) cross-cluster candidate pairs.
/// Shared by the exact scorer below and the LUT scorer
/// ([`crate::budget::MergeLut`]).
#[inline]
pub fn far_pair_merge(a_i: f64, a_j: f64) -> PairMerge {
    let keep_i = a_i.abs() >= a_j.abs();
    PairMerge {
        h: if keep_i { 1.0 } else { 0.0 },
        a_z: if keep_i { a_i } else { a_j },
        wd: a_i.abs().min(a_j.abs()).powi(2),
    }
}

/// Solve the binary merge for coefficients and `c = γ d²`.
///
/// Interval selection per the paper: same-sign coefficients → h∈[0,1]
/// (convex combination); opposite signs → the optimum lies outside,
/// search [-1,0] and [1,2] and keep the better.
pub fn merge_pair_params(a_i: f64, a_j: f64, c: f64, iters: usize) -> PairMerge {
    if c > crate::kernel::EXP_NEG_CUTOFF {
        return far_pair_merge(a_i, a_j);
    }
    let (h, gabs) = if a_i * a_j >= 0.0 {
        golden_max(0.0, 1.0, a_i, a_j, c, iters)
    } else {
        let l = golden_max(-1.0, 0.0, a_i, a_j, c, iters);
        let r = golden_max(1.0, 2.0, a_i, a_j, c, iters);
        if l.1 > r.1 {
            l
        } else {
            r
        }
    };
    let a_z = merge_objective(h, a_i, a_j, c);
    let k_ij = simd::exp_neg(c);
    let wd = a_i * a_i + a_j * a_j + 2.0 * a_i * a_j * k_ij - gabs * gabs;
    PairMerge { h, a_z, wd }
}

/// Full binary merge of two points: returns (z, a_z, wd).
pub fn merge_pair(
    x_i: &[f32],
    a_i: f64,
    x_j: &[f32],
    a_j: f64,
    gamma: f64,
    iters: usize,
) -> (Vec<f32>, f64, f64) {
    let d2 = crate::kernel::sq_dist(x_i, x_j);
    let pm = merge_pair_params(a_i, a_j, gamma * d2, iters);
    let z: Vec<f32> = x_i
        .iter()
        .zip(x_j)
        .map(|(&xi, &xj)| (pm.h * xi as f64 + (1.0 - pm.h) * xj as f64) as f32)
        .collect();
    (z, pm.a_z, pm.wd)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_points_merge_exactly() {
        let x = [1.0f32, -2.0];
        let (z, a_z, wd) = merge_pair(&x, 0.7, &x, 0.3, 2.0, GS_ITERS);
        assert_eq!(z, x.to_vec());
        assert!((a_z - 1.0).abs() < 1e-9);
        assert!(wd.abs() < 1e-9);
    }

    #[test]
    fn symmetric_same_sign_merge_is_midpoint() {
        // equal coefficients, symmetric problem -> h = 0.5
        let pm = merge_pair_params(0.5, 0.5, 1.0, GS_ITERS);
        assert!((pm.h - 0.5).abs() < 1e-6, "h={}", pm.h);
        assert!(pm.wd >= 0.0);
    }

    #[test]
    fn same_sign_h_in_unit_interval() {
        for &(a, b, c) in &[(0.1, 0.9, 0.3), (1.0, 0.2, 5.0), (0.4, 0.4, 50.0)] {
            let pm = merge_pair_params(a, b, c, GS_ITERS);
            assert!((0.0..=1.0).contains(&pm.h), "h={} out of [0,1]", pm.h);
        }
    }

    #[test]
    fn opposite_sign_h_outside_unit_interval() {
        let pm = merge_pair_params(1.0, -0.3, 0.8, GS_ITERS);
        assert!(pm.h <= 0.0 || pm.h >= 1.0, "h={}", pm.h);
    }

    #[test]
    fn beats_removal() {
        // Merging must never be worse than removing the smaller-|α| point
        // (removal = the h=1 endpoint, a_z = a_i + a_j k_ij projection is
        // at least as good because golden section includes the endpoints'
        // neighbourhood).  Compare against the exact removal degradation
        // ‖a_j φ_j − (a_z−a_i)…‖: use wd(removal of j) = a_j²(1−k²) form.
        for &(a_i, a_j, c) in &[(0.05, 0.8, 0.5), (0.3, 0.4, 2.0), (0.2, -0.7, 1.0)] {
            let pm = merge_pair_params(a_i, a_j, c, GS_ITERS);
            // removal of the point with smaller |α| keeps the other; its
            // degradation (best reachable with h at an endpoint, α_z free)
            let k = (-c as f64).exp();
            let small = a_i.abs().min(a_j.abs());
            let big = a_i.abs().max(a_j.abs());
            let _ = big;
            let wd_removal = small * small * (1.0 - k * k);
            assert!(
                pm.wd <= wd_removal + 1e-9,
                "merge wd {} > removal wd {} (a_i={a_i}, a_j={a_j}, c={c})",
                pm.wd,
                wd_removal
            );
        }
    }

    #[test]
    fn degradation_nonnegative() {
        let mut cases = Vec::new();
        for i in 0..20 {
            let a_i = (i as f64 - 10.0) / 7.0 + 0.01;
            for j in 0..10 {
                cases.push((a_i, (j as f64 - 5.0) / 3.0 + 0.02, 0.1 * (j + 1) as f64));
            }
        }
        for (a_i, a_j, c) in cases {
            let pm = merge_pair_params(a_i, a_j, c, GS_ITERS);
            assert!(pm.wd > -1e-9, "wd={} for ({a_i},{a_j},{c})", pm.wd);
        }
    }

    #[test]
    fn far_points_keep_dominant() {
        // c -> large: merging ≈ keeping the bigger-|α| point (h near its end)
        let pm = merge_pair_params(0.1, 0.9, 500.0, GS_ITERS);
        assert!(pm.h < 0.2, "h={} should approach 0 (keep x_j side)", pm.h);
        assert!((pm.a_z - 0.9).abs() < 0.05);
    }

    #[test]
    fn merge_point_on_connecting_line() {
        let x_i = [0.0f32, 0.0];
        let x_j = [2.0f32, 2.0];
        let (z, _, _) = merge_pair(&x_i, 0.4, &x_j, 0.6, 1.0, GS_ITERS);
        assert!((z[0] - z[1]).abs() < 1e-6, "z={z:?} not on the diagonal");
    }
}

//! Multi-merge budget maintenance — the paper's contribution.
//!
//! One maintenance event (paper sec. 3):
//!
//! 1. Fix the first merge candidate: the SV with the smallest |α|.
//! 2. Score every other SV as a merge partner — one Θ(B·K) pass of the
//!    configured scorer (LUT or golden section) through
//!    [`Backend::merge_scores_into`], i.e. the blocked tile engine on
//!    the native backend.
//! 3. Keep the best `M−1` partners by pairwise weight degradation — the
//!    information BSGD throws away; multi-merge re-uses it.
//! 4. Merge all `M` points into one, either by
//!    * [`MergeExec::Cascade`] — `M−1` sequential binary golden-section
//!      merges, cheapest first (Alg. 1, footnote 1), or
//!    * [`MergeExec::GradientDescent`] — a joint minimization of the
//!      total degradation over `z` (Alg. 2).
//!
//! With `M = 2` and `Cascade` this is *exactly* the original BSGD
//! merging of Wang et al. — the baseline of every experiment.
//!
//! **Steady state allocates nothing**: scoring output, partner order,
//! the merge-set snapshot, and the merged point all live in reusable
//! buffers held on the maintainer.
//!
//! **Amortized multi-event maintenance.**  When one `maintain` call
//! must run several events (a budget shrink, a multi-point overflow),
//! the per-event Θ(B·K) rescans dominate.  The maintainer instead
//! pre-scores the `k` smallest-|α| candidates in one tiled
//! [`Backend::merge_scores_batch`] pass and *remaps* a cached row at
//! each event: pair scores depend only on the two SVs' (point, α),
//! which merging never touches for survivors, so a cached lane is
//! bit-identical to a fresh rescan — surviving lanes are relabelled
//! through the swap-remove permutation, lanes of merged-away SVs drop
//! out, and the one freshly merged point per event gets a single
//! O(K) [`Backend::merge_score_pair`] patch.  If the running stream
//! ever picks a candidate outside the prefetched set, the event simply
//! falls back to a fresh scoring pass — the result is identical either
//! way (`cached_multi_event_maintain_matches_fresh_rescan` pins it).
//! The prefetch only engages on backends with a cheap per-pair patch
//! primitive ([`Backend::has_cheap_pair_scoring`] — native and hybrid);
//! for the rest (XLA: every scoring call is a full artifact dispatch)
//! the per-event rescan is already the cheaper schedule and is kept.

use super::golden::{self, GS_ITERS};
use super::{MaintStats, Maintainer};
use crate::model::SvStore;
use crate::runtime::{exact_multi_wd, Backend, MergeScores};

/// Cap on candidates pre-scored per `maintain` call: bounds cache
/// memory at `32 × B` lanes while covering any realistic shrink burst.
const MAX_PREFETCH: usize = 32;

/// Sentinel id for SVs created after the prefetch pass (no cached row).
const FRESH_ID: usize = usize::MAX;

/// How the selected M points are folded into one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MergeExec {
    /// Alg. 1: sequence of M−1 binary golden-section merges.
    Cascade,
    /// Alg. 2: joint gradient descent on the merged point.
    GradientDescent,
}

pub struct MultiMerge {
    /// Number of mergees M ≥ 2 (M = 2 ⇒ classic BSGD).
    pub m: usize,
    pub exec: MergeExec,
    /// Reusable partner-index scratch (no allocation per event).
    order: Vec<usize>,
    /// Reusable per-event scoring output.
    scores: MergeScores,
    /// Flat merge-set snapshot (≤ M rows × dim) for the exact-WD audit.
    pts: Vec<f32>,
    alpha_buf: Vec<f64>,
    /// Reusable merged-point buffer.
    z: Vec<f32>,
    /// Slot → prefetch-id map while a batch cache is live.
    ids: Vec<usize>,
    /// Cached scoring rows by prefetch id (consumed once per event).
    cache: Vec<Option<MergeScores>>,
}

impl MultiMerge {
    pub fn new(m: usize, exec: MergeExec) -> Self {
        assert!((2..=16).contains(&m), "mergees M must be in 2..=16, got {m}");
        Self {
            m,
            exec,
            order: Vec::new(),
            scores: MergeScores::default(),
            pts: Vec::new(),
            alpha_buf: Vec::new(),
            z: Vec::new(),
            ids: Vec::new(),
            cache: Vec::new(),
        }
    }

    /// Select the best `take` partner indices by ascending pairwise wd,
    /// returned *in increasing-wd order* (the cascade merges cheapest
    /// first, per the paper's footnote 1) as a view into the
    /// maintainer's scratch — no per-event allocation.  Test-facing
    /// wrapper over [`select_partners_into`]; deliberately not public
    /// API — it exposes a view into internal scratch.
    #[cfg(test)]
    fn select_partners(&mut self, wd: &[f64], take: usize) -> &[usize] {
        let n = select_partners_into(&mut self.order, wd, take);
        &self.order[..n]
    }
}

/// [`MultiMerge::select_partners`] on an explicit buffer; returns the
/// selected count (the head of `order`).  `select_nth_unstable_by`
/// partitions the `take` smallest to the head, then only that head is
/// (stably) ordered: O(B + take log take).
fn select_partners_into(order: &mut Vec<usize>, wd: &[f64], take: usize) -> usize {
    order.clear();
    order.extend((0..wd.len()).filter(|&j| wd[j].is_finite()));
    let take = take.min(order.len());
    if take > 0 && take < order.len() {
        order.select_nth_unstable_by(take, |&a, &b| wd[a].total_cmp(&wd[b]));
    }
    order.truncate(take);
    order.sort_by(|&a, &b| wd[a].total_cmp(&wd[b]));
    take
}

impl Maintainer for MultiMerge {
    fn maintain(
        &mut self,
        svs: &mut SvStore,
        gamma: f64,
        budget: usize,
        backend: &mut dyn Backend,
    ) -> MaintStats {
        let mut stats = MaintStats::default();
        let m = self.m;
        let dim = svs.dim();

        // Amortized prefetch: only when this call must run > 1 event
        // (one event reduces the store by at most M−1) AND the backend
        // can patch cached rows cheaply — on a backend whose
        // merge_score_pair is the full-pass trait default, replaying
        // cached rows would cost a Θ(B·K) pass per fresh lane, i.e.
        // asymptotically more than the per-event rescans it replaces.
        self.cache.clear();
        self.ids.clear();
        let overflow = svs.len().saturating_sub(budget);
        let prefetched = svs.len() >= 2 && overflow > m - 1 && backend.has_cheap_pair_scoring();
        if prefetched {
            let k = ((overflow + m - 2) / (m - 1)).min(MAX_PREFETCH).min(svs.len());
            self.order.clear();
            self.order.extend(0..svs.len());
            let raw = svs.raw_alphas(); // uniform scale: argmin-safe
            if k < self.order.len() {
                self.order
                    .select_nth_unstable_by(k - 1, |&a, &b| raw[a].abs().total_cmp(&raw[b].abs()));
            }
            self.order.truncate(k);
            let batch = backend.merge_scores_batch(svs, gamma, &self.order);
            self.cache.resize_with(svs.len(), || None);
            for (&c, row) in self.order.iter().zip(batch) {
                self.cache[c] = Some(row);
            }
            self.ids.extend(0..svs.len());
        }
        let b0 = self.cache.len();

        while svs.len() > budget && svs.len() >= 2 {
            // (1) first candidate: smallest |α|.
            let i = svs.min_abs_alpha().expect("nonempty");

            // (2) the Θ(B·K) scoring pass — or its cached stand-in.
            let cached_row = if prefetched && self.ids[i] < b0 {
                self.cache[self.ids[i]].take()
            } else {
                None
            };
            match cached_row {
                Some(row) => {
                    self.scores.reset(svs.len());
                    for j in 0..svs.len() {
                        if j == i {
                            continue; // self lane keeps wd = +inf
                        }
                        let idj = self.ids[j];
                        if idj < b0 {
                            self.scores.wd[j] = row.wd[idj];
                            self.scores.h[j] = row.h[idj];
                            self.scores.a_z[j] = row.a_z[idj];
                            self.scores.d2[j] = row.d2[idj];
                        } else {
                            // merged point born after the prefetch pass
                            let p = backend.merge_score_pair(svs, gamma, i, j);
                            self.scores.wd[j] = p.wd;
                            self.scores.h[j] = p.h;
                            self.scores.a_z[j] = p.a_z;
                            self.scores.d2[j] = p.d2;
                        }
                    }
                }
                None => backend.merge_scores_into(svs, gamma, i, &mut self.scores),
            }

            // (3) best M−1 partners (into the scratch order buffer).
            let n_sel = select_partners_into(&mut self.order, &self.scores.wd, m - 1);
            if n_sel == 0 {
                // Degenerate: nothing mergeable — fall back to removal.
                let a = svs.alpha(i);
                stats.weight_degradation += a * a;
                svs.swap_remove(i);
                if prefetched {
                    self.ids.swap_remove(i);
                }
                stats.removed += 1;
                continue;
            }
            let mut partners_buf = [0usize; 16];
            partners_buf[..n_sel].copy_from_slice(&self.order[..n_sel]);
            let partners = &partners_buf[..n_sel];

            // Snapshot the merge set for the exact-WD audit (flat
            // reusable buffers — the old per-event Vec-of-Vecs clone is
            // gone).
            self.pts.clear();
            self.alpha_buf.clear();
            for &j in std::iter::once(&i).chain(partners) {
                self.pts.extend_from_slice(svs.point(j));
                self.alpha_buf.push(svs.alpha(j));
            }
            let n_pts = self.alpha_buf.len();

            // (4) execute the merge into the reusable z buffer.
            self.z.clear();
            let a_z = match self.exec {
                MergeExec::Cascade => {
                    // First binary merge reuses the scored (h, a_z) for
                    // (i, partners[0]) — no extra golden section.
                    let j0 = partners[0];
                    let h = self.scores.h[j0];
                    self.z.extend(
                        svs.point(i)
                            .iter()
                            .zip(svs.point(j0))
                            .map(|(&xi, &xj)| (h * xi as f64 + (1.0 - h) * xj as f64) as f32),
                    );
                    let mut a_z = self.scores.a_z[j0];
                    stats.merge_ops += 1;
                    for &jk in &partners[1..] {
                        // golden::merge_pair, unrolled to update z in
                        // place (same math, no allocation).
                        let d2 = crate::kernel::sq_dist(&self.z, svs.point(jk));
                        let pm =
                            golden::merge_pair_params(a_z, svs.alpha(jk), gamma * d2, GS_ITERS);
                        for (zt, &xt) in self.z.iter_mut().zip(svs.point(jk)) {
                            *zt = (pm.h * *zt as f64 + (1.0 - pm.h) * xt as f64) as f32;
                        }
                        a_z = pm.a_z;
                        stats.merge_ops += 1;
                    }
                    a_z
                }
                MergeExec::GradientDescent => {
                    let mut view: [(&[f32], f64); 16] = [(&[][..], 0.0); 16];
                    for (t, slot) in view[..n_pts].iter_mut().enumerate() {
                        *slot = (&self.pts[t * dim..(t + 1) * dim], self.alpha_buf[t]);
                    }
                    let (z, a_z, _wd) = backend.merge_gd(&view[..n_pts], gamma);
                    self.z.extend_from_slice(&z);
                    stats.merge_ops += 1;
                    a_z
                }
            };

            // Exact degradation of the whole event (cascade returns only
            // per-step estimates; the audit value is what Theorem 1 sees).
            {
                let mut view: [(&[f32], f64); 16] = [(&[][..], 0.0); 16];
                for (t, slot) in view[..n_pts].iter_mut().enumerate() {
                    *slot = (&self.pts[t * dim..(t + 1) * dim], self.alpha_buf[t]);
                }
                stats.weight_degradation +=
                    exact_multi_wd(&view[..n_pts], &self.z, a_z, gamma).max(0.0);
            }

            // Remove merged SVs (descending index keeps indices valid
            // under swap_remove), then insert the merged point.
            let mut to_remove = [0usize; 16];
            to_remove[0] = i;
            to_remove[1..=n_sel].copy_from_slice(partners);
            let to_remove = &mut to_remove[..n_sel + 1];
            to_remove.sort_unstable_by(|a, b| b.cmp(a));
            for &j in to_remove.iter() {
                svs.swap_remove(j);
                if prefetched {
                    self.ids.swap_remove(j);
                }
            }
            svs.push(&self.z, a_z);
            if prefetched {
                self.ids.push(FRESH_ID);
            }
            stats.removed += n_pts - 1;
        }

        // Cached rows are only valid within this call: the solver
        // rescales every α between maintenance events.
        self.cache.clear();
        self.ids.clear();
        stats
    }

    fn name(&self) -> &'static str {
        match self.exec {
            MergeExec::Cascade => "multimerge-cascade",
            MergeExec::GradientDescent => "multimerge-gd",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn clustered_store(n: usize) -> SvStore {
        // two tight clusters: merges inside a cluster are cheap
        let mut s = SvStore::new(2);
        for i in 0..n {
            let c = if i % 2 == 0 { 0.0f32 } else { 5.0 };
            let eps = (i as f32) * 0.01;
            s.push(&[c + eps, c - eps], 0.2 + 0.01 * i as f64);
        }
        s
    }

    #[test]
    fn m2_reduces_by_one() {
        let mut mm = MultiMerge::new(2, MergeExec::Cascade);
        let mut svs = clustered_store(10);
        let mut be = NativeBackend::new();
        let stats = mm.maintain(&mut svs, 1.0, 9, &mut be);
        assert_eq!(svs.len(), 9);
        assert_eq!(stats.removed, 1);
        assert_eq!(stats.merge_ops, 1);
    }

    #[test]
    fn m5_reduces_by_four() {
        let mut mm = MultiMerge::new(5, MergeExec::Cascade);
        let mut svs = clustered_store(12);
        let mut be = NativeBackend::new();
        let stats = mm.maintain(&mut svs, 1.0, 11, &mut be);
        assert_eq!(svs.len(), 8);
        assert_eq!(stats.removed, 4);
        assert_eq!(stats.merge_ops, 4);
    }

    #[test]
    fn gd_exec_also_enforces() {
        let mut mm = MultiMerge::new(3, MergeExec::GradientDescent);
        let mut svs = clustered_store(9);
        let mut be = NativeBackend::new();
        let stats = mm.maintain(&mut svs, 1.0, 8, &mut be);
        assert_eq!(svs.len(), 7);
        assert_eq!(stats.merge_ops, 1);
        assert!(stats.weight_degradation >= 0.0);
    }

    #[test]
    fn partners_are_nearest_cluster_mates() {
        // The smallest-|α| SV sits in cluster A; its selected partners
        // must come from cluster A, not the far cluster.
        let mut svs = SvStore::new(1);
        svs.push(&[0.00], 0.01); // smallest |α| — candidate
        svs.push(&[0.05], 0.5);
        svs.push(&[0.10], 0.6);
        svs.push(&[9.00], 0.2);
        svs.push(&[9.10], 0.3);
        let mut be = NativeBackend::new();
        let mut mm = MultiMerge::new(3, MergeExec::Cascade);
        let stats = mm.maintain(&mut svs, 1.0, 4, &mut be);
        assert_eq!(svs.len(), 3);
        // far-cluster SVs must be untouched
        let mut far: Vec<f64> = (0..svs.len())
            .filter(|&j| svs.point(j)[0] > 5.0)
            .map(|j| svs.alpha(j))
            .collect();
        far.sort_by(f64::total_cmp);
        assert_eq!(far, vec![0.2, 0.3]);
        assert!(stats.weight_degradation < 0.05, "wd={}", stats.weight_degradation);
    }

    #[test]
    fn merged_coefficient_mass_roughly_preserved() {
        // same-sign tight cluster: α_z ≈ Σα (k ≈ 1 between all points)
        let mut svs = SvStore::new(1);
        for i in 0..4 {
            svs.push(&[0.001 * i as f32], 0.25);
        }
        svs.push(&[100.0], 5.0); // spectator
        let mut be = NativeBackend::new();
        let mut mm = MultiMerge::new(4, MergeExec::Cascade);
        mm.maintain(&mut svs, 1.0, 4, &mut be);
        let total: f64 = svs.alphas_vec().iter().sum();
        assert!((total - 6.0).abs() < 0.01, "mass {total}");
    }

    #[test]
    fn select_partners_orders_by_wd() {
        let mut mm = MultiMerge::new(4, MergeExec::Cascade);
        let wd = vec![0.5, f64::INFINITY, 0.1, 0.9, 0.2];
        let picked = mm.select_partners(&wd, 3);
        assert_eq!(picked, vec![2, 4, 0]);
    }

    #[test]
    fn select_partners_handles_fewer_than_take() {
        let mut mm = MultiMerge::new(4, MergeExec::Cascade);
        let wd = vec![f64::INFINITY, 0.3];
        assert_eq!(mm.select_partners(&wd, 3), vec![1]);
    }

    #[test]
    fn m2_cascade_matches_plain_golden_merge() {
        // With M=2 the event must be exactly a single binary merge of the
        // min-|α| SV with its best partner.  Exact scoring mode: the
        // assertion pins bit-level reuse of the scored (h, a_z), which
        // only the golden-section scorer reproduces.
        let mut svs = SvStore::new(1);
        svs.push(&[0.0], 0.05);
        svs.push(&[0.3], 0.7);
        svs.push(&[2.0], 0.9);
        let x_i = [0.0f32];
        let x_j = [0.3f32];
        let (z_want, a_want, _) = golden::merge_pair(&x_i, 0.05, &x_j, 0.7, 1.0, GS_ITERS);
        let mut be = NativeBackend::exact();
        let mut mm = MultiMerge::new(2, MergeExec::Cascade);
        mm.maintain(&mut svs, 1.0, 2, &mut be);
        // find the merged SV (the one that is neither original survivor)
        let merged: Vec<usize> = (0..svs.len())
            .filter(|&j| svs.point(j)[0] != 2.0)
            .collect();
        assert_eq!(merged.len(), 1);
        let j = merged[0];
        assert!((svs.point(j)[0] - z_want[0]).abs() < 1e-6);
        assert!((svs.alpha(j) - a_want).abs() < 1e-9);
    }

    /// Reference multi-event maintain: the pre-amortization algorithm —
    /// a fresh `merge_scores` pass per event, no caching.  The cached
    /// path must reproduce it bit-for-bit.
    fn maintain_fresh_rescan(
        m: usize,
        svs: &mut SvStore,
        gamma: f64,
        budget: usize,
        be: &mut NativeBackend,
    ) -> MaintStats {
        let mut stats = MaintStats::default();
        while svs.len() > budget && svs.len() >= 2 {
            let i = svs.min_abs_alpha().unwrap();
            let scores = be.merge_scores(svs, gamma, i);
            let mut order = Vec::new();
            let n_sel = select_partners_into(&mut order, &scores.wd, m - 1);
            if n_sel == 0 {
                let a = svs.alpha(i);
                stats.weight_degradation += a * a;
                svs.swap_remove(i);
                stats.removed += 1;
                continue;
            }
            let partners = &order[..n_sel];
            let merge_points: Vec<(Vec<f32>, f64)> = std::iter::once(i)
                .chain(partners.iter().copied())
                .map(|j| (svs.point(j).to_vec(), svs.alpha(j)))
                .collect();
            let j0 = partners[0];
            let h = scores.h[j0];
            let mut z: Vec<f32> = svs
                .point(i)
                .iter()
                .zip(svs.point(j0))
                .map(|(&xi, &xj)| (h * xi as f64 + (1.0 - h) * xj as f64) as f32)
                .collect();
            let mut a_z = scores.a_z[j0];
            stats.merge_ops += 1;
            for &jk in &partners[1..] {
                let (z2, a2, _) =
                    golden::merge_pair(&z, a_z, svs.point(jk), svs.alpha(jk), gamma, GS_ITERS);
                z = z2;
                a_z = a2;
                stats.merge_ops += 1;
            }
            let pts: Vec<(&[f32], f64)> =
                merge_points.iter().map(|(x, a)| (x.as_slice(), *a)).collect();
            stats.weight_degradation += exact_multi_wd(&pts, &z, a_z, gamma).max(0.0);
            let mut to_remove: Vec<usize> =
                std::iter::once(i).chain(partners.iter().copied()).collect();
            to_remove.sort_unstable_by(|a, b| b.cmp(a));
            for j in to_remove {
                svs.swap_remove(j);
            }
            svs.push(&z, a_z);
            stats.removed += merge_points.len() - 1;
        }
        stats
    }

    #[test]
    fn cached_multi_event_maintain_matches_fresh_rescan() {
        // A deep budget shrink forces many consecutive events, so the
        // amortized path exercises prefetch, lane remapping through the
        // swap-remove permutation, merged-point patching, AND the
        // fresh-scoring fallback.  Final store and stats must be
        // bit-identical to the per-event rescan reference.
        let mut rng = crate::rng::Xoshiro256::new(77);
        for (m, budget) in [(2usize, 20usize), (3, 9), (5, 6)] {
            let mut base = SvStore::new(3);
            for _ in 0..40 {
                let x: Vec<f32> =
                    (0..3).map(|_| rng.next_gaussian() as f32 * 0.6).collect();
                let mut a = 0.05 + rng.next_f64();
                if rng.next_f64() < 0.4 {
                    a = -a;
                }
                base.push(&x, a);
            }
            for mode_exact in [false, true] {
                let mk = || {
                    if mode_exact {
                        NativeBackend::exact()
                    } else {
                        NativeBackend::new()
                    }
                };
                let mut a_svs = base.clone();
                let mut b_svs = base.clone();
                let s_a = MultiMerge::new(m, MergeExec::Cascade)
                    .maintain(&mut a_svs, 0.9, budget, &mut mk());
                let s_b = maintain_fresh_rescan(m, &mut b_svs, 0.9, budget, &mut mk());
                assert_eq!(a_svs.len(), b_svs.len(), "M={m} B={budget}");
                assert_eq!(a_svs.points_flat(), b_svs.points_flat(), "M={m} B={budget}");
                assert_eq!(a_svs.alphas_vec(), b_svs.alphas_vec(), "M={m} B={budget}");
                assert_eq!(s_a.removed, s_b.removed);
                assert_eq!(s_a.merge_ops, s_b.merge_ops);
                assert_eq!(
                    s_a.weight_degradation.to_bits(),
                    s_b.weight_degradation.to_bits(),
                    "M={m} B={budget} exact={mode_exact}"
                );
            }
        }
    }

    /// Backend stuck with the trait-default `merge_score_pair` /
    /// `merge_scores_batch` (full pass per call, like the XLA artifact
    /// backend): counts full scoring passes so the test can pin that
    /// the prefetch never engages for it.
    struct SlowPairBackend {
        inner: NativeBackend,
        scoring_passes: usize,
    }

    impl Backend for SlowPairBackend {
        fn name(&self) -> &'static str {
            "slow-pair-test"
        }

        fn margins(
            &mut self,
            svs: &SvStore,
            gamma: f64,
            q: &crate::data::DenseMatrix,
        ) -> Vec<f64> {
            self.inner.margins(svs, gamma, q)
        }

        fn margin1(&mut self, svs: &SvStore, gamma: f64, x: &[f32]) -> f64 {
            self.inner.margin1(svs, gamma, x)
        }

        fn merge_scores(&mut self, svs: &SvStore, gamma: f64, i: usize) -> MergeScores {
            self.scoring_passes += 1;
            self.inner.merge_scores(svs, gamma, i)
        }

        fn merge_gd(&mut self, points: &[(&[f32], f64)], gamma: f64) -> (Vec<f32>, f64, f64) {
            self.inner.merge_gd(points, gamma)
        }
    }

    #[test]
    fn prefetch_gated_off_without_cheap_pair_scoring() {
        // A deep shrink on a backend whose per-pair patch would be a
        // full Θ(B·K) pass must keep the per-event rescan schedule:
        // exactly one scoring pass per merge event — no batch prefetch,
        // no per-lane patch passes.
        let mut be = SlowPairBackend { inner: NativeBackend::new(), scoring_passes: 0 };
        let mut svs = clustered_store(30);
        let mut mm = MultiMerge::new(3, MergeExec::Cascade);
        mm.maintain(&mut svs, 1.0, 8, &mut be);
        assert_eq!(svs.len(), 8);
        // 30 → 8 at M−1 = 2 removals per event: 11 events, 11 passes
        assert_eq!(be.scoring_passes, 11);
    }

    #[test]
    fn maintainer_reuse_across_calls_is_clean() {
        // The same maintainer instance drives many events across many
        // calls (that is how the solver uses it); cached state must not
        // leak between calls.
        let mut mm = MultiMerge::new(3, MergeExec::Cascade);
        let mut be = NativeBackend::new();
        let mut svs = clustered_store(30);
        mm.maintain(&mut svs, 1.0, 8, &mut be); // deep shrink: cache used
        assert!(svs.len() <= 8);
        let n = svs.len();
        svs.push(&[1.0, 1.0], 0.01);
        let stats = mm.maintain(&mut svs, 1.0, n, &mut be); // single event
        assert!(svs.len() <= n);
        assert!(stats.removed >= 1);
    }
}

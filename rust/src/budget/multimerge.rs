//! Multi-merge budget maintenance — the paper's contribution.
//!
//! One maintenance event (paper sec. 3):
//!
//! 1. Fix the first merge candidate: the SV with the smallest |α|.
//! 2. Score every other SV as a merge partner — one Θ(B·K·G) pass of
//!    golden-section searches (the classic bottleneck, executed through
//!    [`Backend::merge_scores`], i.e. the vectorized Pallas kernel on
//!    the XLA backend).
//! 3. Keep the best `M−1` partners by pairwise weight degradation — the
//!    information BSGD throws away; multi-merge re-uses it.
//! 4. Merge all `M` points into one, either by
//!    * [`MergeExec::Cascade`] — `M−1` sequential binary golden-section
//!      merges, cheapest first (Alg. 1, footnote 1), or
//!    * [`MergeExec::GradientDescent`] — a joint minimization of the
//!      total degradation over `z` (Alg. 2).
//!
//! With `M = 2` and `Cascade` this is *exactly* the original BSGD
//! merging of Wang et al. — the baseline of every experiment.

use super::golden::{self, GS_ITERS};
use super::{MaintStats, Maintainer};
use crate::model::SvStore;
use crate::runtime::{exact_multi_wd, Backend};

/// How the selected M points are folded into one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MergeExec {
    /// Alg. 1: sequence of M−1 binary golden-section merges.
    Cascade,
    /// Alg. 2: joint gradient descent on the merged point.
    GradientDescent,
}

pub struct MultiMerge {
    /// Number of mergees M ≥ 2 (M = 2 ⇒ classic BSGD).
    pub m: usize,
    pub exec: MergeExec,
    /// Reusable partner-index scratch (no allocation per event).
    order: Vec<usize>,
}

impl MultiMerge {
    pub fn new(m: usize, exec: MergeExec) -> Self {
        assert!((2..=16).contains(&m), "mergees M must be in 2..=16, got {m}");
        Self { m, exec, order: Vec::new() }
    }

    /// Select the best `take` partner indices by ascending pairwise wd.
    /// Returns them *in increasing-wd order* (the cascade merges cheapest
    /// first, per the paper's footnote 1).
    fn select_partners(&mut self, wd: &[f64], take: usize) -> Vec<usize> {
        self.order.clear();
        self.order.extend((0..wd.len()).filter(|&j| wd[j].is_finite()));
        let take = take.min(self.order.len());
        // Partial selection then sort of the head: O(B + take log take).
        if take < self.order.len() {
            self.order
                .select_nth_unstable_by(take, |&a, &b| wd[a].total_cmp(&wd[b]));
        }
        self.order.truncate(take);
        self.order.sort_by(|&a, &b| wd[a].total_cmp(&wd[b]));
        self.order.clone()
    }
}

impl Maintainer for MultiMerge {
    fn maintain(
        &mut self,
        svs: &mut SvStore,
        gamma: f64,
        budget: usize,
        backend: &mut dyn Backend,
    ) -> MaintStats {
        let mut stats = MaintStats::default();
        while svs.len() > budget && svs.len() >= 2 {
            // (1) first candidate: smallest |α|.
            let i = svs.min_abs_alpha().expect("nonempty");
            // (2) the Θ(B·K·G) scoring pass.
            let scores = backend.merge_scores(svs, gamma, i);
            // (3) best M−1 partners.
            let partners = self.select_partners(&scores.wd, self.m - 1);
            if partners.is_empty() {
                // Degenerate: nothing mergeable — fall back to removal.
                let a = svs.alpha(i);
                stats.weight_degradation += a * a;
                svs.swap_remove(i);
                stats.removed += 1;
                continue;
            }

            // Snapshot the merge set for the exact-WD audit.
            let merge_points: Vec<(Vec<f32>, f64)> = std::iter::once(i)
                .chain(partners.iter().copied())
                .map(|j| (svs.point(j).to_vec(), svs.alpha(j)))
                .collect();

            // (4) execute the merge.
            let (z, a_z) = match self.exec {
                MergeExec::Cascade => {
                    // First binary merge reuses the scored (h, a_z) for
                    // (i, partners[0]) — no extra golden section.
                    let j0 = partners[0];
                    let h = scores.h[j0];
                    let mut z: Vec<f32> = svs
                        .point(i)
                        .iter()
                        .zip(svs.point(j0))
                        .map(|(&xi, &xj)| (h * xi as f64 + (1.0 - h) * xj as f64) as f32)
                        .collect();
                    let mut a_z = scores.a_z[j0];
                    stats.merge_ops += 1;
                    for &jk in &partners[1..] {
                        let (z2, a2, _wd) = golden::merge_pair(
                            &z,
                            a_z,
                            svs.point(jk),
                            svs.alpha(jk),
                            gamma,
                            GS_ITERS,
                        );
                        z = z2;
                        a_z = a2;
                        stats.merge_ops += 1;
                    }
                    (z, a_z)
                }
                MergeExec::GradientDescent => {
                    let pts: Vec<(&[f32], f64)> = merge_points
                        .iter()
                        .map(|(x, a)| (x.as_slice(), *a))
                        .collect();
                    let (z, a_z, _wd) = backend.merge_gd(&pts, gamma);
                    stats.merge_ops += 1;
                    (z, a_z)
                }
            };

            // Exact degradation of the whole event (cascade returns only
            // per-step estimates; the audit value is what Theorem 1 sees).
            let pts: Vec<(&[f32], f64)> =
                merge_points.iter().map(|(x, a)| (x.as_slice(), *a)).collect();
            stats.weight_degradation += exact_multi_wd(&pts, &z, a_z, gamma).max(0.0);

            // Remove merged SVs (descending index keeps indices valid
            // under swap_remove), then insert the merged point.
            let mut to_remove: Vec<usize> =
                std::iter::once(i).chain(partners.iter().copied()).collect();
            to_remove.sort_unstable_by(|a, b| b.cmp(a));
            for j in to_remove {
                svs.swap_remove(j);
            }
            svs.push(&z, a_z);
            stats.removed += merge_points.len() - 1;
        }
        stats
    }

    fn name(&self) -> &'static str {
        match self.exec {
            MergeExec::Cascade => "multimerge-cascade",
            MergeExec::GradientDescent => "multimerge-gd",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn clustered_store(n: usize) -> SvStore {
        // two tight clusters: merges inside a cluster are cheap
        let mut s = SvStore::new(2);
        for i in 0..n {
            let c = if i % 2 == 0 { 0.0f32 } else { 5.0 };
            let eps = (i as f32) * 0.01;
            s.push(&[c + eps, c - eps], 0.2 + 0.01 * i as f64);
        }
        s
    }

    #[test]
    fn m2_reduces_by_one() {
        let mut mm = MultiMerge::new(2, MergeExec::Cascade);
        let mut svs = clustered_store(10);
        let mut be = NativeBackend::new();
        let stats = mm.maintain(&mut svs, 1.0, 9, &mut be);
        assert_eq!(svs.len(), 9);
        assert_eq!(stats.removed, 1);
        assert_eq!(stats.merge_ops, 1);
    }

    #[test]
    fn m5_reduces_by_four() {
        let mut mm = MultiMerge::new(5, MergeExec::Cascade);
        let mut svs = clustered_store(12);
        let mut be = NativeBackend::new();
        let stats = mm.maintain(&mut svs, 1.0, 11, &mut be);
        assert_eq!(svs.len(), 8);
        assert_eq!(stats.removed, 4);
        assert_eq!(stats.merge_ops, 4);
    }

    #[test]
    fn gd_exec_also_enforces() {
        let mut mm = MultiMerge::new(3, MergeExec::GradientDescent);
        let mut svs = clustered_store(9);
        let mut be = NativeBackend::new();
        let stats = mm.maintain(&mut svs, 1.0, 8, &mut be);
        assert_eq!(svs.len(), 7);
        assert_eq!(stats.merge_ops, 1);
        assert!(stats.weight_degradation >= 0.0);
    }

    #[test]
    fn partners_are_nearest_cluster_mates() {
        // The smallest-|α| SV sits in cluster A; its selected partners
        // must come from cluster A, not the far cluster.
        let mut svs = SvStore::new(1);
        svs.push(&[0.00], 0.01); // smallest |α| — candidate
        svs.push(&[0.05], 0.5);
        svs.push(&[0.10], 0.6);
        svs.push(&[9.00], 0.2);
        svs.push(&[9.10], 0.3);
        let mut be = NativeBackend::new();
        let mut mm = MultiMerge::new(3, MergeExec::Cascade);
        let stats = mm.maintain(&mut svs, 1.0, 4, &mut be);
        assert_eq!(svs.len(), 3);
        // far-cluster SVs must be untouched
        let mut far: Vec<f64> = (0..svs.len())
            .filter(|&j| svs.point(j)[0] > 5.0)
            .map(|j| svs.alpha(j))
            .collect();
        far.sort_by(f64::total_cmp);
        assert_eq!(far, vec![0.2, 0.3]);
        assert!(stats.weight_degradation < 0.05, "wd={}", stats.weight_degradation);
    }

    #[test]
    fn merged_coefficient_mass_roughly_preserved() {
        // same-sign tight cluster: α_z ≈ Σα (k ≈ 1 between all points)
        let mut svs = SvStore::new(1);
        for i in 0..4 {
            svs.push(&[0.001 * i as f32], 0.25);
        }
        svs.push(&[100.0], 5.0); // spectator
        let mut be = NativeBackend::new();
        let mut mm = MultiMerge::new(4, MergeExec::Cascade);
        mm.maintain(&mut svs, 1.0, 4, &mut be);
        let total: f64 = svs.alphas_vec().iter().sum();
        assert!((total - 6.0).abs() < 0.01, "mass {total}");
    }

    #[test]
    fn select_partners_orders_by_wd() {
        let mut mm = MultiMerge::new(4, MergeExec::Cascade);
        let wd = vec![0.5, f64::INFINITY, 0.1, 0.9, 0.2];
        let picked = mm.select_partners(&wd, 3);
        assert_eq!(picked, vec![2, 4, 0]);
    }

    #[test]
    fn select_partners_handles_fewer_than_take() {
        let mut mm = MultiMerge::new(4, MergeExec::Cascade);
        let wd = vec![f64::INFINITY, 0.3];
        assert_eq!(mm.select_partners(&wd, 3), vec![1]);
    }

    #[test]
    fn m2_cascade_matches_plain_golden_merge() {
        // With M=2 the event must be exactly a single binary merge of the
        // min-|α| SV with its best partner.  Exact scoring mode: the
        // assertion pins bit-level reuse of the scored (h, a_z), which
        // only the golden-section scorer reproduces.
        let mut svs = SvStore::new(1);
        svs.push(&[0.0], 0.05);
        svs.push(&[0.3], 0.7);
        svs.push(&[2.0], 0.9);
        let x_i = [0.0f32];
        let x_j = [0.3f32];
        let (z_want, a_want, _) = golden::merge_pair(&x_i, 0.05, &x_j, 0.7, 1.0, GS_ITERS);
        let mut be = NativeBackend::exact();
        let mut mm = MultiMerge::new(2, MergeExec::Cascade);
        mm.maintain(&mut svs, 1.0, 2, &mut be);
        // find the merged SV (the one that is neither original survivor)
        let merged: Vec<usize> = (0..svs.len())
            .filter(|&j| svs.point(j)[0] != 2.0)
            .collect();
        assert_eq!(merged.len(), 1);
        let j = merged[0];
        assert!((svs.point(j)[0] - z_want[0]).abs() < 1e-6);
        assert!((svs.alpha(j) - a_want).abs() < 1e-9);
    }
}

//! Removal baseline: drop the SV with the smallest |α|.
//!
//! Wang et al. found this oscillates (the dropped point tends to be
//! re-learned immediately, then dropped again); it is implemented as the
//! baseline the paper contrasts merging against, and for
//! `examples/compare_maintenance.rs`.

use super::{MaintStats, Maintainer};
use crate::model::SvStore;
use crate::runtime::Backend;

pub struct Removal;

impl Maintainer for Removal {
    fn maintain(
        &mut self,
        svs: &mut SvStore,
        _gamma: f64,
        budget: usize,
        _backend: &mut dyn Backend,
    ) -> MaintStats {
        let mut stats = MaintStats::default();
        while svs.len() > budget {
            let i = svs.min_abs_alpha().expect("nonempty");
            // Δ = α_i φ(x_i); ‖φ‖=1 for the Gaussian kernel.
            let a = svs.alpha(i);
            stats.weight_degradation += a * a;
            svs.swap_remove(i);
            stats.removed += 1;
        }
        stats
    }

    fn name(&self) -> &'static str {
        "removal"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn removes_smallest_alpha() {
        let mut svs = SvStore::new(1);
        svs.push(&[0.0], 1.0);
        svs.push(&[1.0], 0.01);
        svs.push(&[2.0], -0.5);
        let mut be = NativeBackend::new();
        let stats = Removal.maintain(&mut svs, 1.0, 2, &mut be);
        assert_eq!(svs.len(), 2);
        assert_eq!(stats.removed, 1);
        assert!((stats.weight_degradation - 0.01f64 * 0.01).abs() < 1e-12);
        // remaining alphas are the two big ones
        let mut alphas = svs.alphas_vec();
        alphas.sort_by(f64::total_cmp);
        assert_eq!(alphas, vec![-0.5, 1.0]);
    }

    #[test]
    fn removes_multiple_if_needed() {
        let mut svs = SvStore::new(1);
        for i in 0..5 {
            svs.push(&[i as f32], (i + 1) as f64 * 0.1);
        }
        let mut be = NativeBackend::new();
        let stats = Removal.maintain(&mut svs, 1.0, 2, &mut be);
        assert_eq!(svs.len(), 2);
        assert_eq!(stats.removed, 3);
        // wd = 0.1² + 0.2² + 0.3²
        assert!((stats.weight_degradation - 0.14).abs() < 1e-9);
    }
}

//! Precomputed golden-section lookup table for binary merge scoring.
//!
//! The companion paper (*Speeding Up Budgeted Stochastic Gradient
//! Descent SVM Training with Precomputed Golden Section Search*, arXiv
//! 1806.10180) observes that the per-pair golden-section search inside
//! merge scoring solves a **two-parameter** family of problems: dividing
//! the objective `g(h) = a_i e^{-c(1-h)²} + a_j e^{-c h²}` by `a_i`
//! shows that the maximizer `h*` depends only on
//!
//! * `c = γ‖x_i − x_j‖²` — the scaled squared distance, and
//! * `r = a_j / a_i`     — the coefficient ratio,
//!
//! so `h*(c, r)` can be tabulated once and merely *interpolated* per
//! candidate pair, collapsing the Θ(B·K·G) scoring pass of
//! [`crate::runtime::NativeBackend::merge_scores`] to Θ(B·K + B): the
//! G = 30 golden-section iterations (≈ 120 `exp` calls per pair) become
//! one bilinear lookup plus three `exp` calls.
//!
//! **Canonical domain.** Swapping the pair maps `h → 1−h` and
//! `r → 1/r`, and flipping both coefficient signs leaves `h` unchanged,
//! so every pair reduces to `|a_i| ≥ |a_j|`, i.e. `r ∈ [−1, 1]`.  On
//! that domain the optimum always lies on the dominant point's branch
//! (`h ∈ [0.5, 1]` for same-sign pairs, `h ∈ [1, 2]` for opposite
//! signs) — searching only that branch at build time keeps the stored
//! surface single-valued and continuous, which plain golden section on
//! the full interval is *not*: past the pitchfork bifurcation at
//! `c = 2, r = 1` the objective is bimodal and golden section lands on
//! either peak, and interpolating across a branch flip would park `h`
//! in the valley between them.
//!
//! **Grid.** The `c`-axis is spaced uniformly in `√c` (the optimum
//! moves fastest near `c = 0`, where the `c → 0` limit
//! `h* = clamp(1/(1+r))` is attached analytically — at `c = 0` exactly
//! the objective is constant in `h` and a numerical search returns
//! noise).  Beyond `c =` [`EXP_NEG_CUTOFF`] the far-pair regime is
//! handled in closed form, so the table never extrapolates; malformed
//! inputs (NaN/∞) fall back to the exact search.
//!
//! Because the objective is flat to first order at its maximum, an
//! `O(Δ²)` interpolation error in `h` costs only `O(Δ⁴)` in `|g|`: at
//! the default 512×256 grid the measured weight-degradation error
//! against the exact search is below `3·10⁻⁷ · (a_i² + a_j²)`
//! (EXPERIMENTS.md §Perf).

use super::golden::{self, PairMerge, GS_ITERS};
use crate::kernel::EXP_NEG_CUTOFF;
use std::sync::OnceLock;

/// Which scorer [`crate::runtime::NativeBackend::merge_scores`] runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeScoreMode {
    /// Per-pair golden-section search (G = 30) — the golden reference.
    Exact,
    /// Precomputed `h*(c, r)` table with bilinear interpolation.
    #[default]
    Lut,
}

impl MergeScoreMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "exact" => Some(Self::Exact),
            "lut" => Some(Self::Lut),
            _ => None,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Lut => "lut",
        }
    }
}

/// Default `c`-axis resolution (cells, not nodes).
pub const DEFAULT_C_STEPS: usize = 512;
/// Default `r`-axis resolution (cells, not nodes).
pub const DEFAULT_R_STEPS: usize = 256;
/// Golden-section iterations used to compute table nodes (more than the
/// runtime G: node cost is paid once, interpolation error dominates).
pub const BUILD_ITERS: usize = 48;

/// The precomputed `h*(c, r)` surface.
pub struct MergeLut {
    c_steps: usize,
    r_steps: usize,
    /// √c of the last column (= √[`EXP_NEG_CUTOFF`]).
    s_max: f64,
    /// Row-major `(c_steps+1) × (r_steps+1)` node values of `h*`.
    h: Vec<f64>,
}

static GLOBAL_LUT: OnceLock<MergeLut> = OnceLock::new();

impl MergeLut {
    /// Build a table with the given resolution.  One-time cost of
    /// `(c_steps+1)·(r_steps+1)` golden-section searches (~tens of ms at
    /// the default resolution in release builds).
    pub fn new(c_steps: usize, r_steps: usize) -> Self {
        assert!(c_steps >= 2 && r_steps >= 2, "degenerate LUT grid");
        let s_max = EXP_NEG_CUTOFF.sqrt();
        let mut h = Vec::with_capacity((c_steps + 1) * (r_steps + 1));
        for ic in 0..=c_steps {
            let s = s_max * ic as f64 / c_steps as f64;
            let c = s * s;
            for ir in 0..=r_steps {
                let r = -1.0 + 2.0 * ir as f64 / r_steps as f64;
                h.push(Self::node(c, r));
            }
        }
        Self { c_steps, r_steps, s_max, h }
    }

    /// The process-wide table at default resolution, built on first use.
    ///
    /// Node construction runs [`golden::merge_objective`], which is
    /// `exp_mode`-aware — so the table reflects whatever exponent path
    /// is active at *first use*.  That is by design: `exp_mode` is a
    /// process-startup knob (the CLI applies it before any scoring),
    /// the two tables differ by ≤ the substrate's 1e-6 exp bound (far
    /// below the interpolation tolerance), and within a process every
    /// comparison sees one consistent table.  Vector-mode tables are
    /// additionally identical across ISAs — the polynomial is
    /// ISA-independent — so vector-mode runs reproduce bit-identically
    /// on heterogeneous fleets.
    pub fn global() -> &'static MergeLut {
        GLOBAL_LUT.get_or_init(|| MergeLut::new(DEFAULT_C_STEPS, DEFAULT_R_STEPS))
    }

    /// Canonical-domain node value: `argmax_h |e^{-c(1-h)²} + r e^{-ch²}|`
    /// restricted to the dominant branch.
    fn node(c: f64, r: f64) -> f64 {
        if c <= 0.0 {
            // Analytic c → 0 limit: maximize (1+r) − c[(1−h)² + r h²] ⇒
            // h = 1/(1+r), clamped to the search interval (r → −1 sends
            // it to +∞; the branch endpoint 2 is the restricted optimum).
            return if 1.0 + r <= 0.5 { 2.0 } else { (1.0 / (1.0 + r)).min(2.0) };
        }
        if r >= 0.0 {
            golden::golden_max(0.5, 1.0, 1.0, r, c, BUILD_ITERS).0
        } else {
            golden::golden_max(1.0, 2.0, 1.0, r, c, BUILD_ITERS).0
        }
    }

    /// Bilinearly interpolated `h*` on the canonical domain
    /// (`c ∈ [0, EXP_NEG_CUTOFF]`, `r ∈ [−1, 1]`; arguments are clamped).
    #[inline]
    pub fn lookup_h(&self, c: f64, r: f64) -> f64 {
        let stride = self.r_steps + 1;
        let s = c.max(0.0).sqrt();
        let fc = (s / self.s_max * self.c_steps as f64)
            .clamp(0.0, self.c_steps as f64 - 1e-9);
        let fr = ((r + 1.0) * 0.5 * self.r_steps as f64)
            .clamp(0.0, self.r_steps as f64 - 1e-9);
        let (ic, ir) = (fc as usize, fr as usize);
        let (tc, tr) = (fc - ic as f64, fr - ir as f64);
        let base = ic * stride + ir;
        let h00 = self.h[base];
        let h01 = self.h[base + 1];
        let h10 = self.h[base + stride];
        let h11 = self.h[base + stride + 1];
        (1.0 - tc) * ((1.0 - tr) * h00 + tr * h01) + tc * ((1.0 - tr) * h10 + tr * h11)
    }

    /// LUT-accelerated drop-in for [`golden::merge_pair_params`]:
    /// table-interpolated `h`, then the merged coefficient and weight
    /// degradation evaluated exactly at that `h` (3 `exp` calls total).
    pub fn merge_pair_params(&self, a_i: f64, a_j: f64, c: f64) -> PairMerge {
        if !(c >= 0.0 && c.is_finite() && a_i.is_finite() && a_j.is_finite()) {
            // Outside the table's domain — exact-search fallback.
            return golden::merge_pair_params(a_i, a_j, c, GS_ITERS);
        }
        if c > EXP_NEG_CUTOFF {
            return golden::far_pair_merge(a_i, a_j);
        }
        let swap = a_j.abs() > a_i.abs();
        let (dom, sub) = if swap { (a_j, a_i) } else { (a_i, a_j) };
        if dom == 0.0 {
            // Both coefficients are exactly zero: any merge is free.
            return PairMerge { h: 0.5, a_z: 0.0, wd: 0.0 };
        }
        let hc = self.lookup_h(c, sub / dom);
        let h = if swap { 1.0 - hc } else { hc };
        let a_z = golden::merge_objective(h, a_i, a_j, c);
        let k_ij = crate::kernel::simd::exp_neg(c);
        let wd = (a_i * a_i + a_j * a_j + 2.0 * a_i * a_j * k_ij - a_z * a_z).max(0.0);
        PairMerge { h, a_z, wd }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn lut() -> &'static MergeLut {
        MergeLut::global()
    }

    #[test]
    fn far_pair_matches_exact() {
        let a = lut().merge_pair_params(0.2, -0.9, 500.0);
        let b = golden::merge_pair_params(0.2, -0.9, 500.0, GS_ITERS);
        assert_eq!(a.h, b.h);
        assert_eq!(a.a_z, b.a_z);
        assert_eq!(a.wd, b.wd);
    }

    #[test]
    fn nan_c_falls_back_to_exact() {
        let a = lut().merge_pair_params(0.5, 0.5, f64::NAN);
        let b = golden::merge_pair_params(0.5, 0.5, f64::NAN, GS_ITERS);
        assert_eq!(a.h.to_bits(), b.h.to_bits());
    }

    #[test]
    fn zero_pair_is_free() {
        let pm = lut().merge_pair_params(0.0, 0.0, 1.0);
        assert_eq!(pm.wd, 0.0);
        assert_eq!(pm.a_z, 0.0);
    }

    #[test]
    fn swap_symmetry() {
        for &(a, b, c) in &[(0.9, 0.2, 1.5), (0.3, -0.8, 4.0), (-1.1, 0.4, 0.2)] {
            let ij = lut().merge_pair_params(a, b, c);
            let ji = lut().merge_pair_params(b, a, c);
            assert!((ij.h - (1.0 - ji.h)).abs() < 1e-12, "h {} vs {}", ij.h, ji.h);
            assert!((ij.wd - ji.wd).abs() < 1e-12);
            assert!((ij.a_z - ji.a_z).abs() < 1e-12);
        }
    }

    #[test]
    fn identical_points_merge_exactly() {
        // c = 0: same-sign coefficients add, wd = 0.
        let pm = lut().merge_pair_params(0.7, 0.3, 0.0);
        assert!((pm.a_z - 1.0).abs() < 1e-9);
        assert!(pm.wd.abs() < 1e-9);
    }

    #[test]
    fn sweep_matches_exact_search() {
        // The tentpole invariant: LUT scoring reproduces the exact
        // golden-section scorer within interpolation tolerance across
        // the whole (a_i, a_j, c) domain.
        let mut rng = Xoshiro256::new(0xA11CE);
        for _ in 0..4000 {
            let a_i = (rng.next_f64() - 0.5) * 3.0;
            let a_j = (rng.next_f64() - 0.5) * 3.0;
            if a_i.abs() < 1e-6 || a_j.abs() < 1e-6 {
                continue;
            }
            let c = rng.next_f64() * (EXP_NEG_CUTOFF - 1e-6) + 1e-6;
            let ex = golden::merge_pair_params(a_i, a_j, c, GS_ITERS);
            let lu = lut().merge_pair_params(a_i, a_j, c);
            let norm2 = a_i * a_i + a_j * a_j;
            assert!(
                (lu.wd - ex.wd).abs() <= 1e-4 * norm2 + 1e-9,
                "wd {} vs exact {} at (a_i={a_i}, a_j={a_j}, c={c})",
                lu.wd,
                ex.wd
            );
            assert!(
                (lu.a_z.abs() - ex.a_z.abs()).abs() <= 1e-4 * norm2.sqrt() + 1e-9,
                "a_z {} vs exact {} at (a_i={a_i}, a_j={a_j}, c={c})",
                lu.a_z,
                ex.a_z
            );
            assert!(
                (lu.h - ex.h).abs() <= 0.05,
                "h {} vs exact {} at (a_i={a_i}, a_j={a_j}, c={c})",
                lu.h,
                ex.h
            );
        }
    }

    #[test]
    fn lut_never_materially_worse_than_exact() {
        // wd is one-sided: a suboptimal h can only increase it, and the
        // interpolation bound keeps the increase negligible.
        let mut rng = Xoshiro256::new(0xBEEF);
        for _ in 0..2000 {
            let a_i = (rng.next_f64() - 0.5) * 2.0;
            let a_j = (rng.next_f64() - 0.5) * 2.0;
            if a_i.abs() < 1e-6 || a_j.abs() < 1e-6 {
                continue;
            }
            let c = rng.next_f64() * 39.0 + 0.01;
            let ex = golden::merge_pair_params(a_i, a_j, c, GS_ITERS);
            let lu = lut().merge_pair_params(a_i, a_j, c);
            assert!(
                lu.wd <= ex.wd + 1e-4 * (a_i * a_i + a_j * a_j) + 1e-9,
                "lut wd {} way above exact {}",
                lu.wd,
                ex.wd
            );
        }
    }

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(MergeScoreMode::parse("lut"), Some(MergeScoreMode::Lut));
        assert_eq!(MergeScoreMode::parse("exact"), Some(MergeScoreMode::Exact));
        assert_eq!(MergeScoreMode::parse("bogus"), None);
        for m in [MergeScoreMode::Exact, MergeScoreMode::Lut] {
            assert_eq!(MergeScoreMode::parse(m.describe()), Some(m));
        }
    }
}

//! Projection baseline (Wang et al. 2012 §4.2): remove the smallest-|α|
//! SV and project its feature-space term onto the span of the survivors.
//!
//! Solve `K δ = α_r k_r` where `K` is the survivors' Gram matrix and
//! `k_r` the removed point's kernel column; add δ to the survivors'
//! coefficients.  The weight degradation is
//! `‖Δ‖² = α_r² (k_rr − k_rᵀ K⁻¹ k_r) = α_r² (1 − k_rᵀ δ/α_r)`.
//!
//! O(B³) per event — exactly why the paper dismisses it for large B; the
//! ablation bench (`rust/benches/hot_paths.rs`) shows the crossover.

use super::{MaintStats, Maintainer};
use crate::kernel::{Gaussian, Kernel};
use crate::linalg::Cholesky;
use crate::model::SvStore;
use crate::runtime::Backend;

pub struct Projection {
    /// Diagonal jitter for near-singular Gram matrices.
    pub jitter: f64,
}

impl Default for Projection {
    fn default() -> Self {
        Self { jitter: 1e-8 }
    }
}

impl Maintainer for Projection {
    fn maintain(
        &mut self,
        svs: &mut SvStore,
        gamma: f64,
        budget: usize,
        _backend: &mut dyn Backend,
    ) -> MaintStats {
        let kern = Gaussian::new(gamma);
        let mut stats = MaintStats::default();
        while svs.len() > budget {
            let r = svs.min_abs_alpha().expect("nonempty");
            let a_r = svs.alpha(r);
            let x_r = svs.point(r).to_vec();
            svs.swap_remove(r);
            stats.removed += 1;
            let b = svs.len();
            if b == 0 {
                stats.weight_degradation += a_r * a_r;
                continue;
            }
            // Gram matrix of survivors + rhs.
            let mut gram = vec![0.0f64; b * b];
            for i in 0..b {
                gram[i * b + i] = 1.0;
                for j in (i + 1)..b {
                    let k = kern.eval(svs.point(i), svs.point(j));
                    gram[i * b + j] = k;
                    gram[j * b + i] = k;
                }
            }
            let k_r: Vec<f64> = (0..b).map(|j| kern.eval(svs.point(j), &x_r)).collect();
            let rhs: Vec<f64> = k_r.iter().map(|&k| a_r * k).collect();
            match Cholesky::factor(&gram, b, self.jitter) {
                Ok(ch) => {
                    let delta = ch.solve(&rhs);
                    for (j, &d) in delta.iter().enumerate() {
                        svs.add_alpha(j, d);
                    }
                    // ‖Δ‖² = α_r² − k_rᵀ δ · α_r  (exact for jitter → 0)
                    let proj: f64 = k_r.iter().zip(&delta).map(|(&k, &d)| k * d).sum();
                    stats.weight_degradation += (a_r * a_r - a_r * proj).max(0.0);
                }
                Err(_) => {
                    // Degenerate Gram: fall back to plain removal.
                    stats.weight_degradation += a_r * a_r;
                }
            }
        }
        stats
    }

    fn name(&self) -> &'static str {
        "projection"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    #[test]
    fn projection_onto_duplicate_is_lossless() {
        // The removed point coincides with a survivor: projection must
        // absorb its coefficient exactly (wd ≈ 0).
        let mut svs = SvStore::new(1);
        svs.push(&[0.0], 1.0);
        svs.push(&[5.0], 0.8);
        svs.push(&[0.0], 0.3); // duplicate of SV 0, smallest |α|... no: 0.3 < 0.8 < 1.0
        let mut be = NativeBackend::new();
        let stats = Projection::default().maintain(&mut svs, 1.0, 2, &mut be);
        assert_eq!(svs.len(), 2);
        assert!(stats.weight_degradation < 1e-6, "wd={}", stats.weight_degradation);
        // total coefficient mass at x=0 is preserved
        let total: f64 = (0..2)
            .filter(|&j| svs.point(j)[0] == 0.0)
            .map(|j| svs.alpha(j))
            .sum();
        assert!((total - 1.3).abs() < 1e-6);
    }

    #[test]
    fn projection_beats_removal_on_wd() {
        let mut svs_p = SvStore::new(1);
        let mut svs_r = SvStore::new(1);
        for (x, a) in [(0.0, 0.9), (0.4, 0.1), (1.0, 0.8)] {
            svs_p.push(&[x as f32], a);
            svs_r.push(&[x as f32], a);
        }
        let mut be = NativeBackend::new();
        let wd_p = Projection::default()
            .maintain(&mut svs_p, 1.0, 2, &mut be)
            .weight_degradation;
        let wd_r = super::super::Removal
            .maintain(&mut svs_r, 1.0, 2, &mut be)
            .weight_degradation;
        assert!(wd_p < wd_r, "projection {wd_p} should beat removal {wd_r}");
    }

    #[test]
    fn empty_survivor_set_falls_back() {
        let mut svs = SvStore::new(1);
        svs.push(&[1.0], 0.5);
        let mut be = NativeBackend::new();
        // budget 0 is not allowed by Budget::new, but the maintainer
        // itself handles it gracefully
        let stats = Projection::default().maintain(&mut svs, 1.0, 0, &mut be);
        assert_eq!(svs.len(), 0);
        assert!((stats.weight_degradation - 0.25).abs() < 1e-12);
    }
}

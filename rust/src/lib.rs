//! # mmbsgd — Multi-Merge Budget Maintenance for SGD SVM Training
//!
//! A production-grade reproduction of Qaadan & Glasmachers, *Multi-Merge
//! Budget Maintenance for Stochastic Gradient Descent SVM Training*
//! (cs.LG 2018), built as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator: the BSGD solver with
//!   pluggable budget maintenance (removal / projection / binary merge /
//!   multi-merge cascade / MM-GD), data pipeline, SMO reference solver,
//!   experiment harness regenerating every table and figure of the paper.
//! * **L2 (python/compile/model.py)** — fixed-shape jax entry points
//!   (margins, merge scoring, MM-GD) lowered once to HLO-text artifacts.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the masked RBF
//!   margin matvec and the vectorized golden-section merge scorer (the
//!   paper's Θ(B·K·G) bottleneck).
//!
//! Python never runs at training time: the [`runtime`] module loads the
//! AOT artifacts through PJRT (`xla` crate) and the coordinator calls
//! them from the hot path; [`runtime::NativeBackend`] is a pure-rust
//! mirror used for tests, tiny problems, and perf baselines.
//!
//! ## Quickstart
//!
//! ```no_run
//! use mmbsgd::prelude::*;
//!
//! let ds = mmbsgd::data::synth::dataset(&SynthSpec::adult_like(1.0), 1);
//! let cfg = TrainConfig {
//!     lambda: 1.0 / (32.0 * ds.train.len() as f64),
//!     gamma: 0.008,
//!     budget: 256,
//!     mergees: 4, // M: merge 4 SVs into 1 per maintenance event
//!     epochs: 1,
//!     ..TrainConfig::default()
//! };
//! let out = bsgd::train(&ds.train, &cfg).expect("valid config + data");
//! let acc = out.model.accuracy(&ds.test);
//! println!("test accuracy {:.2}%", 100.0 * acc);
//! ```
//!
//! For streaming ingestion, checkpoint/resume, and long-running jobs,
//! use [`solver::session::TrainSession`]; for deployment-side batched
//! inference, [`serve::Predictor`].  Both return typed
//! [`error::TrainError`]s instead of panicking on user input.
//!
//! For live traffic, the [`serve`] subsystem scales the same model up
//! to a long-lived server: [`serve::ModelRegistry`] holds many named,
//! versioned models over one shared backend with deterministic
//! weighted A/B routing, [`serve::BatchEngine`] coalesces single-query
//! requests into tiled margins passes with explicit load shedding, and
//! `mmbsgd serve` speaks a newline-delimited TCP protocol over both
//! (every request-path failure is a typed [`error::ServeError`]).
//!
//! Beyond one process, the [`fleet`] subsystem replicates serving:
//! `mmbsgd package` wraps a trained model into a self-verifying
//! versioned artifact ([`fleet::Artifact`]), `mmbsgd fleet push`
//! distributes and activates it across replica servers (each keeping
//! its previous generation as an on-disk last-good for `rollback`),
//! and `mmbsgd fleet route` fronts the replicas with a
//! consistent-hash router ([`fleet::Ring`]) that reroutes around dead
//! replicas without disturbing the surviving key assignments.

pub mod budget;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod exp;
pub mod fleet;
pub mod kernel;
pub mod linalg;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod telemetry;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::budget::{Budget, MaintenanceKind, MergeScoreMode};
    pub use crate::config::TrainConfig;
    pub use crate::data::synth::SynthSpec;
    pub use crate::data::{Dataset, DenseMatrix, Split};
    pub use crate::error::{ServeError, TrainError};
    pub use crate::kernel::Gaussian;
    pub use crate::model::SvmModel;
    pub use crate::rng::Xoshiro256;
    pub use crate::runtime::{Backend, NativeBackend};
    pub use crate::serve::{BatchEngine, ModelRegistry, Predictor, RouteSpec, ShedPolicy};
    pub use crate::solver::bsgd;
    pub use crate::solver::{Checkpoint, TrainSession};
}

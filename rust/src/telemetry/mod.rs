//! First-class serving telemetry: named counters, gauges, and
//! log-spaced latency histograms behind one [`Registry`].
//!
//! The serving layer used to keep its observability in three ad-hoc
//! places — `ProtoStats` atomics, `BatchEngine` totals, and the drift
//! `Monitor` — all funneled into a hand-rolled `stats` line.  This
//! module is the one surface they now publish to, and what the HTTP
//! front end's `GET /metrics` renders:
//!
//! * [`Counter`] — monotone `u64` event counts (`fetch_add` relaxed;
//!   incrementing is one uncontended atomic RMW, no lock).
//! * [`Gauge`] — a point-in-time `f64` stored as bits in an atomic.
//! * [`Histogram`] — fixed log-spaced buckets shared by **every**
//!   histogram in the process (see [`bucket_bounds`]): `observe` is a
//!   binary search plus three relaxed `fetch_add`s, and p50/p90/p99
//!   come from a rank walk over the bucket counts with linear
//!   interpolation inside the landing bucket, so quantile error is
//!   bounded by the ~25% bucket width (measured ≤ 4% on
//!   latency-shaped samples).
//!
//! [`Registry::render`] emits a line-oriented text exposition format
//! (versioned header, `counter|gauge|histogram|bucket` records) that
//! [`Snapshot::parse`] reads back losslessly — the golden test
//! round-trips a scrape — and [`Snapshot::merge`] combines scrapes
//! from many processes (fleet replicas) by element-wise addition.
//!
//! Everything is std-only; handles are `Arc`s so the hot path never
//! touches the registry's name map after startup.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// First line of the text exposition format; bumped on layout changes
/// so scrapers fail loudly instead of misparsing.
pub const EXPOSITION_HEADER: &str = "# mmbsgd-metrics-v1";

/// Snapshot bucket key for the open-ended overflow bucket (rendered
/// as `inf`); real bounds never reach it (see [`bucket_bounds`]).
pub const OVERFLOW: u64 = u64::MAX;

/// Monotone event counter.  All orderings are `Relaxed`: counters
/// synchronize nothing, they only have to end up right.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite with an externally owned monotone total.  Mirror
    /// mode: `BatchEngine` owns its stats as plain fields on the
    /// engine thread; the serve loop republishes them here after each
    /// burst rather than double-counting at every site.
    pub fn set_total(&self, total: u64) {
        self.0.store(total, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time `f64` value (queue depth, window accuracy, …) stored
/// as raw bits in an atomic so readers never see a torn write.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Replace the value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// The global histogram bucket upper bounds, computed once by the
/// integer recurrence `b[i+1] = max(b[i] + 1, b[i] * 5 / 4)` from 1:
/// unit steps through the single digits, then geometric with ratio
/// ≤ 1.25 (so ~25% relative bucket width) — 192 bounds up to ~4.5e18,
/// plus the open overflow bucket.  Pure integer math, so every
/// process on every platform builds the identical table; the
/// merge-of-snapshots and exposition golden tests rely on that.
pub fn bucket_bounds() -> &'static [u64] {
    static BOUNDS: OnceLock<Vec<u64>> = OnceLock::new();
    BOUNDS.get_or_init(|| {
        let mut v = vec![1u64];
        loop {
            let b = *v.last().expect("non-empty");
            if b > u64::MAX / 5 {
                break;
            }
            v.push((b + 1).max(b * 5 / 4));
        }
        v
    })
}

/// Fixed-bucket log-spaced histogram (shared bounds, see
/// [`bucket_bounds`]).  `observe` is lock-free; snapshots and
/// quantiles read the atomics without stopping writers, so a scrape
/// taken mid-burst is a consistent-enough point-in-time view (counts
/// can trail `count` by in-flight increments, never corrupt).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    /// A fresh all-zero histogram over the global bounds.
    pub fn new() -> Self {
        let slots = bucket_bounds().len() + 1;
        Self {
            buckets: (0..slots).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value (bucket `i` holds `v ≤ bounds[i]`, the last
    /// slot everything beyond the final bound).
    pub fn observe(&self, v: u64) {
        let idx = bucket_bounds().partition_point(|&b| b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a [`std::time::Duration`] in nanoseconds (saturating —
    /// a 585-year request is off the chart anyway).
    pub fn observe_duration(&self, d: std::time::Duration) {
        self.observe(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observed values (wrapping at `u64::MAX`).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Point-in-time copy (only non-empty buckets are materialized).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let bounds = bucket_bounds();
        let mut buckets = BTreeMap::new();
        for (i, slot) in self.buckets.iter().enumerate() {
            let c = slot.load(Ordering::Relaxed);
            if c > 0 {
                buckets.insert(bounds.get(i).copied().unwrap_or(OVERFLOW), c);
            }
        }
        HistogramSnapshot { count: self.count(), sum: self.sum(), buckets }
    }

    /// Estimate the `q`-quantile (see [`HistogramSnapshot::quantile`]).
    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Plain-data copy of one histogram: bucket upper bound → count
/// ([`OVERFLOW`] keys the open bucket), plus totals.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistogramSnapshot {
    /// Observations recorded.
    pub count: u64,
    /// Sum of observed values.
    pub sum: u64,
    /// Non-empty buckets only, keyed by upper bound.
    pub buckets: BTreeMap<u64, u64>,
}

impl HistogramSnapshot {
    /// Estimate the `q`-quantile (`q` clamped to `[0, 1]`) by rank
    /// walk: find the bucket holding the `⌈q·count⌉`-th observation
    /// and interpolate linearly inside it.  Error is bounded by the
    /// bucket's relative width (~25%); the overflow bucket clamps to
    /// the last finite bound.  Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let bounds = bucket_bounds();
        let mut cum = 0u64;
        for (&hi, &c) in &self.buckets {
            if cum + c >= target {
                if hi == OVERFLOW {
                    return *bounds.last().expect("non-empty bounds");
                }
                let i = bounds.partition_point(|&b| b < hi);
                let lo = if i == 0 { 0 } else { bounds[i - 1] + 1 };
                let into = (target - cum) as f64 / c as f64;
                return lo + ((hi - lo) as f64 * into).round() as u64;
            }
            cum += c;
        }
        *bounds.last().expect("non-empty bounds")
    }

    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// The one metrics surface: named metric handles, registered once and
/// then updated lock-free through their `Arc`s.  Registration
/// get-or-creates, so two subsystems naming the same counter share it.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.' | '/' | ':'))
}

impl Registry {
    /// A fresh shared registry.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Get-or-register the named counter.  Names are compile-time
    /// constants in this codebase, so an invalid one is a programmer
    /// error (panics; whitespace would corrupt the exposition format).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        assert!(valid_name(name), "invalid metric name {name:?}");
        let mut m = self.counters.lock().expect("telemetry registry lock");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-register the named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        assert!(valid_name(name), "invalid metric name {name:?}");
        let mut m = self.gauges.lock().expect("telemetry registry lock");
        Arc::clone(m.entry(name.to_string()).or_default())
    }

    /// Get-or-register the named histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        assert!(valid_name(name), "invalid metric name {name:?}");
        let mut m = self.histograms.lock().expect("telemetry registry lock");
        Arc::clone(m.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())))
    }

    /// Point-in-time copy of every metric.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .lock()
            .expect("telemetry registry lock")
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .expect("telemetry registry lock")
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .expect("telemetry registry lock")
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        Snapshot { counters, gauges, histograms }
    }

    /// Render the text exposition format (what `GET /metrics` serves).
    pub fn render(&self) -> String {
        self.snapshot().render()
    }
}

/// Plain-data copy of a whole registry; the parse target of the
/// exposition format and the unit of cross-process merging.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    /// Counter name → value.
    pub counters: BTreeMap<String, u64>,
    /// Gauge name → value.
    pub gauges: BTreeMap<String, f64>,
    /// Histogram name → buckets and totals.
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl Snapshot {
    /// Render the versioned text exposition format:
    ///
    /// ```text
    /// # mmbsgd-metrics-v1
    /// counter <name> <u64>
    /// gauge <name> <f64>
    /// histogram <name> count <u64> sum <u64>
    /// bucket <name> <upper-bound|inf> <u64>
    /// ```
    ///
    /// Gauges print with Rust's shortest round-trip `f64` formatting
    /// and only non-empty buckets are listed, so
    /// [`Snapshot::parse`]`(render())` reproduces the snapshot
    /// exactly (pinned by the golden test).
    pub fn render(&self) -> String {
        let mut out = String::from(EXPOSITION_HEADER);
        out.push('\n');
        for (name, v) in &self.counters {
            out.push_str(&format!("counter {name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("gauge {name} {v}\n"));
        }
        for (name, h) in &self.histograms {
            out.push_str(&format!("histogram {name} count {} sum {}\n", h.count, h.sum));
            for (&b, &c) in &h.buckets {
                if b == OVERFLOW {
                    out.push_str(&format!("bucket {name} inf {c}\n"));
                } else {
                    out.push_str(&format!("bucket {name} {b} {c}\n"));
                }
            }
        }
        out
    }

    /// Parse a scrape back into a snapshot (inverse of
    /// [`Snapshot::render`]; extra `#` comment lines and blank lines
    /// are tolerated, anything else malformed is a typed error).
    pub fn parse(text: &str) -> Result<Snapshot, String> {
        let mut lines = text.lines();
        match lines.next() {
            Some(h) if h.trim() == EXPOSITION_HEADER => {}
            other => return Err(format!("bad exposition header {other:?}")),
        }
        let mut snap = Snapshot::default();
        for line in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let fail = || format!("malformed metrics line {line:?}");
            match toks.as_slice() {
                ["counter", name, v] => {
                    let v: u64 = v.parse().map_err(|_| fail())?;
                    snap.counters.insert(name.to_string(), v);
                }
                ["gauge", name, v] => {
                    let v: f64 = v.parse().map_err(|_| fail())?;
                    snap.gauges.insert(name.to_string(), v);
                }
                ["histogram", name, "count", c, "sum", s] => {
                    let h = snap.histograms.entry(name.to_string()).or_default();
                    h.count = c.parse().map_err(|_| fail())?;
                    h.sum = s.parse().map_err(|_| fail())?;
                }
                ["bucket", name, bound, c] => {
                    let b = if *bound == "inf" {
                        OVERFLOW
                    } else {
                        bound.parse().map_err(|_| fail())?
                    };
                    let c: u64 = c.parse().map_err(|_| fail())?;
                    snap.histograms.entry(name.to_string()).or_default().buckets.insert(b, c);
                }
                _ => return Err(fail()),
            }
        }
        Ok(snap)
    }

    /// Merge another snapshot in: counters and histogram buckets add
    /// element-wise (cross-replica totals), gauges take `other`'s
    /// value (a merged point-in-time reading has no meaningful sum).
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            let e = self.histograms.entry(k.clone()).or_default();
            e.count += h.count;
            e.sum = e.sum.wrapping_add(h.sum);
            for (&b, &c) in &h.buckets {
                *e.buckets.entry(b).or_insert(0) += c;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn bucket_bounds_are_log_spaced_and_deterministic() {
        let b = bucket_bounds();
        assert_eq!(&b[..12], &[1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 15, 18]);
        for w in b.windows(2) {
            assert!(w[1] > w[0], "bounds must be strictly increasing");
            // relative width never exceeds the 25% design ratio (+1
            // for the integer unit steps at the bottom)
            assert!(w[1] - w[0] <= w[0] / 4 + 1, "bucket too wide at {w:?}");
        }
        assert!(b.len() > 150 && b.len() < 256, "unexpected table size {}", b.len());
        assert!(*b.last().unwrap() > u64::MAX / 5, "table must cover the u64 range");
    }

    #[test]
    fn observe_places_boundaries_exactly() {
        let h = Histogram::new();
        // bucket i holds v <= bounds[i]: 1 and 2 land in different
        // buckets, 9 and 10 share the (8, 10] bucket
        h.observe(1);
        h.observe(2);
        h.observe(9);
        h.observe(10);
        h.observe(u64::MAX); // overflow bucket
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.buckets.get(&1), Some(&1));
        assert_eq!(s.buckets.get(&2), Some(&1));
        assert_eq!(s.buckets.get(&10), Some(&2));
        assert_eq!(s.buckets.get(&OVERFLOW), Some(&1));
        let want_sum =
            1u64.wrapping_add(2).wrapping_add(9).wrapping_add(10).wrapping_add(u64::MAX);
        assert_eq!(s.sum, want_sum);
    }

    #[test]
    fn quantiles_track_exact_sorted_reference() {
        // latency-shaped samples at several scales; the estimator must
        // stay inside one bucket width (25% + 1) of the exact order
        // statistic at every probed quantile
        for (seed, scale) in [(1u64, 100u64), (2, 10_000), (3, 5_000_000)] {
            let mut rng = Xoshiro256::new(seed);
            let h = Histogram::new();
            let mut vals: Vec<u64> = (0..8192)
                .map(|_| {
                    let base = rng.next_u64() % scale;
                    let spike = if rng.next_u64() % 20 == 0 { scale * 8 } else { 0 };
                    base + spike
                })
                .collect();
            for &v in &vals {
                h.observe(v);
            }
            vals.sort_unstable();
            for q in [0.5, 0.9, 0.99] {
                let rank = ((q * vals.len() as f64).ceil() as usize).max(1) - 1;
                let exact = vals[rank] as f64;
                let est = h.quantile(q) as f64;
                assert!(
                    (est - exact).abs() <= exact * 0.25 + 1.0,
                    "seed {seed} q {q}: est {est} vs exact {exact}"
                );
            }
        }
    }

    #[test]
    fn quantile_edge_cases() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram answers 0");
        h.observe(7);
        assert_eq!(h.quantile(0.0), 7);
        assert_eq!(h.quantile(1.0), 7);
        let over = Histogram::new();
        over.observe(u64::MAX);
        assert_eq!(over.quantile(0.5), *bucket_bounds().last().unwrap());
    }

    #[test]
    fn registry_get_or_registers_shared_handles() {
        let r = Registry::new();
        r.counter("a_total").inc();
        r.counter("a_total").add(2);
        assert_eq!(r.counter("a_total").get(), 3);
        r.gauge("g").set(-1.5);
        assert_eq!(r.gauge("g").get(), -1.5);
        r.histogram("h_ns").observe(42);
        assert_eq!(r.histogram("h_ns").count(), 1);
    }

    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn whitespace_names_are_rejected() {
        Registry::new().counter("bad name");
    }

    #[test]
    fn exposition_golden_roundtrip() {
        let r = Registry::new();
        r.counter("serve_requests_total").add(17);
        r.gauge("serve_window_accuracy").set(0.9875);
        r.gauge("serve_queue_depth").set(-1.0);
        let h = r.histogram("serve_http_request_ns");
        for v in [3, 9, 250, 251, 1_000_000, u64::MAX] {
            h.observe(v);
        }
        let text = r.render();
        assert!(text.starts_with(EXPOSITION_HEADER));
        assert!(text.contains("counter serve_requests_total 17"));
        assert!(text.contains("histogram serve_http_request_ns count 6"));
        assert!(text.contains("bucket serve_http_request_ns inf 1"));
        let parsed = Snapshot::parse(&text).expect("scrape parses");
        assert_eq!(parsed, r.snapshot(), "render -> parse must be lossless");
    }

    #[test]
    fn parse_rejects_malformed_scrapes() {
        assert!(Snapshot::parse("").is_err(), "missing header");
        assert!(Snapshot::parse("# wrong-header\n").is_err());
        let hdr = format!("{EXPOSITION_HEADER}\n");
        assert!(Snapshot::parse(&format!("{hdr}counter x notanumber\n")).is_err());
        assert!(Snapshot::parse(&format!("{hdr}frobnicate x 1\n")).is_err());
        assert!(Snapshot::parse(&format!("{hdr}bucket h nan 1\n")).is_err());
        // comments and blank lines are fine
        let ok = Snapshot::parse(&format!("{hdr}\n# note\ncounter x 1\n")).unwrap();
        assert_eq!(ok.counters.get("x"), Some(&1));
    }

    #[test]
    fn merge_adds_counters_and_buckets() {
        let a = Registry::new();
        let b = Registry::new();
        a.counter("reqs").add(5);
        b.counter("reqs").add(7);
        b.counter("only_b").inc();
        a.gauge("acc").set(0.5);
        b.gauge("acc").set(0.75);
        a.histogram("lat").observe(10);
        b.histogram("lat").observe(10);
        b.histogram("lat").observe(1_000);
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged.counters["reqs"], 12);
        assert_eq!(merged.counters["only_b"], 1);
        assert_eq!(merged.gauges["acc"], 0.75, "gauges take the newest reading");
        let h = &merged.histograms["lat"];
        assert_eq!(h.count, 3);
        assert_eq!(h.sum, 1_020);
        assert_eq!(h.buckets.get(&10), Some(&2));
        // a merged snapshot still answers quantiles
        assert!(h.quantile(0.99) >= 800);
    }
}

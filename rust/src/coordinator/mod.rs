//! Training coordinator: job specification, backend construction, a
//! leader/worker pool for experiment grids, progress reporting and
//! metric aggregation.
//!
//! The paper's experiments are *grids* — (dataset × B × M × seed) — of
//! independent training runs.  The coordinator is the leader: it owns
//! the job queue, hands jobs to worker threads over a channel, and
//! aggregates [`RunResult`]s in deterministic job order regardless of
//! completion order.  Each worker builds its own backend (PJRT clients
//! and executable caches are per-worker — no shared mutable state on
//! the hot path).

mod metrics;
mod progress;

pub use metrics::{result_to_json, results_to_json};
pub use progress::ProgressObserver;

use crate::config::{BackendChoice, TrainConfig};
use crate::data::synth::{dataset, SynthSpec};
use crate::data::Split;
use crate::runtime::{Backend, HybridBackend, NativeBackend, XlaBackend};
use crate::solver::bsgd::{self, TrainOutput};
use crate::solver::NoopObserver;
use anyhow::Result;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// One training job.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Job label (shows up in tables/CSV).
    pub name: String,
    /// Synthetic dataset spec (experiments use synth twins; the CLI can
    /// also train on LIBSVM files, bypassing the grid path).
    pub data: SynthSpec,
    pub data_seed: u64,
    pub cfg: TrainConfig,
}

/// Aggregated outcome of one job.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub name: String,
    pub dataset: String,
    pub budget: usize,
    pub mergees: usize,
    pub maintenance: String,
    pub seed: u64,
    pub train_seconds: f64,
    pub merge_fraction: f64,
    pub test_accuracy: f64,
    pub n_svs: usize,
    pub steps: u64,
    pub margin_violations: u64,
    pub maintenance_events: u64,
    pub mean_wd: f64,
}

/// Build the backend named by the config.
pub fn build_backend(choice: BackendChoice) -> Result<Box<dyn Backend>> {
    Ok(match choice {
        BackendChoice::Native => Box::new(NativeBackend::new()),
        BackendChoice::Xla => Box::new(XlaBackend::from_default_dir()?),
        BackendChoice::Hybrid => Box::new(HybridBackend::from_default_dir()?),
    })
}

/// Execute one job end-to-end (generate data, train, evaluate).
pub fn run_one(spec: &RunSpec) -> Result<RunResult> {
    let split = dataset(&spec.data, spec.data_seed);
    run_on_split(spec, &split)
}

/// Execute one job on pre-generated data (grid drivers reuse splits).
pub fn run_on_split(spec: &RunSpec, split: &Split) -> Result<RunResult> {
    let mut cfg = spec.cfg.clone();
    cfg.resolve_c(split.train.len());
    cfg.validate()?;
    let mut backend = build_backend(cfg.backend)?;
    let out: TrainOutput = bsgd::train_full(
        &split.train,
        &cfg,
        backend.as_mut(),
        Some(&split.test),
        &mut NoopObserver,
    )?;
    let test_accuracy = bsgd::evaluate(&out.model, backend.as_mut(), &split.test);
    Ok(RunResult {
        name: spec.name.clone(),
        dataset: spec.data.name.to_string(),
        budget: cfg.budget,
        mergees: cfg.mergees,
        maintenance: cfg.maintenance_kind().describe(),
        seed: cfg.seed,
        train_seconds: out.train_seconds,
        merge_fraction: out.merge_fraction(),
        test_accuracy,
        n_svs: out.model.svs.len(),
        steps: out.steps,
        margin_violations: out.margin_violations,
        maintenance_events: out.maintenance_events,
        mean_wd: out.mean_weight_degradation,
    })
}

/// Run a grid of jobs on `threads` workers; results return in job order.
///
/// NOTE on timing fidelity: wall-clock comparisons across M (the paper's
/// tables) must not be polluted by core contention, so experiment
/// drivers that *time* runs call this with `threads = 1` and reserve
/// parallelism for accuracy-only sweeps.
pub fn run_grid(specs: Vec<RunSpec>, threads: usize) -> Vec<Result<RunResult>> {
    let n = specs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return specs.iter().map(run_one).collect();
    }
    let queue = Arc::new(Mutex::new(specs.into_iter().enumerate().collect::<Vec<_>>()));
    let (tx, rx) = mpsc::channel::<(usize, Result<RunResult>)>();
    let mut handles = Vec::new();
    for _ in 0..threads {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, spec)) => {
                    let res = run_one(&spec);
                    if tx.send((idx, res)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);
    let mut results: Vec<Option<Result<RunResult>>> = (0..n).map(|_| None).collect();
    for (idx, res) in rx {
        results[idx] = Some(res);
    }
    for h in handles {
        let _ = h.join();
    }
    results.into_iter().map(|r| r.expect("worker dropped a job")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(name: &str, m: usize, seed: u64) -> RunSpec {
        RunSpec {
            name: name.into(),
            data: SynthSpec::ijcnn_like(0.01),
            data_seed: 1,
            cfg: TrainConfig {
                lambda: 1e-3,
                gamma: 2.0,
                budget: 24,
                mergees: m,
                seed,
                ..TrainConfig::default()
            },
        }
    }

    #[test]
    fn run_one_produces_sane_result() {
        let r = run_one(&tiny_spec("t", 3, 1)).unwrap();
        assert!(r.test_accuracy > 0.5);
        assert!(r.n_svs <= 24);
        assert!(r.train_seconds > 0.0);
        assert_eq!(r.mergees, 3);
        assert_eq!(r.maintenance, "merge:3");
    }

    #[test]
    fn grid_preserves_job_order() {
        let specs: Vec<RunSpec> =
            (0..6).map(|i| tiny_spec(&format!("job{i}"), 2 + (i % 3), i as u64)).collect();
        let results = run_grid(specs, 3);
        assert_eq!(results.len(), 6);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap().name, format!("job{i}"));
        }
    }

    #[test]
    fn grid_single_thread_equals_parallel() {
        let mk = || (0..4).map(|i| tiny_spec(&format!("j{i}"), 2, 42)).collect::<Vec<_>>();
        let seq = run_grid(mk(), 1);
        let par = run_grid(mk(), 4);
        for (a, b) in seq.iter().zip(&par) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            // deterministic everything except wall-clock
            assert_eq!(a.test_accuracy, b.test_accuracy);
            assert_eq!(a.n_svs, b.n_svs);
            assert_eq!(a.maintenance_events, b.maintenance_events);
        }
    }
}

//! Live progress reporting for interactive `mmbsgd train` runs.

use crate::solver::Observer;
use std::io::Write;
use std::time::Instant;

/// Prints a status line every `every` steps (stderr, overwriting).
pub struct ProgressObserver {
    every: u64,
    started: Instant,
    last_svs: usize,
    events: u64,
    quiet: bool,
}

impl ProgressObserver {
    pub fn new(every: u64) -> Self {
        Self { every: every.max(1), started: Instant::now(), last_svs: 0, events: 0, quiet: false }
    }

    pub fn quiet() -> Self {
        let mut p = Self::new(u64::MAX);
        p.quiet = true;
        p
    }
}

impl Observer for ProgressObserver {
    fn on_step(&mut self, step: u64, n_svs: usize) {
        self.last_svs = n_svs;
        if !self.quiet && step % self.every == 0 {
            let rate = step as f64 / self.started.elapsed().as_secs_f64().max(1e-9);
            eprint!(
                "\r[train] step {step}  svs {n_svs}  maint {}  {:.0} steps/s   ",
                self.events, rate
            );
            let _ = std::io::stderr().flush();
        }
    }

    fn on_maintenance(&mut self, event: u64, _total_wd: f64, _n_svs: usize) {
        self.events = event;
    }

    fn on_eval(&mut self, step: u64, accuracy: f64) {
        if !self.quiet {
            eprintln!("\r[eval ] step {step}  accuracy {:.2}%          ", accuracy * 100.0);
        }
    }

    fn on_epoch(&mut self, epoch: usize) {
        if !self.quiet {
            eprintln!("\r[epoch] {epoch}                                ");
        }
    }
}

//! Metric serialization: run results → JSON for dashboards / plotting.

use super::RunResult;
use crate::util::json::{obj, Json};

pub fn result_to_json(r: &RunResult) -> Json {
    obj(vec![
        ("name", Json::Str(r.name.clone())),
        ("dataset", Json::Str(r.dataset.clone())),
        ("budget", Json::Num(r.budget as f64)),
        ("mergees", Json::Num(r.mergees as f64)),
        ("maintenance", Json::Str(r.maintenance.clone())),
        ("seed", Json::Num(r.seed as f64)),
        ("train_seconds", Json::Num(r.train_seconds)),
        ("merge_fraction", Json::Num(r.merge_fraction)),
        ("test_accuracy", Json::Num(r.test_accuracy)),
        ("n_svs", Json::Num(r.n_svs as f64)),
        ("steps", Json::Num(r.steps as f64)),
        ("margin_violations", Json::Num(r.margin_violations as f64)),
        ("maintenance_events", Json::Num(r.maintenance_events as f64)),
        ("mean_wd", Json::Num(r.mean_wd)),
    ])
}

pub fn results_to_json(rs: &[RunResult]) -> Json {
    Json::Arr(rs.iter().map(result_to_json).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake() -> RunResult {
        RunResult {
            name: "t".into(),
            dataset: "adult".into(),
            budget: 128,
            mergees: 3,
            maintenance: "merge:3".into(),
            seed: 1,
            train_seconds: 1.5,
            merge_fraction: 0.4,
            test_accuracy: 0.83,
            n_svs: 128,
            steps: 1000,
            margin_violations: 700,
            maintenance_events: 200,
            mean_wd: 0.001,
        }
    }

    #[test]
    fn json_roundtrip() {
        let j = result_to_json(&fake());
        let text = crate::util::json::to_string(&j);
        let re = Json::parse(&text).unwrap();
        assert_eq!(re.get("budget").unwrap().as_usize(), Some(128));
        assert_eq!(re.get("maintenance").unwrap().as_str(), Some("merge:3"));
    }

    #[test]
    fn array_serialization() {
        let j = results_to_json(&[fake(), fake()]);
        assert_eq!(j.as_arr().unwrap().len(), 2);
    }
}

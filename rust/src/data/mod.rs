//! Data pipeline: storage, LIBSVM-format I/O, synthetic dataset
//! generators matching the paper's benchmark datasets, scaling and
//! splitting.
//!
//! The paper evaluates on PHISHING, WEB, ADULT, IJCNN and SKIN/NON-SKIN
//! from the LIBSVM repository.  The build image is offline, so
//! [`synth`] provides statistical twins (same n, d, class balance,
//! comparable difficulty) — see DESIGN.md §3 for the substitution
//! argument.  Real LIBSVM files are fully supported through [`libsvm`]
//! whenever the user has them on disk.

pub mod libsvm;
pub mod scale;
pub mod split;
pub mod synth;

/// Dense row-major matrix of `f32` features.
///
/// BSGD's hot loop streams full rows (kernel evaluations touch every
/// feature), so a dense layout with contiguous rows is the right
/// structure even for datasets distributed in sparse format; `d` is at
/// most a few hundred for every workload in the paper.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { data, rows: r, cols: c }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { data, rows, cols }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Select a subset of rows into a new matrix.
    pub fn gather(&self, idx: &[usize]) -> Self {
        let mut out = Self::zeros(0, self.cols);
        out.data.reserve(idx.len() * self.cols);
        for &i in idx {
            out.data.extend_from_slice(self.row(i));
            out.rows += 1;
        }
        out
    }
}

/// A labelled binary-classification sample view.
#[derive(Clone, Copy, Debug)]
pub struct Sample<'a> {
    pub x: &'a [f32],
    pub y: f32, // -1.0 or +1.0
}

/// A labelled dataset: dense features + ±1 labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: DenseMatrix,
    pub y: Vec<f32>,
    /// Human-readable origin tag ("adult-synth", "path/to/file", ...).
    pub name: String,
}

impl Dataset {
    pub fn new(x: DenseMatrix, y: Vec<f32>, name: impl Into<String>) -> Self {
        assert_eq!(x.rows(), y.len(), "feature/label count mismatch");
        for &l in &y {
            assert!(l == 1.0 || l == -1.0, "labels must be ±1, got {l}");
        }
        Self { x, y, name: name.into() }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    pub fn sample(&self, i: usize) -> Sample<'_> {
        Sample { x: self.x.row(i), y: self.y[i] }
    }

    /// Fraction of positive labels.
    pub fn positive_fraction(&self) -> f64 {
        self.y.iter().filter(|&&l| l > 0.0).count() as f64 / self.len().max(1) as f64
    }

    /// Subset by row indices.
    pub fn gather(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.gather(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            name: self.name.clone(),
        }
    }
}

/// A train/test split (paired with the generator/loader that made it).
#[derive(Clone, Debug)]
pub struct Split {
    pub train: Dataset,
    pub test: Dataset,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matrix_row_access() {
        let m = DenseMatrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn gather_selects_rows() {
        let m = DenseMatrix::from_rows(vec![vec![1.0], vec![2.0], vec![3.0]]);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.row(0), &[3.0]);
        assert_eq!(g.row(1), &[1.0]);
    }

    #[test]
    fn push_row_grows() {
        let mut m = DenseMatrix::zeros(0, 3);
        m.push_row(&[1.0, 2.0, 3.0]);
        assert_eq!(m.rows(), 1);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn dataset_rejects_bad_labels() {
        let x = DenseMatrix::zeros(1, 1);
        Dataset::new(x, vec![0.5], "bad");
    }

    #[test]
    fn positive_fraction() {
        let x = DenseMatrix::zeros(4, 1);
        let d = Dataset::new(x, vec![1.0, 1.0, -1.0, 1.0], "t");
        assert!((d.positive_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn ragged_rows_panic() {
        DenseMatrix::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}

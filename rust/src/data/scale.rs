//! Feature scaling.
//!
//! LIBSVM practice (and the paper's datasets as distributed) is features
//! scaled to [-1, 1] or [0, 1].  The RBF bandwidth γ from Table 2 is only
//! meaningful on comparable scales, so the synthetic twins and any
//! user-supplied raw data go through the same scaler.

use super::Dataset;

/// Per-feature affine transform x' = (x - offset) * factor.
#[derive(Clone, Debug)]
pub struct Scaler {
    pub offset: Vec<f32>,
    pub factor: Vec<f32>,
}

impl Scaler {
    /// Fit a [lo, hi] range scaler on the training data.
    pub fn fit_range(ds: &Dataset, lo: f32, hi: f32) -> Self {
        let d = ds.dim();
        let mut min = vec![f32::INFINITY; d];
        let mut max = vec![f32::NEG_INFINITY; d];
        for i in 0..ds.len() {
            for (j, &v) in ds.sample(i).x.iter().enumerate() {
                min[j] = min[j].min(v);
                max[j] = max[j].max(v);
            }
        }
        let mut offset = vec![0.0; d];
        let mut factor = vec![1.0; d];
        for j in 0..d {
            let span = max[j] - min[j];
            if span > 0.0 && span.is_finite() {
                factor[j] = (hi - lo) / span;
                offset[j] = min[j] - lo / factor[j];
            } else {
                // constant feature: map to lo
                factor[j] = 0.0;
                offset[j] = min[j];
            }
        }
        Self { offset, factor }
    }

    /// Fit standardization (zero mean, unit variance).
    pub fn fit_standard(ds: &Dataset) -> Self {
        let d = ds.dim();
        let n = ds.len().max(1) as f64;
        let mut mean = vec![0.0f64; d];
        for i in 0..ds.len() {
            for (j, &v) in ds.sample(i).x.iter().enumerate() {
                mean[j] += v as f64;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0f64; d];
        for i in 0..ds.len() {
            for (j, &v) in ds.sample(i).x.iter().enumerate() {
                let c = v as f64 - mean[j];
                var[j] += c * c;
            }
        }
        let offset = mean.iter().map(|&m| m as f32).collect();
        let factor = var
            .iter()
            .map(|&v| {
                let s = (v / n).sqrt();
                if s > 1e-12 {
                    (1.0 / s) as f32
                } else {
                    0.0
                }
            })
            .collect();
        Self { offset, factor }
    }

    /// Apply in place.
    pub fn apply(&self, ds: &mut Dataset) {
        for i in 0..ds.len() {
            let row = ds.x.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v = (*v - self.offset[j]) * self.factor[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DenseMatrix;

    fn toy() -> Dataset {
        let x = DenseMatrix::from_rows(vec![
            vec![0.0, 10.0, 5.0],
            vec![2.0, 20.0, 5.0],
            vec![4.0, 30.0, 5.0],
        ]);
        Dataset::new(x, vec![1.0, -1.0, 1.0], "t")
    }

    #[test]
    fn range_scaling_hits_bounds() {
        let mut ds = toy();
        let sc = Scaler::fit_range(&ds, -1.0, 1.0);
        sc.apply(&mut ds);
        for j in 0..2 {
            let col: Vec<f32> = (0..3).map(|i| ds.sample(i).x[j]).collect();
            let lo = col.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!((lo + 1.0).abs() < 1e-6 && (hi - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn constant_feature_maps_to_lo_without_nan() {
        let mut ds = toy();
        let sc = Scaler::fit_range(&ds, 0.0, 1.0);
        sc.apply(&mut ds);
        for i in 0..3 {
            assert_eq!(ds.sample(i).x[2], 0.0);
            assert!(ds.sample(i).x.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut ds = toy();
        let sc = Scaler::fit_standard(&ds);
        sc.apply(&mut ds);
        for j in 0..2 {
            let col: Vec<f64> = (0..3).map(|i| ds.sample(i).x[j] as f64).collect();
            let m = col.iter().sum::<f64>() / 3.0;
            let v = col.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / 3.0;
            assert!(m.abs() < 1e-6);
            assert!((v - 1.0).abs() < 1e-5);
        }
    }
}

//! Train/test splitting and cross-validation folds.

use super::{Dataset, Split};
use crate::rng::Xoshiro256;

/// Shuffle indices and carve off `n_test` points for testing.
pub fn train_test(ds: &Dataset, n_test: usize, seed: u64) -> Split {
    assert!(n_test < ds.len(), "test size {n_test} >= dataset {}", ds.len());
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    let mut rng = Xoshiro256::new(seed);
    rng.shuffle(&mut idx);
    let (test_idx, train_idx) = idx.split_at(n_test);
    Split { train: ds.gather(train_idx), test: ds.gather(test_idx) }
}

/// K-fold cross-validation index sets: returns `k` (train, valid) pairs.
pub fn kfold(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n, "bad fold count k={k} for n={n}");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256::new(seed);
    rng.shuffle(&mut idx);
    let mut folds = Vec::with_capacity(k);
    for f in 0..k {
        let lo = f * n / k;
        let hi = (f + 1) * n / k;
        let valid: Vec<usize> = idx[lo..hi].to_vec();
        let train: Vec<usize> =
            idx[..lo].iter().chain(idx[hi..].iter()).copied().collect();
        folds.push((train, valid));
    }
    folds
}

/// Stratified subsample preserving the class balance (used to scale the
/// experiments down while keeping the positive fraction intact).
pub fn stratified_subsample(ds: &Dataset, n: usize, seed: u64) -> Dataset {
    if n >= ds.len() {
        return ds.clone();
    }
    let mut pos: Vec<usize> = (0..ds.len()).filter(|&i| ds.y[i] > 0.0).collect();
    let mut neg: Vec<usize> = (0..ds.len()).filter(|&i| ds.y[i] < 0.0).collect();
    let mut rng = Xoshiro256::new(seed);
    rng.shuffle(&mut pos);
    rng.shuffle(&mut neg);
    let frac = n as f64 / ds.len() as f64;
    let n_pos = ((pos.len() as f64) * frac).round() as usize;
    let n_pos = n_pos.min(n).min(pos.len());
    let n_neg = (n - n_pos).min(neg.len());
    let mut keep: Vec<usize> = pos[..n_pos].to_vec();
    keep.extend_from_slice(&neg[..n_neg]);
    keep.sort_unstable();
    ds.gather(&keep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DenseMatrix;

    fn toy(n: usize) -> Dataset {
        let x = DenseMatrix::from_rows((0..n).map(|i| vec![i as f32]).collect());
        let y = (0..n).map(|i| if i % 4 == 0 { 1.0 } else { -1.0 }).collect();
        Dataset::new(x, y, "toy")
    }

    #[test]
    fn split_partitions() {
        let ds = toy(100);
        let s = train_test(&ds, 25, 1);
        assert_eq!(s.train.len(), 75);
        assert_eq!(s.test.len(), 25);
        // all original feature values present exactly once
        let mut seen: Vec<i64> = s
            .train
            .x
            .as_slice()
            .iter()
            .chain(s.test.x.as_slice())
            .map(|&v| v as i64)
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<i64>>());
    }

    #[test]
    fn kfold_covers_everything() {
        let folds = kfold(103, 5, 7);
        assert_eq!(folds.len(), 5);
        let mut all_valid: Vec<usize> =
            folds.iter().flat_map(|(_, v)| v.clone()).collect();
        all_valid.sort_unstable();
        assert_eq!(all_valid, (0..103).collect::<Vec<usize>>());
        for (tr, va) in &folds {
            assert_eq!(tr.len() + va.len(), 103);
        }
    }

    #[test]
    fn stratified_preserves_balance() {
        let ds = toy(400); // 25% positive
        let sub = stratified_subsample(&ds, 100, 3);
        assert_eq!(sub.len(), 100);
        assert!((sub.positive_fraction() - 0.25).abs() < 0.03);
    }

    #[test]
    fn stratified_noop_when_larger() {
        let ds = toy(10);
        let sub = stratified_subsample(&ds, 50, 3);
        assert_eq!(sub.len(), 10);
    }
}

//! LIBSVM sparse text format reader/writer.
//!
//! Format: one sample per line, `label index:value index:value ...` with
//! 1-based, strictly increasing indices.  This is the distribution format
//! of every dataset in the paper (ADULT = a9a, IJCNN = ijcnn1, ...), so
//! users with the real files can run the experiments on them directly:
//! `mmbsgd train --data path/to/a9a ...`.
//!
//! Labels: any positive number maps to +1, any non-positive to -1
//! (the LIBSVM repo uses {+1,-1}, {1,0} and {1,2} conventions; {1,2}
//! files should be converted by the caller — we map 2 to +1 and warn).

use super::{Dataset, DenseMatrix};
use anyhow::{bail, Context, Result};
use std::fs;
use std::path::Path;

/// Parse LIBSVM text into a dense dataset.
///
/// `dim_hint`: pass `Some(d)` to force the feature dimension (needed when
/// the test split contains higher indices than the train split); `None`
/// infers the maximum index present.
pub fn parse(text: &str, dim_hint: Option<usize>) -> Result<(Vec<Vec<(usize, f32)>>, Vec<f32>, usize)> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels: Vec<f32> = Vec::new();
    let mut max_idx = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let lab: f32 = parts
            .next()
            .with_context(|| format!("line {}: missing label", ln + 1))?
            .parse()
            .with_context(|| format!("line {}: bad label", ln + 1))?;
        if !lab.is_finite() {
            bail!("line {}: non-finite label {lab}", ln + 1);
        }
        let mut feats = Vec::new();
        let mut prev = 0usize;
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .with_context(|| format!("line {}: token '{tok}' lacks ':'", ln + 1))?;
            let idx: usize = i
                .parse()
                .with_context(|| format!("line {}: bad index '{i}'", ln + 1))?;
            if idx == 0 {
                bail!("line {}: LIBSVM indices are 1-based, got 0", ln + 1);
            }
            if idx <= prev {
                bail!("line {}: indices must be strictly increasing", ln + 1);
            }
            prev = idx;
            let val: f32 = v
                .parse()
                .with_context(|| format!("line {}: bad value '{v}'", ln + 1))?;
            // NaN/±inf would silently poison every kernel evaluation
            // downstream; reject with the position instead.
            if !val.is_finite() {
                bail!("line {}: non-finite value '{v}' at feature index {idx}", ln + 1);
            }
            max_idx = max_idx.max(idx);
            feats.push((idx - 1, val));
        }
        rows.push(feats);
        labels.push(if lab > 0.0 { 1.0 } else { -1.0 });
    }
    let dim = match dim_hint {
        Some(d) => {
            if max_idx > d {
                bail!("dim_hint {d} smaller than max feature index {max_idx}");
            }
            d
        }
        None => max_idx,
    };
    Ok((rows, labels, dim))
}

/// Load a LIBSVM file into a dense [`Dataset`].
///
/// Injection site [`crate::util::fault::site::LIBSVM_READ`]: an `io`
/// rule fails the read outright; a `truncate:K` rule hands the parser
/// only the first `K` bytes, as a torn download would.
pub fn load(path: &Path, dim_hint: Option<usize>) -> Result<Dataset> {
    use crate::util::fault;
    let mut text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    match fault::armed(fault::site::LIBSVM_READ) {
        Some(fault::FaultKind::Io) => {
            bail!("reading {}: injected read fault", path.display())
        }
        Some(fault::FaultKind::Truncate(k)) => {
            let mut cut = k.min(text.len());
            while cut > 0 && !text.is_char_boundary(cut) {
                cut -= 1;
            }
            text.truncate(cut);
        }
        _ => {}
    }
    let (rows, labels, dim) = parse(&text, dim_hint)?;
    let mut x = DenseMatrix::zeros(rows.len(), dim);
    for (r, feats) in rows.iter().enumerate() {
        let row = x.row_mut(r);
        for &(i, v) in feats {
            row[i] = v;
        }
    }
    Ok(Dataset::new(x, labels, path.display().to_string()))
}

/// Write a dataset in LIBSVM format (zeros omitted).
pub fn write(ds: &Dataset) -> String {
    let mut out = String::new();
    for i in 0..ds.len() {
        let s = ds.sample(i);
        out.push_str(if s.y > 0.0 { "+1" } else { "-1" });
        for (j, &v) in s.x.iter().enumerate() {
            if v != 0.0 {
                out.push_str(&format!(" {}:{}", j + 1, v));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let text = "+1 1:0.5 3:1.5\n-1 2:2.0\n";
        let (rows, labels, dim) = parse(text, None).unwrap();
        assert_eq!(dim, 3);
        assert_eq!(labels, vec![1.0, -1.0]);
        assert_eq!(rows[0], vec![(0, 0.5), (2, 1.5)]);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = "# header\n\n+1 1:1\n";
        let (rows, ..) = parse(text, None).unwrap();
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn rejects_zero_index() {
        assert!(parse("+1 0:1\n", None).is_err());
    }

    #[test]
    fn rejects_decreasing_indices() {
        assert!(parse("+1 3:1 2:1\n", None).is_err());
    }

    #[test]
    fn dim_hint_conflict() {
        assert!(parse("+1 5:1\n", Some(3)).is_err());
        assert!(parse("+1 2:1\n", Some(5)).is_ok());
    }

    #[test]
    fn roundtrip_via_write() {
        use crate::data::DenseMatrix;
        let x = DenseMatrix::from_rows(vec![vec![0.0, 1.5], vec![2.0, 0.0]]);
        let ds = Dataset::new(x, vec![1.0, -1.0], "t");
        let text = write(&ds);
        let (rows, labels, dim) = parse(&text, Some(2)).unwrap();
        assert_eq!(dim, 2);
        assert_eq!(labels, vec![1.0, -1.0]);
        assert_eq!(rows[0], vec![(1, 1.5)]);
        assert_eq!(rows[1], vec![(0, 2.0)]);
    }

    #[test]
    fn nonpositive_labels_map_to_minus_one() {
        let (_, labels, _) = parse("0 1:1\n-3 1:1\n2 1:1\n", None).unwrap();
        assert_eq!(labels, vec![-1.0, -1.0, 1.0]);
    }

    #[test]
    fn rejects_non_finite_values_naming_position() {
        for (text, needle) in [
            ("+1 1:nan\n", "feature index 1"),
            ("+1 1:0.5 2:inf\n", "feature index 2"),
            ("+1 3:-inf\n", "feature index 3"),
            ("+1 1:1e40\n", "feature index 1"), // overflows f32 to +inf
            ("nan 1:1\n", "non-finite label"),
            ("inf 1:1\n", "non-finite label"),
        ] {
            let err = parse(text, None).unwrap_err().to_string();
            assert!(err.contains("line 1"), "{text:?}: {err}");
            assert!(err.contains(needle), "{text:?}: {err}");
        }
        // second line positions correctly
        let err = parse("+1 1:1\n-1 2:nan\n", None).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }
}

//! Synthetic statistical twins of the paper's benchmark datasets.
//!
//! The image is offline, so the five LIBSVM-repository datasets the paper
//! evaluates on are substituted with generators matched on the statistics
//! that drive BSGD's cost structure and merging behaviour (DESIGN.md §3):
//!
//! * n (train size), d (feature count), class balance;
//! * *difficulty*: a Gaussian-mixture class-conditional structure whose
//!   Bayes error is calibrated so that a full RBF-SVM lands near the
//!   paper's Table 2 accuracy — this controls the margin-violation rate
//!   and hence the number of support vectors, which is what budget
//!   maintenance actually reacts to.
//!
//! Each class is a mixture of `clusters` Gaussians placed on a scaled
//! hypersphere; a fraction `label_noise` of points get flipped labels
//! (irreducible error ≈ the gap between 100 % and the paper's LIBSVM
//! accuracy), and `overlap` scales the cluster radius relative to the
//! inter-cluster distance (reducible-but-hard error).

use super::{Dataset, DenseMatrix, Split};
use crate::rng::Xoshiro256;

/// Specification of a synthetic binary-classification dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    /// Total points; split into train/test with `test_fraction`.
    pub n: usize,
    pub dim: usize,
    pub test_fraction: f64,
    /// Gaussian clusters per class.
    pub clusters: usize,
    /// Cluster std relative to unit placement radius (difficulty knob).
    pub overlap: f64,
    /// Fraction of labels flipped after generation (irreducible error).
    pub label_noise: f64,
    /// Fraction of positive samples.
    pub positive_fraction: f64,
    /// Paper's tuned hyperparameters (Table 2), reused by experiments.
    pub c: f64,
    pub gamma: f64,
    /// Paper's LIBSVM reference accuracy (Table 2), for reporting.
    pub paper_accuracy: f64,
}

impl SynthSpec {
    /// PHISHING twin: 8 315 × 68, LIBSVM 97.55 %, C=8, γ=8.
    pub fn phishing_like(scale: f64) -> Self {
        Self {
            name: "phishing",
            n: (8_315 as f64 * scale) as usize,
            dim: 68,
            test_fraction: 0.25,
            clusters: 6,
            overlap: 0.40,
            label_noise: 0.015,
            positive_fraction: 0.56,
            c: 8.0,
            gamma: 8.0,
            paper_accuracy: 0.9755,
        }
    }

    /// WEB (w8a-like) twin: 17 188 × 300, LIBSVM 98.80 %, C=8, γ=0.03.
    pub fn web_like(scale: f64) -> Self {
        Self {
            name: "web",
            n: (17_188 as f64 * scale) as usize,
            dim: 300,
            test_fraction: 0.25,
            clusters: 8,
            overlap: 0.45,
            label_noise: 0.008,
            positive_fraction: 0.03,
            c: 8.0,
            gamma: 0.03,
            paper_accuracy: 0.9880,
        }
    }

    /// ADULT (a9a) twin: 32 561 × 123, LIBSVM 84.82 %, C=32, γ=0.008.
    ///
    /// ADULT is the noisy one — ~15 % irreducible error is what makes its
    /// full SVM huge (≈ 11 k SVs) and budget maintenance interesting.
    pub fn adult_like(scale: f64) -> Self {
        Self {
            name: "adult",
            n: (32_561 as f64 * scale) as usize,
            dim: 123,
            test_fraction: 0.25,
            clusters: 10,
            overlap: 0.85,
            label_noise: 0.10,
            positive_fraction: 0.24,
            c: 32.0,
            gamma: 0.008,
            paper_accuracy: 0.8482,
        }
    }

    /// IJCNN twin: 49 990 × 22, LIBSVM 98.77 %, C=32, γ=2.
    pub fn ijcnn_like(scale: f64) -> Self {
        Self {
            name: "ijcnn",
            n: (49_990 as f64 * scale) as usize,
            dim: 22,
            test_fraction: 0.25,
            clusters: 12,
            overlap: 0.50,
            label_noise: 0.008,
            positive_fraction: 0.10,
            c: 32.0,
            gamma: 2.0,
            paper_accuracy: 0.9877,
        }
    }

    /// SKIN/NON-SKIN twin: 164 788 × 3, LIBSVM 98.96 %, C=8, γ=0.03.
    pub fn skin_like(scale: f64) -> Self {
        Self {
            name: "skin",
            n: (164_788 as f64 * scale) as usize,
            dim: 3,
            test_fraction: 0.25,
            clusters: 4,
            overlap: 0.35,
            label_noise: 0.008,
            positive_fraction: 0.21,
            c: 8.0,
            gamma: 0.03,
            paper_accuracy: 0.9896,
        }
    }

    /// All five paper datasets in the paper's Table 2 order.
    pub fn paper_suite(scale: f64) -> Vec<Self> {
        vec![
            Self::phishing_like(scale),
            Self::web_like(scale),
            Self::adult_like(scale),
            Self::ijcnn_like(scale),
            Self::skin_like(scale),
        ]
    }

    /// Look up by name (CLI surface).
    pub fn by_name(name: &str, scale: f64) -> Option<Self> {
        match name {
            "phishing" => Some(Self::phishing_like(scale)),
            "web" => Some(Self::web_like(scale)),
            "adult" => Some(Self::adult_like(scale)),
            "ijcnn" => Some(Self::ijcnn_like(scale)),
            "skin" => Some(Self::skin_like(scale)),
            _ => None,
        }
    }
}

/// Generate the full dataset and split it. Deterministic in `seed`.
pub fn dataset(spec: &SynthSpec, seed: u64) -> Split {
    let mut rng = Xoshiro256::new(seed ^ 0x5e ^ hash_name(spec.name));
    let n = spec.n.max(8);
    let d = spec.dim;

    // Place cluster centers for both classes on a unit hypersphere; the
    // RBF-SVM-relevant geometry is relative (gamma rescales distances).
    let total_clusters = spec.clusters * 2;
    let mut centers: Vec<Vec<f64>> = Vec::with_capacity(total_clusters);
    for _ in 0..total_clusters {
        let mut c: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let norm = c.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
        for v in &mut c {
            *v /= norm;
        }
        centers.push(c);
    }
    // Average nearest-center distance sets the overlap scale.
    let mut nn = f64::INFINITY;
    for i in 0..total_clusters {
        for j in (i + 1)..total_clusters {
            let d2: f64 = centers[i]
                .iter()
                .zip(&centers[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            nn = nn.min(d2.sqrt());
        }
    }
    // Per-coordinate noise scaled by 1/√d so the cluster *radius*
    // (σ·√d in expectation) is `overlap · nn/2` in every dimension —
    // otherwise high-d clusters (WEB d=300) swamp their separation.
    let sigma = spec.overlap * nn / (2.0 * (d as f64).sqrt());

    let mut x = DenseMatrix::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let pos = rng.next_f64() < spec.positive_fraction;
        let class = if pos { 0 } else { 1 };
        let k = rng.next_below(spec.clusters);
        let center = &centers[class * spec.clusters + k];
        let row = x.row_mut(i);
        for (j, c) in center.iter().enumerate() {
            row[j] = (c + sigma * rng.next_gaussian()) as f32;
        }
        let mut label = if pos { 1.0 } else { -1.0 };
        if rng.next_f64() < spec.label_noise {
            label = -label;
        }
        y.push(label);
    }

    // --- kernel-scale calibration -------------------------------------
    // The paper's γ values (Table 2) were tuned on the real datasets'
    // coordinate scales.  Rescale the synthetic coordinates so that
    // γ · median(‖x−x'‖²) ≈ 5 over random pairs: the tuned γ is then,
    // by construction, a *sensible* bandwidth for the twin — random
    // pairs are near-orthogonal in feature space (k ≈ e⁻⁵), while
    // same-cluster neighbours (d² a few times smaller) stay strongly
    // correlated.  Neither a constant kernel (γd² ≈ 0) nor a delta
    // kernel (γd² ≫ 1) — the regime real RBF-SVM tuning lands in.
    let mut d2s: Vec<f64> = Vec::with_capacity(512);
    for _ in 0..512 {
        let i = rng.next_below(n);
        let j = rng.next_below(n);
        if i == j {
            continue;
        }
        let (ri, rj) = (x.row(i), x.row(j));
        d2s.push(
            ri.iter()
                .zip(rj)
                .map(|(&a, &b)| ((a - b) as f64) * ((a - b) as f64))
                .sum(),
        );
    }
    d2s.sort_by(f64::total_cmp);
    let median_d2 = d2s[d2s.len() / 2].max(1e-12);
    let scale_factor = (5.0 / (spec.gamma * median_d2)).sqrt() as f32;
    for v in 0..n {
        for c in x.row_mut(v) {
            *c *= scale_factor;
        }
    }

    let ds = Dataset::new(x, y, format!("{}-synth", spec.name));
    let n_test = ((n as f64) * spec.test_fraction) as usize;
    super::split::train_test(&ds, n_test, seed ^ 0x7e57)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a — stable tiny hash so different datasets decorrelate seeds.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = SynthSpec::ijcnn_like(0.01);
        let a = dataset(&spec, 3);
        let b = dataset(&spec, 3);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.y, b.train.y);
    }

    #[test]
    fn seeds_change_data() {
        let spec = SynthSpec::ijcnn_like(0.01);
        let a = dataset(&spec, 3);
        let b = dataset(&spec, 4);
        assert_ne!(a.train.x, b.train.x);
    }

    #[test]
    fn sizes_and_dims_match_spec() {
        let spec = SynthSpec::phishing_like(0.1);
        let split = dataset(&spec, 1);
        let total = split.train.len() + split.test.len();
        assert_eq!(total, spec.n);
        assert_eq!(split.train.dim(), 68);
        let frac = split.test.len() as f64 / total as f64;
        assert!((frac - spec.test_fraction).abs() < 0.01);
    }

    #[test]
    fn class_balance_near_spec() {
        let spec = SynthSpec::adult_like(0.2);
        let split = dataset(&spec, 5);
        let pf = split.train.positive_fraction();
        // label_noise shifts the observed fraction slightly; wide check.
        assert!((pf - 0.24).abs() < 0.08, "positive fraction {pf}");
    }

    #[test]
    fn by_name_roundtrip() {
        for s in SynthSpec::paper_suite(1.0) {
            let again = SynthSpec::by_name(s.name, 1.0).unwrap();
            assert_eq!(again.n, s.n);
        }
        assert!(SynthSpec::by_name("nope", 1.0).is_none());
    }

    #[test]
    fn data_is_separable_better_than_chance() {
        // 1-NN on a tiny slice must beat the majority class by a margin —
        // i.e. the generator produces learnable structure, not noise.
        let spec = SynthSpec::skin_like(0.005);
        let split = dataset(&spec, 9);
        let tr = &split.train;
        let te = &split.test;
        let mut correct = 0;
        for i in 0..te.len().min(200) {
            let q = te.sample(i);
            let mut best = (f32::INFINITY, 0.0f32);
            for j in 0..tr.len() {
                let s = tr.sample(j);
                let d2: f32 = q.x.iter().zip(s.x).map(|(a, b)| (a - b) * (a - b)).sum();
                if d2 < best.0 {
                    best = (d2, s.y);
                }
            }
            if best.1 == q.y {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len().min(200) as f64;
        assert!(acc > 0.85, "1-NN accuracy {acc} too low — generator broken?");
    }
}

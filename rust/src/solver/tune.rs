//! Hyperparameter tuning: grid search with k-fold cross-validation.
//!
//! The paper (§4.2) tunes C and γ "with grid search and
//! cross-validation"; this module provides that machinery for users
//! bringing their own data.  The inner solver is budgeted SGD (fast,
//! and the model that will be deployed anyway); the SMO reference can
//! be swapped in for small data via [`TuneParams::exact`].

use super::{bsgd, smo};
use crate::config::TrainConfig;
use crate::data::{split, Dataset};
use crate::error::TrainError;

#[derive(Clone, Debug)]
pub struct TuneParams {
    pub c_grid: Vec<f64>,
    pub gamma_grid: Vec<f64>,
    pub folds: usize,
    /// Base config for the inner BSGD runs (budget, mergees, seed...).
    pub base: TrainConfig,
    /// Use the exact SMO solver instead of BSGD (small data only).
    pub exact: bool,
    pub seed: u64,
}

impl Default for TuneParams {
    fn default() -> Self {
        Self {
            c_grid: vec![1.0, 4.0, 16.0, 64.0],
            gamma_grid: vec![0.01, 0.1, 1.0, 10.0],
            folds: 5,
            base: TrainConfig::default(),
            exact: false,
            seed: 1,
        }
    }
}

/// One grid cell's cross-validated result.
#[derive(Clone, Copy, Debug)]
pub struct CellResult {
    pub c: f64,
    pub gamma: f64,
    pub cv_accuracy: f64,
}

/// Full grid search; returns every cell (sorted best-first) so callers
/// can inspect the response surface, not just the argmax.
pub fn grid_search(ds: &Dataset, params: &TuneParams) -> Result<Vec<CellResult>, TrainError> {
    if ds.is_empty() {
        return Err(TrainError::EmptyDataset);
    }
    if params.folds < 2 || params.folds > ds.len() {
        return Err(TrainError::InvalidConfig {
            field: "folds",
            message: format!(
                "need 2..={} folds for {} samples, got {}",
                ds.len(),
                ds.len(),
                params.folds
            ),
        });
    }
    if params.c_grid.is_empty() || params.gamma_grid.is_empty() {
        return Err(TrainError::InvalidConfig {
            field: "grid",
            message: "c_grid and gamma_grid must be non-empty".into(),
        });
    }
    let folds = split::kfold(ds.len(), params.folds, params.seed);
    let mut out = Vec::new();
    for &c in &params.c_grid {
        for &gamma in &params.gamma_grid {
            let mut acc_sum = 0.0;
            for (train_idx, valid_idx) in &folds {
                let train = ds.gather(train_idx);
                let valid = ds.gather(valid_idx);
                let acc = if params.exact {
                    let p = smo::SmoParams { c, gamma, ..Default::default() };
                    let (model, _) = smo::train(&train, &p);
                    model.accuracy(&valid)
                } else {
                    let mut cfg = params.base.clone();
                    cfg.lambda = TrainConfig::lambda_from_c(c, train.len());
                    cfg.cost_c = None; // grid C overrides any pending base C
                    cfg.gamma = gamma;
                    let outp = bsgd::train(&train, &cfg)?;
                    outp.model.accuracy(&valid)
                };
                acc_sum += acc;
            }
            out.push(CellResult { c, gamma, cv_accuracy: acc_sum / folds.len() as f64 });
        }
    }
    out.sort_by(|a, b| b.cv_accuracy.total_cmp(&a.cv_accuracy));
    Ok(out)
}

/// Convenience: best (C, γ) from the grid.
pub fn best(ds: &Dataset, params: &TuneParams) -> Result<CellResult, TrainError> {
    Ok(grid_search(ds, params)?[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{dataset, SynthSpec};

    fn tiny() -> Dataset {
        dataset(&SynthSpec::ijcnn_like(0.01), 3).train
    }

    #[test]
    fn grid_covers_all_cells_sorted() {
        let ds = tiny();
        let params = TuneParams {
            c_grid: vec![1.0, 32.0],
            gamma_grid: vec![0.1, 2.0],
            folds: 3,
            seed: 7,
            ..Default::default()
        };
        let cells = grid_search(&ds, &params).unwrap();
        assert_eq!(cells.len(), 4);
        assert!(cells.windows(2).all(|w| w[0].cv_accuracy >= w[1].cv_accuracy));
        for cell in &cells {
            assert!((0.0..=1.0).contains(&cell.cv_accuracy));
        }
    }

    #[test]
    fn tuned_gamma_beats_terrible_gamma() {
        // The grid must rank a sane bandwidth above an absurd one.
        let ds = tiny();
        let params = TuneParams {
            c_grid: vec![32.0],
            gamma_grid: vec![2.0, 1e4],
            folds: 3,
            seed: 7,
            ..Default::default()
        };
        let best = best(&ds, &params).unwrap();
        assert_eq!(best.gamma, 2.0, "picked gamma {}", best.gamma);
    }

    #[test]
    fn bad_params_are_typed_errors() {
        use crate::error::TrainError;
        let ds = tiny();
        let mut params = TuneParams { folds: 1, ..Default::default() };
        match grid_search(&ds, &params) {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "folds"),
            other => panic!("expected folds error, got {:?}", other.map(|v| v.len())),
        }
        params.folds = 2;
        params.c_grid.clear();
        assert!(grid_search(&ds, &params).is_err());
    }

    #[test]
    fn exact_mode_runs() {
        let ds = crate::data::split::stratified_subsample(&tiny(), 120, 1);
        let params = TuneParams {
            c_grid: vec![8.0],
            gamma_grid: vec![2.0],
            folds: 2,
            exact: true,
            seed: 5,
            ..Default::default()
        };
        let cells = grid_search(&ds, &params).unwrap();
        assert_eq!(cells.len(), 1);
        assert!(cells[0].cv_accuracy > 0.5);
    }
}

//! Budgeted Stochastic Gradient Descent (BSGD) — Pegasos SGD on the
//! primal SVM objective with an a-priori budget on support vectors
//! (Wang, Crammer, Vucetic 2012), with the paper's multi-merge budget
//! maintenance plugged in through [`crate::budget::Budget`].
//!
//! Per step t (learning rate η_t = η₀/(λ·t)):
//!   1. margin: f(x_t) = Σ_j α_j k(x_j, x_t) + b          — Θ(B·K)
//!   2. shrink: α ← (1 − η_t λ) α                          — O(1) (lazy)
//!   3. if y_t f(x_t) < 1: α_t ← η_t y_t (new SV), b += η_t y_t
//!   4. if |SV| > B: budget maintenance                    — Θ(B·K·G)
//!
//! Wall-clock is attributed per phase into a [`TimeBook`]
//! (`margin` / `merge` / other), which is exactly the measurement behind
//! the paper's Figure 1 (fraction of training time spent merging).

use super::Observer;
use crate::budget::Budget;
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::model::SvmModel;
use crate::rng::Xoshiro256;
use crate::runtime::{Backend, NativeBackend};
use crate::util::timer::TimeBook;
use std::time::Instant;

/// One point of the evaluation curve.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: u64,
    pub accuracy: f64,
    pub n_svs: usize,
    pub elapsed_s: f64,
}

/// Everything a training run produces.
pub struct TrainOutput {
    pub model: SvmModel,
    /// Per-phase wall clock: `margin`, `merge`, `update`.
    pub times: TimeBook,
    /// Total training wall-clock (includes per-phase buckets).
    pub train_seconds: f64,
    pub steps: u64,
    pub margin_violations: u64,
    /// Budget-maintenance statistics (events, Σwd, ...).
    pub maintenance_events: u64,
    pub total_weight_degradation: f64,
    pub mean_weight_degradation: f64,
    /// Evaluation curve (non-empty iff `eval_every > 0` and eval data given).
    pub history: Vec<EvalPoint>,
}

impl TrainOutput {
    /// Fraction of training time spent on budget maintenance (Fig. 1).
    pub fn merge_fraction(&self) -> f64 {
        if self.train_seconds <= 0.0 {
            return 0.0;
        }
        self.times.get("merge").as_secs_f64() / self.train_seconds
    }
}

/// Train with an explicit backend, optional eval set, and observer.
pub fn train_full(
    ds: &Dataset,
    cfg: &TrainConfig,
    backend: &mut dyn Backend,
    eval: Option<&Dataset>,
    obs: &mut dyn Observer,
) -> TrainOutput {
    cfg.validate().expect("invalid TrainConfig");
    assert!(!ds.is_empty(), "empty training set");
    // Record the scorer actually in effect, not the requested one: a
    // backend with a fixed scorer (e.g. the AOT artifact kernel) ignores
    // the request, and provenance must not claim otherwise.
    let score_mode = backend.set_merge_score_mode(cfg.merge_score_mode);

    let mut model = SvmModel::new(ds.dim(), cfg.gamma);
    model.meta = format!(
        "bsgd maintenance={} B={} seed={} backend={} score={}",
        cfg.maintenance_kind().describe(),
        cfg.budget,
        cfg.seed,
        backend.name(),
        score_mode.describe()
    );
    let mut budget = Budget::new(cfg.budget, cfg.maintenance_kind());
    let mut rng = Xoshiro256::new(cfg.seed);
    let mut order: Vec<usize> = (0..ds.len()).collect();
    let mut times = TimeBook::new();
    let mut history = Vec::new();
    let mut violations = 0u64;
    let mut t = 0u64;
    let started = Instant::now();

    for epoch in 0..cfg.epochs {
        obs.on_epoch(epoch);
        rng.shuffle(&mut order);
        for &idx in &order {
            t += 1;
            let s = ds.sample(idx);
            let eta = cfg.eta0 / (cfg.lambda * t as f64);

            // (1) margin of the candidate point — the Θ(B·K) step cost.
            let t0 = Instant::now();
            let f = backend.margin1(&model.svs, cfg.gamma, s.x) + model.bias;
            times.add("margin", t0.elapsed());

            // (2) regularizer shrink — O(1) via the lazy scale.
            model.svs.scale_all(1.0 - eta * cfg.lambda);

            // (3) margin violation ⇒ new SV.
            if (s.y as f64) * f < 1.0 {
                violations += 1;
                let t1 = Instant::now();
                model.svs.push(s.x, eta * s.y as f64);
                if cfg.use_bias {
                    model.bias += eta * s.y as f64;
                }
                times.add("update", t1.elapsed());

                // (4) budget maintenance — the paper's Θ(B·K·G) event.
                if model.svs.len() > budget.size {
                    let t2 = Instant::now();
                    budget.enforce(&mut model.svs, cfg.gamma, backend);
                    if cfg.prune_eps > 0.0 {
                        model.svs.prune(cfg.prune_eps);
                    }
                    times.add("merge", t2.elapsed());
                    obs.on_maintenance(budget.events, budget.total_wd, model.svs.len());
                }
            }
            obs.on_step(t, model.svs.len());

            if cfg.eval_every > 0 && t % cfg.eval_every as u64 == 0 {
                if let Some(ev) = eval {
                    let acc = evaluate(&model, backend, ev);
                    history.push(EvalPoint {
                        step: t,
                        accuracy: acc,
                        n_svs: model.svs.len(),
                        elapsed_s: started.elapsed().as_secs_f64(),
                    });
                    obs.on_eval(t, acc);
                }
            }
        }
    }
    let train_seconds = started.elapsed().as_secs_f64();
    model.svs.fold_scale();

    TrainOutput {
        model,
        times,
        train_seconds,
        steps: t,
        margin_violations: violations,
        maintenance_events: budget.events,
        total_weight_degradation: budget.total_wd,
        mean_weight_degradation: budget.mean_wd(),
        history,
    }
}

/// Accuracy of `model` on `ds` using the backend's batched margins.
pub fn evaluate(model: &SvmModel, backend: &mut dyn Backend, ds: &Dataset) -> f64 {
    if ds.is_empty() {
        return 0.0;
    }
    let margins = backend.margins(&model.svs, model.gamma, &ds.x);
    let correct = margins
        .iter()
        .zip(&ds.y)
        .filter(|(&f, &y)| {
            let pred = if f + model.bias >= 0.0 { 1.0 } else { -1.0 };
            pred == y
        })
        .count();
    correct as f64 / ds.len() as f64
}

/// Convenience: train with the native backend and no observer.
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> TrainOutput {
    let mut backend = NativeBackend::new();
    train_full(ds, cfg, &mut backend, None, &mut super::NoopObserver)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::MaintenanceKind;
    use crate::data::synth::{dataset, SynthSpec};

    fn tiny_cfg(budget: usize, m: usize) -> TrainConfig {
        TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget,
            mergees: m,
            epochs: 1,
            seed: 7,
            ..TrainConfig::default()
        }
    }

    fn tiny_split() -> crate::data::Split {
        dataset(&SynthSpec::ijcnn_like(0.02), 11) // ~1000 points, d=22
    }

    #[test]
    fn learns_better_than_chance() {
        let split = tiny_split();
        let out = train(&split.train, &tiny_cfg(64, 2));
        let acc = out.model.accuracy(&split.test);
        // majority class is ~90%; require beating coin flip at minimum
        // and the run to actually use its budget
        assert!(acc > 0.8, "accuracy {acc}");
        assert!(out.model.svs.len() <= 64);
        assert!(out.margin_violations > 0);
    }

    #[test]
    fn budget_is_never_exceeded() {
        let split = tiny_split();
        for m in [2, 5] {
            let out = train(&split.train, &tiny_cfg(32, m));
            assert!(out.model.svs.len() <= 32, "M={m}: {} SVs", out.model.svs.len());
            assert!(out.maintenance_events > 0, "M={m}: budget never hit?");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let split = tiny_split();
        let a = train(&split.train, &tiny_cfg(32, 3));
        let b = train(&split.train, &tiny_cfg(32, 3));
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.margin_violations, b.margin_violations);
        assert_eq!(a.model.svs.len(), b.model.svs.len());
        assert!((a.model.bias - b.model.bias).abs() < 1e-15);
        assert_eq!(a.model.svs.points_flat(), b.model.svs.points_flat());
    }

    #[test]
    fn multimerge_triggers_fewer_maintenance_events() {
        // The paper's core accounting: merging M points per event means
        // ~(M-1)x fewer events for the same stream.
        let split = tiny_split();
        let out2 = train(&split.train, &tiny_cfg(32, 2));
        let out5 = train(&split.train, &tiny_cfg(32, 5));
        assert!(
            (out5.maintenance_events as f64) < (out2.maintenance_events as f64) * 0.45,
            "events M=5 {} vs M=2 {}",
            out5.maintenance_events,
            out2.maintenance_events
        );
    }

    #[test]
    fn eval_history_recorded() {
        let split = tiny_split();
        let mut cfg = tiny_cfg(32, 2);
        cfg.eval_every = 200;
        let mut be = NativeBackend::new();
        let out = train_full(
            &split.train,
            &cfg,
            &mut be,
            Some(&split.test),
            &mut crate::solver::NoopObserver,
        );
        assert!(!out.history.is_empty());
        assert!(out.history.iter().all(|p| p.accuracy >= 0.0 && p.accuracy <= 1.0));
        // curve steps strictly increasing
        assert!(out.history.windows(2).all(|w| w[0].step < w[1].step));
    }

    #[test]
    fn removal_maintenance_also_works() {
        let split = tiny_split();
        let mut cfg = tiny_cfg(24, 2);
        cfg.maintenance = Some(MaintenanceKind::Removal);
        let out = train(&split.train, &cfg);
        assert!(out.model.svs.len() <= 24);
        assert!(out.maintenance_events > 0);
    }

    #[test]
    fn merge_fraction_is_sane() {
        let split = tiny_split();
        // B small enough that maintenance definitely triggers
        let out = train(&split.train, &tiny_cfg(8, 2));
        let frac = out.merge_fraction();
        assert!((0.0..=1.0).contains(&frac), "merge fraction {frac}");
        assert!(frac > 0.0, "maintenance ran, fraction must be positive");
    }

    #[test]
    fn unbudgeted_limit_matches_pegasos_contract() {
        // huge budget => no maintenance events
        let split = tiny_split();
        let out = train(&split.train, &tiny_cfg(100_000, 2));
        assert_eq!(out.maintenance_events, 0);
        assert_eq!(out.model.svs.len() as u64, out.margin_violations);
    }
}

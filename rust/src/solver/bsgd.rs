//! Budgeted Stochastic Gradient Descent (BSGD) — Pegasos SGD on the
//! primal SVM objective with an a-priori budget on support vectors
//! (Wang, Crammer, Vucetic 2012), with the paper's multi-merge budget
//! maintenance plugged in through [`crate::budget::Budget`].
//!
//! Per step t (learning rate η_t = η₀/(λ·t)):
//!   1. margin: f(x_t) = Σ_j α_j k(x_j, x_t) + b          — Θ(B·K)
//!   2. shrink: α ← (1 − η_t λ) α                          — O(1) (lazy)
//!   3. if y_t f(x_t) < 1: α_t ← η_t y_t (new SV), b += η_t y_t
//!   4. if |SV| > B: budget maintenance                    — Θ(B·K·G)
//!
//! Wall-clock is attributed per phase into a [`TimeBook`]
//! (`margin` / `merge` / other), which is exactly the measurement behind
//! the paper's Figure 1 (fraction of training time spent merging).

use super::session::TrainSession;
use super::Observer;
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::error::TrainError;
use crate::model::SvmModel;
use crate::runtime::{Backend, NativeBackend};
use crate::util::timer::TimeBook;

/// One point of the evaluation curve.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub step: u64,
    pub accuracy: f64,
    pub n_svs: usize,
    pub elapsed_s: f64,
}

/// Everything a training run produces.
pub struct TrainOutput {
    pub model: SvmModel,
    /// Per-phase wall clock: `margin`, `merge`, `update`.
    pub times: TimeBook,
    /// Total training wall-clock (includes per-phase buckets).
    pub train_seconds: f64,
    pub steps: u64,
    pub margin_violations: u64,
    /// Budget-maintenance statistics (events, Σwd, ...).
    pub maintenance_events: u64,
    pub total_weight_degradation: f64,
    pub mean_weight_degradation: f64,
    /// Evaluation curve (non-empty iff `eval_every > 0` and eval data given).
    pub history: Vec<EvalPoint>,
}

impl TrainOutput {
    /// Fraction of training time spent on budget maintenance (Fig. 1).
    pub fn merge_fraction(&self) -> f64 {
        if self.train_seconds <= 0.0 {
            return 0.0;
        }
        self.times.get("merge").as_secs_f64() / self.train_seconds
    }
}

/// Train with an explicit backend, optional eval set, and observer.
///
/// A thin epoch loop over [`TrainSession`] — the step logic lives
/// there, and callers needing streaming ingestion, mid-run
/// checkpointing, or resume use the session directly.
pub fn train_full(
    ds: &Dataset,
    cfg: &TrainConfig,
    backend: &mut dyn Backend,
    eval: Option<&Dataset>,
    obs: &mut dyn Observer,
) -> Result<TrainOutput, TrainError> {
    let mut sess = TrainSession::new(cfg.clone(), backend)?;
    while sess.epochs_done() < cfg.epochs as u64 {
        sess.run_epoch(ds, eval, obs, 0)?;
    }
    Ok(sess.finish())
}

/// Accuracy of `model` on `ds` using the backend's batched margins.
pub fn evaluate(model: &SvmModel, backend: &mut dyn Backend, ds: &Dataset) -> f64 {
    if ds.is_empty() {
        return 0.0;
    }
    let margins = backend.margins(&model.svs, model.gamma, &ds.x);
    let correct = margins
        .iter()
        .zip(&ds.y)
        .filter(|(&f, &y)| {
            let pred = if f + model.bias >= 0.0 { 1.0 } else { -1.0 };
            pred == y
        })
        .count();
    correct as f64 / ds.len() as f64
}

/// Convenience: train with the native backend and no observer.
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> Result<TrainOutput, TrainError> {
    let mut backend = NativeBackend::new();
    train_full(ds, cfg, &mut backend, None, &mut super::NoopObserver)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::MaintenanceKind;
    use crate::data::synth::{dataset, SynthSpec};

    fn tiny_cfg(budget: usize, m: usize) -> TrainConfig {
        TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget,
            mergees: m,
            epochs: 1,
            seed: 7,
            ..TrainConfig::default()
        }
    }

    fn tiny_split() -> crate::data::Split {
        dataset(&SynthSpec::ijcnn_like(0.02), 11) // ~1000 points, d=22
    }

    #[test]
    fn learns_better_than_chance() {
        let split = tiny_split();
        let out = train(&split.train, &tiny_cfg(64, 2)).unwrap();
        let acc = out.model.accuracy(&split.test);
        // majority class is ~90%; require beating coin flip at minimum
        // and the run to actually use its budget
        assert!(acc > 0.8, "accuracy {acc}");
        assert!(out.model.svs.len() <= 64);
        assert!(out.margin_violations > 0);
    }

    #[test]
    fn budget_is_never_exceeded() {
        let split = tiny_split();
        for m in [2, 5] {
            let out = train(&split.train, &tiny_cfg(32, m)).unwrap();
            assert!(out.model.svs.len() <= 32, "M={m}: {} SVs", out.model.svs.len());
            assert!(out.maintenance_events > 0, "M={m}: budget never hit?");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let split = tiny_split();
        let a = train(&split.train, &tiny_cfg(32, 3)).unwrap();
        let b = train(&split.train, &tiny_cfg(32, 3)).unwrap();
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.margin_violations, b.margin_violations);
        assert_eq!(a.model.svs.len(), b.model.svs.len());
        assert!((a.model.bias - b.model.bias).abs() < 1e-15);
        assert_eq!(a.model.svs.points_flat(), b.model.svs.points_flat());

        // A run interrupted mid-epoch (checkpoint → resume in a fresh
        // session and backend) must be bit-identical to the
        // uninterrupted ones; tests/session.rs covers this in depth.
        let mut be = NativeBackend::new();
        let mut sess = TrainSession::new(tiny_cfg(32, 3), &mut be).unwrap();
        let done = sess
            .run_epoch(&split.train, None, &mut crate::solver::NoopObserver, 313)
            .unwrap();
        assert!(!done, "interrupt point past the epoch — shrink max_steps");
        let blob = sess.checkpoint();
        let mut be2 = NativeBackend::new();
        let mut resumed = TrainSession::resume(&blob, &mut be2).unwrap();
        resumed.partial_fit(&split.train).unwrap();
        let c = resumed.finish();
        assert_eq!(c.steps, a.steps);
        assert_eq!(c.margin_violations, a.margin_violations);
        assert_eq!(c.maintenance_events, a.maintenance_events);
        assert_eq!(c.model.svs.points_flat(), a.model.svs.points_flat());
        assert_eq!(c.model.svs.alphas_vec(), a.model.svs.alphas_vec());
        assert_eq!(c.model.bias.to_bits(), a.model.bias.to_bits());
    }

    #[test]
    fn multimerge_triggers_fewer_maintenance_events() {
        // The paper's core accounting: merging M points per event means
        // ~(M-1)x fewer events for the same stream.
        let split = tiny_split();
        let out2 = train(&split.train, &tiny_cfg(32, 2)).unwrap();
        let out5 = train(&split.train, &tiny_cfg(32, 5)).unwrap();
        assert!(
            (out5.maintenance_events as f64) < (out2.maintenance_events as f64) * 0.45,
            "events M=5 {} vs M=2 {}",
            out5.maintenance_events,
            out2.maintenance_events
        );
    }

    #[test]
    fn eval_history_recorded() {
        let split = tiny_split();
        let mut cfg = tiny_cfg(32, 2);
        cfg.eval_every = 200;
        let mut be = NativeBackend::new();
        let out = train_full(
            &split.train,
            &cfg,
            &mut be,
            Some(&split.test),
            &mut crate::solver::NoopObserver,
        )
        .unwrap();
        assert!(!out.history.is_empty());
        assert!(out.history.iter().all(|p| p.accuracy >= 0.0 && p.accuracy <= 1.0));
        // curve steps strictly increasing
        assert!(out.history.windows(2).all(|w| w[0].step < w[1].step));
    }

    #[test]
    fn removal_maintenance_also_works() {
        let split = tiny_split();
        let mut cfg = tiny_cfg(24, 2);
        cfg.maintenance = Some(MaintenanceKind::Removal);
        let out = train(&split.train, &cfg).unwrap();
        assert!(out.model.svs.len() <= 24);
        assert!(out.maintenance_events > 0);
    }

    #[test]
    fn merge_fraction_is_sane() {
        let split = tiny_split();
        // B small enough that maintenance definitely triggers
        let out = train(&split.train, &tiny_cfg(8, 2)).unwrap();
        let frac = out.merge_fraction();
        assert!((0.0..=1.0).contains(&frac), "merge fraction {frac}");
        assert!(frac > 0.0, "maintenance ran, fraction must be positive");
    }

    #[test]
    fn unbudgeted_limit_matches_pegasos_contract() {
        // huge budget => no maintenance events
        let split = tiny_split();
        let out = train(&split.train, &tiny_cfg(100_000, 2)).unwrap();
        assert_eq!(out.maintenance_events, 0);
        assert_eq!(out.model.svs.len() as u64, out.margin_violations);
    }
}

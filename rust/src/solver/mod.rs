//! Solvers.
//!
//! * [`session`] — the incremental training surface: [`session::TrainSession`]
//!   (streaming `step`/`partial_fit`, checkpoint/resume) that the batch
//!   entry points wrap.
//! * [`bsgd`]    — Budgeted SGD (Pegasos + budget maintenance): the
//!   algorithm the paper modifies; every experiment runs through it.
//! * [`pegasos`] — unbudgeted Pegasos SGD (the B → ∞ limit, sanity
//!   baseline).
//! * [`smo`]     — dual SMO solver with second-order working-set
//!   selection: the "exact" LIBSVM reference of Table 2 / Fig. 5.

pub mod bsgd;
pub mod pegasos;
pub mod session;
pub mod smo;
pub mod tune;

pub use session::{load_checkpoint, Checkpoint, LoadedCheckpoint, StepOutcome, TrainSession};

/// Progress hooks; implemented by the coordinator for live reporting.
/// All methods default to no-ops.
pub trait Observer {
    fn on_step(&mut self, _step: u64, _n_svs: usize) {}
    fn on_maintenance(&mut self, _event: u64, _wd: f64, _n_svs: usize) {}
    fn on_eval(&mut self, _step: u64, _accuracy: f64) {}
    fn on_epoch(&mut self, _epoch: usize) {}
}

/// The do-nothing observer.
pub struct NoopObserver;

impl Observer for NoopObserver {}

//! Incremental training sessions — the first-class surface behind
//! [`super::bsgd::train_full`].
//!
//! BSGD is an inherently online algorithm: the paper's budget
//! maintenance fires incrementally, one overflow at a time, and nothing
//! in the update rule needs the whole dataset up front.  A
//! [`TrainSession`] owns the complete training state (model, budget
//! counters, RNG, phase timers, step counter, eval history) and exposes
//! it one step at a time:
//!
//! * [`TrainSession::step`] ingests a single labelled sample — the
//!   streaming primitive;
//! * [`TrainSession::partial_fit`] / [`TrainSession::run_epoch`] drive
//!   one (possibly resumed, possibly step-capped) shuffled pass over a
//!   dataset;
//! * [`TrainSession::checkpoint`] serializes *all* state — including
//!   the RNG stream, the lazy coefficient scale, and the unconsumed
//!   remainder of the current epoch — so a run interrupted at any step
//!   and resumed via [`TrainSession::resume`] produces bit-identical
//!   support vectors, bias, and maintenance statistics to an
//!   uninterrupted run (`rust/tests/session.rs` enforces this);
//! * [`TrainSession::finish`] folds the model and returns the familiar
//!   [`TrainOutput`].
//!
//! Construction never panics on user input: invalid configs, malformed
//! checkpoints, and shape mismatches surface as [`TrainError`].
//!
//! ```
//! use mmbsgd::prelude::*;
//! use mmbsgd::solver::session::TrainSession;
//!
//! let split = mmbsgd::data::synth::dataset(&SynthSpec::ijcnn_like(0.01), 1);
//! let cfg = TrainConfig { lambda: 1e-3, gamma: 2.0, budget: 32, ..TrainConfig::default() };
//!
//! // Stream one epoch, checkpoint mid-run, resume, finish.
//! let mut backend = NativeBackend::new();
//! let mut sess = TrainSession::new(cfg, &mut backend).unwrap();
//! sess.run_epoch(&split.train, None, &mut mmbsgd::solver::NoopObserver, 100).unwrap();
//! let blob = sess.checkpoint();
//!
//! let mut backend2 = NativeBackend::new();
//! let mut resumed = TrainSession::resume(&blob, &mut backend2).unwrap();
//! resumed.partial_fit(&split.train).unwrap();
//! let out = resumed.finish();
//! assert!(out.steps as usize >= split.train.len());
//! assert!(out.model.svs.len() <= 32);
//! ```

use super::bsgd::{evaluate, EvalPoint, TrainOutput};
use super::{NoopObserver, Observer};
use crate::budget::{Budget, MaintenanceKind, MergeScoreMode};
use crate::config::{BackendChoice, TrainConfig};
use crate::data::{Dataset, Sample};
use crate::error::TrainError;
use crate::model::{SvStore, SvmModel};
use crate::rng::Xoshiro256;
use crate::runtime::Backend;
use crate::util::durable;
use crate::util::timer::TimeBook;
use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// What one [`TrainSession::step`] did.
#[derive(Clone, Copy, Debug)]
pub struct StepOutcome {
    /// Decision value f(x) (bias included) before the update.
    pub margin: f64,
    /// y·f(x) < 1 — the sample violated the margin and became an SV.
    pub violation: bool,
    /// Budget maintenance ran on this step.
    pub maintained: bool,
}

/// Time-bucket names the checkpoint format round-trips (`&'static`
/// keys force an allowlist; unknown names in a checkpoint are dropped).
const TIME_BUCKETS: [&str; 3] = ["margin", "update", "merge"];

/// A resumable BSGD training session; see the [module docs](self).
pub struct TrainSession<'b> {
    cfg: TrainConfig,
    backend: &'b mut dyn Backend,
    model: SvmModel,
    budget: Budget,
    rng: Xoshiro256,
    times: TimeBook,
    history: Vec<EvalPoint>,
    violations: u64,
    t: u64,
    epochs_done: u64,
    /// Shuffled sample indices of the in-flight epoch; `pos` marks the
    /// next one to consume.  Serialized so a mid-epoch checkpoint
    /// resumes on exactly the same remaining stream.
    pending: Vec<usize>,
    pos: usize,
    /// Accumulated wall-clock over all (possibly interrupted) segments.
    elapsed_s: f64,
}

impl<'b> TrainSession<'b> {
    /// Start a fresh session.  Validates the config (typed errors, no
    /// panics) and records provenance; the feature dimension binds
    /// lazily on the first sample.
    pub fn new(cfg: TrainConfig, backend: &'b mut dyn Backend) -> Result<Self, TrainError> {
        cfg.validate()?;
        let score_mode = backend.set_merge_score_mode(cfg.merge_score_mode);
        // Threads and SIMD dispatch are applied but deliberately NOT
        // recorded in model provenance: both are execution details
        // with bit-identical results for every setting, and embedding
        // them would make saved models / checkpoints byte-differ
        // across `--threads` / `--simd-mode` (the CLI prints the
        // effective values per run instead).  The exp mode is the same
        // kind of knob (vector mode changes results only within its
        // documented 1e-6 accuracy envelope) and follows the same rule.
        backend.set_threads(cfg.threads);
        crate::kernel::simd::set_mode(cfg.simd_mode);
        crate::kernel::simd::set_exp_mode(cfg.exp_mode);
        let mut model = SvmModel::new(0, cfg.gamma);
        model.meta = format!(
            "bsgd maintenance={} B={} seed={} backend={} score={}",
            cfg.maintenance_kind().describe(),
            cfg.budget,
            cfg.seed,
            backend.name(),
            score_mode.describe(),
        );
        let budget = Budget::new(cfg.budget, cfg.maintenance_kind());
        let rng = Xoshiro256::new(cfg.seed);
        Ok(Self {
            cfg,
            backend,
            model,
            budget,
            rng,
            times: TimeBook::new(),
            history: Vec::new(),
            violations: 0,
            t: 0,
            epochs_done: 0,
            pending: Vec::new(),
            pos: 0,
            elapsed_s: 0.0,
        })
    }

    /// Rebuild a session from a [`TrainSession::checkpoint`] blob.
    pub fn resume(text: &str, backend: &'b mut dyn Backend) -> Result<Self, TrainError> {
        Checkpoint::parse(text)?.into_session(backend)
    }

    // ------------------------------------------------------- streaming

    /// Ingest one labelled sample: margin, Pegasos shrink, conditional
    /// SV insertion, budget maintenance.  The feature dimension is
    /// bound by the first sample; later mismatches are typed errors.
    pub fn step(&mut self, s: &Sample<'_>) -> Result<StepOutcome, TrainError> {
        let dim = self.model.svs.dim();
        if dim != s.x.len() {
            if dim == 0 && self.model.svs.is_empty() {
                // capacity is a hint; clamp so an absurd budget cannot
                // overflow the `cap * dim` reservation
                let cap = self.cfg.budget.saturating_add(1).min(1 << 16);
                self.model.svs = SvStore::with_capacity(s.x.len(), cap);
            } else {
                return Err(TrainError::DimMismatch { expected: dim, got: s.x.len() });
            }
        }
        self.t += 1;
        let eta = self.cfg.eta0 / (self.cfg.lambda * self.t as f64);

        // (1) margin of the candidate point — the Θ(B·K) step cost.
        let t0 = Instant::now();
        let f = self.backend.margin1(&self.model.svs, self.cfg.gamma, s.x) + self.model.bias;
        self.times.add("margin", t0.elapsed());

        // (2) regularizer shrink — O(1) via the lazy scale.
        self.model.svs.scale_all(1.0 - eta * self.cfg.lambda);

        // (3) margin violation ⇒ new SV.
        let violation = (s.y as f64) * f < 1.0;
        let mut maintained = false;
        if violation {
            self.violations += 1;
            let t1 = Instant::now();
            self.model.svs.push(s.x, eta * s.y as f64);
            if self.cfg.use_bias {
                self.model.bias += eta * s.y as f64;
            }
            self.times.add("update", t1.elapsed());

            // (4) budget maintenance — the paper's Θ(B·K·G) event.
            if self.model.svs.len() > self.budget.size {
                let t2 = Instant::now();
                self.budget.enforce(&mut self.model.svs, self.cfg.gamma, &mut *self.backend);
                if self.cfg.prune_eps > 0.0 {
                    self.model.svs.prune(self.cfg.prune_eps);
                }
                self.times.add("merge", t2.elapsed());
                maintained = true;
            }
        }
        Ok(StepOutcome { margin: f, violation, maintained })
    }

    /// Drive the in-flight epoch over `ds` (starting a fresh shuffled
    /// pass if none is pending), stopping after at most `max_steps`
    /// steps (`0` = run to the epoch boundary).  Evaluates on `eval`
    /// every `cfg.eval_every` steps.  Returns `true` when the epoch
    /// completed.
    pub fn run_epoch(
        &mut self,
        ds: &Dataset,
        eval: Option<&Dataset>,
        obs: &mut dyn Observer,
        max_steps: u64,
    ) -> Result<bool, TrainError> {
        if ds.is_empty() {
            return Err(TrainError::EmptyDataset);
        }
        let started = Instant::now();
        let res = self.run_epoch_inner(ds, eval, obs, max_steps, started);
        self.elapsed_s += started.elapsed().as_secs_f64();
        res
    }

    fn run_epoch_inner(
        &mut self,
        ds: &Dataset,
        eval: Option<&Dataset>,
        obs: &mut dyn Observer,
        max_steps: u64,
        started: Instant,
    ) -> Result<bool, TrainError> {
        if self.pos >= self.pending.len() {
            obs.on_epoch(self.epochs_done as usize);
            // Each epoch is a fresh Fisher–Yates shuffle of the identity
            // permutation.  (The pre-session batch loop shuffled the
            // previous epoch's order in place, i.e. composed the
            // permutations; composing would force checkpoints to carry
            // the full O(n) order to stay bit-identical across resumes.
            // Multi-epoch streams therefore differ from the pre-PR-2
            // loop — see EXPERIMENTS.md §Deviations.)
            self.pending = (0..ds.len()).collect();
            self.rng.shuffle(&mut self.pending);
            self.pos = 0;
        }
        let mut taken = 0u64;
        while self.pos < self.pending.len() {
            if max_steps > 0 && taken >= max_steps {
                return Ok(false);
            }
            let idx = self.pending[self.pos];
            if idx >= ds.len() {
                return Err(TrainError::Checkpoint(format!(
                    "pending sample index {idx} out of range for a dataset of {} rows — \
                     resumed against a different dataset?",
                    ds.len()
                )));
            }
            self.pos += 1;
            let out = self.step(&ds.sample(idx))?;
            taken += 1;
            if out.maintained {
                obs.on_maintenance(self.budget.events, self.budget.total_wd, self.model.svs.len());
            }
            obs.on_step(self.t, self.model.svs.len());

            if self.cfg.eval_every > 0 && self.t % self.cfg.eval_every as u64 == 0 {
                if let Some(ev) = eval {
                    let acc = evaluate(&self.model, &mut *self.backend, ev);
                    self.history.push(EvalPoint {
                        step: self.t,
                        accuracy: acc,
                        n_svs: self.model.svs.len(),
                        elapsed_s: self.elapsed_s + started.elapsed().as_secs_f64(),
                    });
                    obs.on_eval(self.t, acc);
                }
            }
        }
        self.pending.clear();
        self.pos = 0;
        self.epochs_done += 1;
        Ok(true)
    }

    /// One full shuffled pass over `ds` (scikit-learn-style streaming
    /// ingestion); completes the in-flight epoch if one is pending.
    pub fn partial_fit(&mut self, ds: &Dataset) -> Result<(), TrainError> {
        self.run_epoch(ds, None, &mut NoopObserver, 0).map(|_| ())
    }

    /// Accuracy of the current model on `ds` through the session's
    /// backend (batched margins).
    pub fn evaluate(&mut self, ds: &Dataset) -> f64 {
        evaluate(&self.model, &mut *self.backend, ds)
    }

    /// Consume the session into a [`TrainOutput`] (folds the lazy
    /// coefficient scale).
    pub fn finish(mut self) -> TrainOutput {
        self.model.svs.fold_scale();
        TrainOutput {
            model: self.model,
            times: self.times,
            train_seconds: self.elapsed_s,
            steps: self.t,
            margin_violations: self.violations,
            maintenance_events: self.budget.events,
            total_weight_degradation: self.budget.total_wd,
            mean_weight_degradation: self.budget.mean_wd(),
            history: self.history,
        }
    }

    // ------------------------------------------------------- accessors

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    pub fn model(&self) -> &SvmModel {
        &self.model
    }

    /// Steps taken so far (across all epochs and resumes).
    pub fn steps(&self) -> u64 {
        self.t
    }

    pub fn epochs_done(&self) -> u64 {
        self.epochs_done
    }

    pub fn margin_violations(&self) -> u64 {
        self.violations
    }

    pub fn maintenance_events(&self) -> u64 {
        self.budget.events
    }

    pub fn n_svs(&self) -> usize {
        self.model.svs.len()
    }

    pub fn history(&self) -> &[EvalPoint] {
        &self.history
    }

    pub fn times(&self) -> &TimeBook {
        &self.times
    }

    /// Samples left in the in-flight epoch (0 at an epoch boundary).
    pub fn remaining_in_epoch(&self) -> usize {
        self.pending.len() - self.pos
    }

    // ---------------------------------------------------- persistence

    /// Serialize the complete session state to a self-describing text
    /// blob.  Everything bit-identity depends on is captured: config,
    /// RNG state, raw (unfolded) SV coefficients plus the lazy scale,
    /// budget counters, and the unconsumed remainder of the current
    /// epoch.  Wall-clock buckets are carried as aggregates.
    pub fn checkpoint(&self) -> String {
        let cfg = &self.cfg;
        let mut out = String::new();
        let _ = writeln!(out, "mmbsgd-checkpoint v1");
        let _ = writeln!(out, "lambda {}", cfg.lambda);
        let _ = writeln!(out, "gamma {}", cfg.gamma);
        let _ = writeln!(out, "budget {}", cfg.budget);
        let _ = writeln!(out, "mergees {}", cfg.mergees);
        let maint = match cfg.maintenance {
            None => "auto".to_string(),
            Some(k) => k.describe(),
        };
        let _ = writeln!(out, "maintenance {maint}");
        let _ = writeln!(out, "epochs {}", cfg.epochs);
        let _ = writeln!(out, "eta0 {}", cfg.eta0);
        let _ = writeln!(out, "use_bias {}", cfg.use_bias);
        let _ = writeln!(out, "seed {}", cfg.seed);
        let _ = writeln!(out, "eval_every {}", cfg.eval_every);
        let _ = writeln!(out, "backend {}", cfg.backend.describe());
        let _ = writeln!(out, "merge_score_mode {}", cfg.merge_score_mode.describe());
        let _ = writeln!(out, "prune_eps {}", cfg.prune_eps);
        let s = self.rng.state();
        let _ = writeln!(out, "rng {} {} {} {}", s[0], s[1], s[2], s[3]);
        let _ = writeln!(out, "step {}", self.t);
        let _ = writeln!(out, "violations {}", self.violations);
        let _ = writeln!(out, "epochs_done {}", self.epochs_done);
        let _ = writeln!(out, "elapsed_s {}", self.elapsed_s);
        let _ = writeln!(out, "events {}", self.budget.events);
        let _ = writeln!(out, "total_wd {}", self.budget.total_wd);
        let _ = writeln!(out, "total_removed {}", self.budget.total_removed);
        let _ = writeln!(out, "total_merge_ops {}", self.budget.total_merge_ops);
        let _ = writeln!(out, "bias {}", self.model.bias);
        let _ = writeln!(out, "scale {}", self.model.svs.scale());
        let _ = writeln!(out, "meta {}", self.model.meta.replace('\n', " "));
        let _ = writeln!(out, "dim {}", self.model.svs.dim());
        let _ = writeln!(out, "nsv {}", self.model.svs.len());
        for j in 0..self.model.svs.len() {
            let _ = write!(out, "{}", self.model.svs.raw_alphas()[j]);
            for &v in self.model.svs.point(j) {
                let _ = write!(out, " {v}");
            }
            out.push('\n');
        }
        let rest = &self.pending[self.pos..];
        let _ = writeln!(out, "pending {}", rest.len());
        for (i, idx) in rest.iter().enumerate() {
            let _ = write!(out, "{}{}", if i > 0 { " " } else { "" }, idx);
        }
        out.push('\n');
        let _ = writeln!(out, "history {}", self.history.len());
        for p in &self.history {
            let _ = writeln!(out, "{} {} {} {}", p.step, p.accuracy, p.n_svs, p.elapsed_s);
        }
        let buckets: Vec<(&'static str, Duration, u64)> = self.times.iter().collect();
        let _ = writeln!(out, "times {}", buckets.len());
        for (name, d, n) in buckets {
            let _ = writeln!(out, "{name} {} {n}", d.as_secs_f64());
        }
        let _ = writeln!(out, "end");
        out
    }
}

/// A parsed-but-not-yet-attached checkpoint: inspect the embedded
/// config (e.g. to build the right backend) before turning it into a
/// live [`TrainSession`] with [`Checkpoint::into_session`].
pub struct Checkpoint {
    cfg: TrainConfig,
    rng_state: [u64; 4],
    t: u64,
    violations: u64,
    epochs_done: u64,
    elapsed_s: f64,
    events: u64,
    total_wd: f64,
    total_removed: u64,
    total_merge_ops: u64,
    bias: f64,
    meta: String,
    dim: usize,
    scale: f64,
    points: Vec<f32>,
    raw_alphas: Vec<f64>,
    pending: Vec<usize>,
    history: Vec<EvalPoint>,
    times: Vec<(&'static str, f64, u64)>,
}

impl Checkpoint {
    /// Parse a [`TrainSession::checkpoint`] blob.  Every malformation
    /// is a typed [`TrainError::Checkpoint`] naming the offending line.
    pub fn parse(text: &str) -> Result<Self, TrainError> {
        let mut rd = Reader { lines: text.lines().enumerate() };
        let magic = rd.line("magic")?;
        if magic.1.trim() != "mmbsgd-checkpoint v1" {
            return Err(bad(magic.0, format!("bad magic line {:?}", magic.1)));
        }
        let mut cfg = TrainConfig {
            lambda: rd.kv_parse("lambda")?,
            gamma: rd.kv_parse("gamma")?,
            budget: rd.kv_parse("budget")?,
            mergees: rd.kv_parse("mergees")?,
            ..TrainConfig::default()
        };
        let (ln, maint) = rd.kv("maintenance")?;
        cfg.maintenance = match maint.as_str() {
            "auto" => None,
            other => Some(
                MaintenanceKind::parse(other)
                    .ok_or_else(|| bad(ln, format!("bad maintenance {other:?}")))?,
            ),
        };
        cfg.epochs = rd.kv_parse("epochs")?;
        cfg.eta0 = rd.kv_parse("eta0")?;
        cfg.use_bias = rd.kv_parse("use_bias")?;
        cfg.seed = rd.kv_parse("seed")?;
        cfg.eval_every = rd.kv_parse("eval_every")?;
        let (ln, be) = rd.kv("backend")?;
        cfg.backend = BackendChoice::parse(&be)
            .ok_or_else(|| bad(ln, format!("bad backend {be:?}")))?;
        let (ln, mode) = rd.kv("merge_score_mode")?;
        cfg.merge_score_mode = MergeScoreMode::parse(&mode)
            .ok_or_else(|| bad(ln, format!("bad merge_score_mode {mode:?}")))?;
        cfg.prune_eps = rd.kv_parse("prune_eps")?;
        cfg.validate().map_err(|e| TrainError::Checkpoint(format!("embedded config: {e}")))?;

        let (ln, rng_line) = rd.kv("rng")?;
        let words: Vec<&str> = rng_line.split_ascii_whitespace().collect();
        if words.len() != 4 {
            return Err(bad(ln, format!("rng wants 4 words, got {}", words.len())));
        }
        let mut rng_state = [0u64; 4];
        for (slot, w) in rng_state.iter_mut().zip(&words) {
            *slot = w
                .parse::<u64>()
                .map_err(|_| bad(ln, format!("bad rng word {w:?}")))?;
        }

        let t = rd.kv_parse("step")?;
        let violations = rd.kv_parse("violations")?;
        let epochs_done = rd.kv_parse("epochs_done")?;
        let elapsed_s = rd.kv_parse("elapsed_s")?;
        let events = rd.kv_parse("events")?;
        let total_wd = rd.kv_parse("total_wd")?;
        let total_removed = rd.kv_parse("total_removed")?;
        let total_merge_ops = rd.kv_parse("total_merge_ops")?;
        let bias = rd.kv_parse("bias")?;
        let scale: f64 = rd.kv_parse("scale")?;
        if !(scale.is_finite() && scale != 0.0) {
            return Err(TrainError::Checkpoint(format!(
                "scale must be finite nonzero, got {scale}"
            )));
        }
        let meta = rd.kv("meta")?.1;
        let dim: usize = rd.kv_parse("dim")?;
        let nsv: usize = rd.kv_parse("nsv")?;

        // Capacity from the (untrusted) header is a hint only, clamped
        // so a forged count cannot force a huge up-front allocation;
        // the per-line reads below bound the real growth.
        let mut points = Vec::with_capacity(nsv.saturating_mul(dim).min(1 << 22));
        let mut raw_alphas = Vec::with_capacity(nsv.min(1 << 16));
        for _ in 0..nsv {
            let (ln, line) = rd.line("SV block")?;
            let mut it = line.split_ascii_whitespace();
            let a = it
                .next()
                .ok_or_else(|| bad(ln, "missing alpha".into()))?
                .parse::<f64>()
                .map_err(|_| bad(ln, "bad alpha".into()))?;
            let row: Vec<f32> = it
                .map(|w| w.parse::<f32>())
                .collect::<Result<_, _>>()
                .map_err(|_| bad(ln, "bad SV coordinate".into()))?;
            if row.len() != dim {
                return Err(bad(ln, format!("SV has {} features, expected {dim}", row.len())));
            }
            raw_alphas.push(a);
            points.extend_from_slice(&row);
        }

        let n_pending: usize = rd.kv_parse("pending")?;
        let (ln, pend_line) = rd.line("pending indices")?;
        let pending: Vec<usize> = pend_line
            .split_ascii_whitespace()
            .map(|w| w.parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|_| bad(ln, "bad pending index".into()))?;
        if pending.len() != n_pending {
            return Err(bad(
                ln,
                format!("want {n_pending} pending indices, got {}", pending.len()),
            ));
        }

        let n_hist: usize = rd.kv_parse("history")?;
        let mut history = Vec::with_capacity(n_hist.min(1 << 16));
        for _ in 0..n_hist {
            let (ln, line) = rd.line("history point")?;
            let w: Vec<&str> = line.split_ascii_whitespace().collect();
            if w.len() != 4 {
                return Err(bad(ln, format!("history point wants 4 fields, got {}", w.len())));
            }
            history.push(EvalPoint {
                step: w[0].parse().map_err(|_| bad(ln, "bad history step".into()))?,
                accuracy: w[1].parse().map_err(|_| bad(ln, "bad history accuracy".into()))?,
                n_svs: w[2].parse().map_err(|_| bad(ln, "bad history n_svs".into()))?,
                elapsed_s: w[3].parse().map_err(|_| bad(ln, "bad history elapsed".into()))?,
            });
        }

        let n_times: usize = rd.kv_parse("times")?;
        let mut times = Vec::new();
        for _ in 0..n_times {
            let (ln, line) = rd.line("time bucket")?;
            let w: Vec<&str> = line.split_ascii_whitespace().collect();
            if w.len() != 3 {
                return Err(bad(ln, format!("time bucket wants 3 fields, got {}", w.len())));
            }
            let secs: f64 = w[1].parse().map_err(|_| bad(ln, "bad bucket seconds".into()))?;
            let count: u64 = w[2].parse().map_err(|_| bad(ln, "bad bucket count".into()))?;
            if !(secs >= 0.0 && secs.is_finite()) {
                return Err(bad(ln, format!("bucket seconds must be finite >= 0, got {secs}")));
            }
            // map onto the static allowlist; unknown buckets are dropped
            if let Some(&name) = TIME_BUCKETS.iter().find(|&&n| n == w[0]) {
                times.push((name, secs, count));
            }
        }
        let (ln, endline) = rd.line("end marker")?;
        if endline != "end" {
            return Err(bad(ln, format!("expected end marker, got {endline:?}")));
        }

        Ok(Self {
            cfg,
            rng_state,
            t,
            violations,
            epochs_done,
            elapsed_s,
            events,
            total_wd,
            total_removed,
            total_merge_ops,
            bias,
            meta,
            dim,
            scale,
            points,
            raw_alphas,
            pending,
            history,
            times,
        })
    }

    /// The training config embedded in the checkpoint (e.g. to build
    /// the matching backend before [`Checkpoint::into_session`]).
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Mutable access, e.g. to extend `epochs` before resuming.
    pub fn config_mut(&mut self) -> &mut TrainConfig {
        &mut self.cfg
    }

    /// Steps already taken when the checkpoint was written.
    pub fn step(&self) -> u64 {
        self.t
    }

    pub fn epochs_done(&self) -> u64 {
        self.epochs_done
    }

    /// Attach the checkpoint to a backend, yielding a live session that
    /// continues the original run bit-identically.
    pub fn into_session<'b>(
        self,
        backend: &'b mut dyn Backend,
    ) -> Result<TrainSession<'b>, TrainError> {
        self.cfg.validate()?;
        // Provenance (`meta`) already records the original effective
        // scorer; just put the backend in the configured mode.  The
        // thread count and SIMD dispatch are execution details
        // (results are invariant to both), so neither is checkpointed —
        // and neither is the exp mode: resume runs with whatever the
        // caller configured.
        backend.set_merge_score_mode(self.cfg.merge_score_mode);
        backend.set_threads(self.cfg.threads);
        crate::kernel::simd::set_mode(self.cfg.simd_mode);
        crate::kernel::simd::set_exp_mode(self.cfg.exp_mode);
        let mut budget = Budget::new(self.cfg.budget, self.cfg.maintenance_kind());
        budget.events = self.events;
        budget.total_wd = self.total_wd;
        budget.total_removed = self.total_removed;
        budget.total_merge_ops = self.total_merge_ops;
        let mut model = SvmModel::new(0, self.cfg.gamma);
        model.svs = SvStore::from_raw(self.dim, self.points, self.raw_alphas, self.scale);
        model.bias = self.bias;
        model.meta = self.meta;
        let mut times = TimeBook::new();
        for (name, secs, count) in self.times {
            times.add_many(name, Duration::from_secs_f64(secs), count);
        }
        Ok(TrainSession {
            cfg: self.cfg,
            backend,
            model,
            budget,
            rng: Xoshiro256::from_state(self.rng_state),
            times,
            history: self.history,
            violations: self.violations,
            t: self.t,
            epochs_done: self.epochs_done,
            pending: self.pending,
            pos: 0,
            elapsed_s: self.elapsed_s,
        })
    }
}

fn bad(line_no: usize, msg: String) -> TrainError {
    TrainError::Checkpoint(format!("line {}: {msg}", line_no + 1))
}

/// Line-oriented sequential reader with positioned errors.
struct Reader<'a> {
    lines: std::iter::Enumerate<std::str::Lines<'a>>,
}

impl<'a> Reader<'a> {
    fn line(&mut self, what: &str) -> Result<(usize, &'a str), TrainError> {
        self.lines
            .next()
            .ok_or_else(|| TrainError::Checkpoint(format!("truncated: missing {what}")))
    }

    /// Read `key <value>`; returns (line_no, value).
    fn kv(&mut self, key: &str) -> Result<(usize, String), TrainError> {
        let (n, line) = self.line(key)?;
        let (k, v) = line.split_once(' ').unwrap_or((line, ""));
        if k != key {
            return Err(bad(n, format!("expected key {key:?}, got {k:?}")));
        }
        Ok((n, v.to_string()))
    }

    fn kv_parse<T: std::str::FromStr>(&mut self, key: &str) -> Result<T, TrainError> {
        let (n, v) = self.kv(key)?;
        v.parse::<T>().map_err(|_| bad(n, format!("bad {key} value {v:?}")))
    }
}

// ------------------------------------------------- durable file loads

/// A checkpoint read back from disk, recording which generation
/// satisfied the load.
#[derive(Debug)]
pub struct LoadedCheckpoint {
    pub checkpoint: Checkpoint,
    /// `Primary` when `<path>` itself verified and parsed; `Prev` when
    /// the load fell back to the `<path>.prev` last-good generation.
    pub generation: durable::Generation,
    /// Why the primary was rejected, when `generation == Prev`.
    pub primary_error: Option<String>,
}

/// One load failure, positioned: which section gave out and at which
/// byte of the file.
struct LoadFailure {
    section: &'static str,
    offset: u64,
    detail: String,
}

/// Byte offset of the 1-based line named by a `"line N: ..."` parse
/// message; 0 when the message carries no position.
fn line_byte_offset(text: &str, msg: &str) -> u64 {
    let n: usize = msg
        .strip_prefix("line ")
        .and_then(|rest| rest.split(':').next())
        .and_then(|digits| digits.trim().parse().ok())
        .unwrap_or(0);
    if n <= 1 {
        return 0;
    }
    let mut offset = 0u64;
    for (i, line) in text.lines().enumerate() {
        if i + 1 == n {
            break;
        }
        offset += line.len() as u64 + 1;
    }
    offset
}

fn load_one(path: &std::path::Path) -> Result<Checkpoint, LoadFailure> {
    let payload = durable::read_verified(path).map_err(|e| match e {
        durable::DurableError::Io { detail, .. } => {
            LoadFailure { section: "io", offset: 0, detail }
        }
        durable::DurableError::Corrupt { section, offset, detail, .. } => {
            LoadFailure { section, offset, detail }
        }
    })?;
    Checkpoint::parse(&payload).map_err(|e| match e {
        TrainError::Checkpoint(msg) => LoadFailure {
            section: "body",
            offset: line_byte_offset(&payload, &msg),
            detail: msg,
        },
        other => LoadFailure { section: "body", offset: 0, detail: other.to_string() },
    })
}

/// Load a checkpoint file through the durable layer: verify the
/// checksum footer, parse, and — when either fails — fall back to the
/// `<path>.prev` last-good generation.  When both generations are
/// unusable the error is [`TrainError::CorruptCheckpoint`], naming the
/// failing section, the byte offset, and whether a `.prev` existed.
pub fn load_checkpoint(path: &std::path::Path) -> Result<LoadedCheckpoint, TrainError> {
    let primary = match load_one(path) {
        Ok(checkpoint) => {
            return Ok(LoadedCheckpoint {
                checkpoint,
                generation: durable::Generation::Primary,
                primary_error: None,
            })
        }
        Err(f) => f,
    };
    let prev = durable::prev_path(path);
    let prev_exists = prev.exists();
    let mut detail = primary.detail.clone();
    if prev_exists {
        match load_one(&prev) {
            Ok(checkpoint) => {
                return Ok(LoadedCheckpoint {
                    checkpoint,
                    generation: durable::Generation::Prev,
                    primary_error: Some(format!(
                        "{} at byte {}: {}",
                        primary.section, primary.offset, primary.detail
                    )),
                })
            }
            Err(pf) => {
                detail.push_str(&format!("; .prev also failed: {}: {}", pf.section, pf.detail));
            }
        }
    }
    Err(TrainError::CorruptCheckpoint {
        path: path.display().to_string(),
        section: primary.section.to_string(),
        offset: primary.offset,
        prev_exists,
        detail,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{dataset, SynthSpec};
    use crate::runtime::NativeBackend;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget: 24,
            mergees: 3,
            seed: 9,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn new_rejects_invalid_config() {
        let mut be = NativeBackend::new();
        let mut cfg = tiny_cfg();
        cfg.budget = 0;
        match TrainSession::new(cfg, &mut be) {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "budget"),
            other => panic!("expected InvalidConfig, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn step_binds_dim_then_rejects_mismatch() {
        let mut be = NativeBackend::new();
        let mut sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        let x3 = [0.1f32, 0.2, 0.3];
        sess.step(&Sample { x: &x3, y: 1.0 }).unwrap();
        let x2 = [0.1f32, 0.2];
        assert_eq!(
            sess.step(&Sample { x: &x2, y: 1.0 }).unwrap_err(),
            TrainError::DimMismatch { expected: 3, got: 2 }
        );
        assert_eq!(sess.steps(), 1);
    }

    #[test]
    fn run_epoch_empty_dataset_is_typed() {
        let mut be = NativeBackend::new();
        let mut sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        let empty = Dataset::new(crate::data::DenseMatrix::zeros(0, 2), vec![], "e");
        assert_eq!(
            sess.run_epoch(&empty, None, &mut NoopObserver, 0).unwrap_err(),
            TrainError::EmptyDataset
        );
    }

    #[test]
    fn checkpoint_text_roundtrips_through_parse() {
        let split = dataset(&SynthSpec::ijcnn_like(0.01), 4);
        let mut be = NativeBackend::new();
        let mut sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        // stop mid-epoch so pending indices are non-trivial
        let done = sess.run_epoch(&split.train, None, &mut NoopObserver, 57).unwrap();
        assert!(!done);
        let blob = sess.checkpoint();
        let ck = Checkpoint::parse(&blob).unwrap();
        assert_eq!(ck.step(), 57);
        assert_eq!(ck.config().budget, 24);
        assert_eq!(ck.pending.len(), split.train.len() - 57);
        // a resumed session re-serializes to the identical blob
        let mut be2 = NativeBackend::new();
        let resumed = ck.into_session(&mut be2).unwrap();
        assert_eq!(resumed.checkpoint(), blob);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(matches!(Checkpoint::parse(""), Err(TrainError::Checkpoint(_))));
        assert!(matches!(
            Checkpoint::parse("wrong magic\n"),
            Err(TrainError::Checkpoint(_))
        ));
        // valid prefix, truncated body
        let mut be = NativeBackend::new();
        let sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        let blob = sess.checkpoint();
        let cut = &blob[..blob.len() / 2];
        assert!(matches!(Checkpoint::parse(cut), Err(TrainError::Checkpoint(_))));
        // flipped field order
        let swapped = blob.replacen("lambda", "gamma", 1);
        assert!(matches!(Checkpoint::parse(&swapped), Err(TrainError::Checkpoint(_))));
    }

    #[test]
    fn parse_rejects_invalid_embedded_config() {
        let mut be = NativeBackend::new();
        let sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        let blob = sess.checkpoint().replace("budget 24", "budget 1");
        match Checkpoint::parse(&blob) {
            Err(TrainError::Checkpoint(msg)) => assert!(msg.contains("budget"), "{msg}"),
            other => panic!("expected Checkpoint error, got {:?}", other.map(|_| ()).err()),
        }
    }

    #[test]
    fn resume_against_wrong_dataset_is_detected() {
        let split = dataset(&SynthSpec::ijcnn_like(0.01), 4);
        let mut be = NativeBackend::new();
        let mut sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        sess.run_epoch(&split.train, None, &mut NoopObserver, 10).unwrap();
        let blob = sess.checkpoint();
        let mut be2 = NativeBackend::new();
        let mut resumed = TrainSession::resume(&blob, &mut be2).unwrap();
        // a much smaller dataset invalidates the pending indices
        let small = split.train.gather(&[0, 1, 2]);
        let err = resumed.run_epoch(&small, None, &mut NoopObserver, 0).unwrap_err();
        assert!(matches!(err, TrainError::Checkpoint(_)), "{err}");
    }

    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mmbsgd_session_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_checkpoint_verifies_and_falls_back_to_prev() {
        let split = dataset(&SynthSpec::ijcnn_like(0.01), 4);
        let mut be = NativeBackend::new();
        let mut sess = TrainSession::new(tiny_cfg(), &mut be).unwrap();
        sess.run_epoch(&split.train, None, &mut NoopObserver, 40).unwrap();
        let gen1 = sess.checkpoint();
        sess.run_epoch(&split.train, None, &mut NoopObserver, 40).unwrap();
        let gen2 = sess.checkpoint();

        let dir = scratch_dir("loadck");
        let p = dir.join("ck.txt");
        durable::write_atomic(&p, &gen1).unwrap();
        durable::write_atomic(&p, &gen2).unwrap();

        // clean primary
        let loaded = load_checkpoint(&p).unwrap();
        assert_eq!(loaded.generation, durable::Generation::Primary);
        assert!(loaded.primary_error.is_none());
        assert_eq!(loaded.checkpoint.t, 80);

        // flip a payload byte → checksum rejects primary, .prev serves
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, text.replacen("step 80", "step 81", 1)).unwrap();
        let loaded = load_checkpoint(&p).unwrap();
        assert_eq!(loaded.generation, durable::Generation::Prev);
        assert_eq!(loaded.checkpoint.t, 40);
        let why = loaded.primary_error.unwrap();
        assert!(why.contains("payload"), "{why}");

        // both generations corrupt → typed error naming everything
        std::fs::write(durable::prev_path(&p), "garbage").unwrap();
        match load_checkpoint(&p) {
            Err(TrainError::CorruptCheckpoint { section, prev_exists, detail, .. }) => {
                assert_eq!(section, "payload");
                assert!(prev_exists);
                assert!(detail.contains(".prev also failed"), "{detail}");
            }
            other => panic!("expected CorruptCheckpoint, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_checkpoint_without_prev_says_so() {
        let dir = scratch_dir("noprev");
        let p = dir.join("ck.txt");
        durable::write_atomic(&p, "not a checkpoint\n").unwrap();
        match load_checkpoint(&p) {
            Err(TrainError::CorruptCheckpoint { section, prev_exists, .. }) => {
                assert_eq!(section, "body");
                assert!(!prev_exists);
            }
            other => panic!("expected CorruptCheckpoint, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn line_byte_offset_points_at_the_line() {
        let text = "aa\nbbb\ncccc\n";
        assert_eq!(line_byte_offset(text, "line 1: x"), 0);
        assert_eq!(line_byte_offset(text, "line 2: x"), 3);
        assert_eq!(line_byte_offset(text, "line 3: x"), 7);
        assert_eq!(line_byte_offset(text, "no position"), 0);
    }
}

//! Unbudgeted Pegasos SGD — the B → ∞ limit of BSGD.
//!
//! Kept as an explicit entry point (rather than "BSGD with huge B") so
//! examples and ablations can state their baseline precisely, and so the
//! model metadata records the solver honestly.

use super::bsgd::{self, TrainOutput};
use super::Observer;
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::error::TrainError;
use crate::runtime::Backend;

/// Train unbudgeted Pegasos: identical SGD dynamics, no maintenance.
pub fn train_full(
    ds: &Dataset,
    cfg: &TrainConfig,
    backend: &mut dyn Backend,
    eval: Option<&Dataset>,
    obs: &mut dyn Observer,
) -> Result<TrainOutput, TrainError> {
    let mut cfg = cfg.clone();
    // A budget no stream of len*epochs steps can exceed.
    cfg.budget = ds.len() * cfg.epochs.max(1) + 2;
    let mut out = bsgd::train_full(ds, &cfg, backend, eval, obs)?;
    out.model.meta = format!("pegasos seed={} backend={}", cfg.seed, backend.name());
    debug_assert_eq!(out.maintenance_events, 0);
    Ok(out)
}

/// Convenience wrapper with the native backend.
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> Result<TrainOutput, TrainError> {
    let mut backend = crate::runtime::NativeBackend::new();
    train_full(ds, cfg, &mut backend, None, &mut super::NoopObserver)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{dataset, SynthSpec};

    #[test]
    fn never_maintains_and_beats_budgeted_small_b() {
        let split = dataset(&SynthSpec::ijcnn_like(0.02), 3);
        let cfg = TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            epochs: 1,
            seed: 5,
            ..TrainConfig::default()
        };
        let unb = train(&split.train, &cfg).unwrap();
        assert_eq!(unb.maintenance_events, 0);
        let acc_unb = unb.model.accuracy(&split.test);

        let mut cfg_b = cfg.clone();
        cfg_b.budget = 8; // brutally small budget
        let bud = bsgd::train(&split.train, &cfg_b).unwrap();
        let acc_bud = bud.model.accuracy(&split.test);
        assert!(
            acc_unb >= acc_bud - 0.02,
            "unbudgeted {acc_unb} should not lose to B=8 {acc_bud}"
        );
    }
}

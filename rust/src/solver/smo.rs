//! SMO dual solver — the "exact" LIBSVM reference of Table 2.
//!
//! C-SVC dual: minimize ½ αᵀQα − eᵀα s.t. yᵀα = 0, 0 ≤ α ≤ C, with
//! Q_ij = y_i y_j k(x_i, x_j).  Working-set selection is LIBSVM's
//! second-order WSS (Fan, Chen, Lin 2005); kernel rows go through an LRU
//! [`RowCache`].  Shrinking is intentionally omitted (simplicity over
//! speed; the experiment drivers subsample large datasets instead — the
//! reference solver only has to produce Table 2-grade accuracies and SV
//! counts, not LIBSVM-grade wall-clock).

use crate::data::Dataset;
use crate::kernel::{Gaussian, Kernel, RowCache};
use crate::model::SvmModel;

const TAU: f64 = 1e-12;

#[derive(Clone, Debug)]
pub struct SmoParams {
    pub c: f64,
    pub gamma: f64,
    /// KKT-violation stopping tolerance (LIBSVM default 1e-3).
    pub eps: f64,
    /// Hard iteration cap (0 ⇒ LIBSVM-style 100·n, at least 10⁷ pairs).
    pub max_iter: usize,
    /// Kernel row-cache capacity in rows.
    pub cache_rows: usize,
}

impl Default for SmoParams {
    fn default() -> Self {
        Self { c: 1.0, gamma: 1.0, eps: 1e-3, max_iter: 0, cache_rows: 512 }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SmoStats {
    pub iterations: usize,
    pub objective: f64,
    pub n_sv: usize,
    pub n_bounded_sv: usize,
    pub converged: bool,
}

/// Solve the dual and return the model + stats.
pub fn train(ds: &Dataset, params: &SmoParams) -> (SvmModel, SmoStats) {
    let n = ds.len();
    assert!(n >= 2, "SMO needs at least two points");
    let kern = Gaussian::new(params.gamma);
    let c = params.c;
    let y: Vec<f64> = ds.y.iter().map(|&v| v as f64).collect();

    let mut alpha = vec![0.0f64; n];
    // G_i = (Qα)_i − 1; starts at −1 with α = 0.
    let mut grad = vec![-1.0f64; n];
    let mut cache = RowCache::new(params.cache_rows.max(2));

    let max_iter = if params.max_iter == 0 {
        (100 * n).max(10_000_000 / n.max(1)).max(1000)
    } else {
        params.max_iter
    };

    // Kernel row fetcher (K, not Q — signs applied at use sites).
    let row = |cache: &mut RowCache, t: usize| -> Vec<f64> {
        cache
            .get(t, || {
                let xt = ds.x.row(t);
                (0..n).map(|u| kern.eval(xt, ds.x.row(u))).collect()
            })
            .to_vec()
    };

    let mut iter = 0usize;
    let mut converged = false;
    while iter < max_iter {
        // ---- working-set selection (second order) ----
        let mut gmax = f64::NEG_INFINITY;
        let mut i = usize::MAX;
        for t in 0..n {
            let up = (y[t] > 0.0 && alpha[t] < c) || (y[t] < 0.0 && alpha[t] > 0.0);
            if up {
                let v = -y[t] * grad[t];
                if v >= gmax {
                    gmax = v;
                    i = t;
                }
            }
        }
        if i == usize::MAX {
            converged = true;
            break;
        }
        let k_i = row(&mut cache, i);

        // M(α) = min over I_low of −y_t G_t; stop when m(α) − M(α) < eps.
        let mut gmin2 = f64::INFINITY;
        let mut j = usize::MAX;
        let mut obj_min = f64::INFINITY;
        for t in 0..n {
            let low = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < c);
            if !low {
                continue;
            }
            let neg_ygt = -y[t] * grad[t];
            gmin2 = gmin2.min(neg_ygt);
            let grad_diff = gmax - neg_ygt; // = m(α) + y_t G_t > 0 for violators
            if grad_diff > 0.0 {
                let quad = 2.0 - 2.0 * y[i] * y[t] * k_i[t]; // K_ii + K_tt − 2Q̃; K_ss = 1 (RBF)
                let quad = if quad > TAU { quad } else { TAU };
                let obj = -(grad_diff * grad_diff) / quad;
                if obj <= obj_min {
                    obj_min = obj;
                    j = t;
                }
            }
        }
        // Stop: maximal KKT violation below eps.
        if gmax - gmin2 < params.eps || j == usize::MAX {
            converged = true;
            break;
        }
        let k_j = row(&mut cache, j);

        // ---- two-variable subproblem (LIBSVM update + clipping) ----
        let (old_ai, old_aj) = (alpha[i], alpha[j]);
        if y[i] != y[j] {
            let quad = 2.0 + 2.0 * k_i[j]; // QD_i + QD_j + 2 Q_ij with y_i≠y_j
            let quad = if quad > TAU { quad } else { TAU };
            let delta = (-grad[i] - grad[j]) / quad;
            let diff = alpha[i] - alpha[j];
            alpha[i] += delta;
            alpha[j] += delta;
            if diff > 0.0 {
                if alpha[j] < 0.0 {
                    alpha[j] = 0.0;
                    alpha[i] = diff;
                }
            } else if alpha[i] < 0.0 {
                alpha[i] = 0.0;
                alpha[j] = -diff;
            }
            if diff > 0.0 {
                if alpha[i] > c {
                    alpha[i] = c;
                    alpha[j] = c - diff;
                }
            } else if alpha[j] > c {
                alpha[j] = c;
                alpha[i] = c + diff;
            }
        } else {
            let quad = 2.0 - 2.0 * k_i[j];
            let quad = if quad > TAU { quad } else { TAU };
            let delta = (grad[i] - grad[j]) / quad;
            let sum = alpha[i] + alpha[j];
            alpha[i] -= delta;
            alpha[j] += delta;
            if sum > c {
                if alpha[i] > c {
                    alpha[i] = c;
                    alpha[j] = sum - c;
                }
            } else if alpha[j] < 0.0 {
                alpha[j] = 0.0;
                alpha[i] = sum;
            }
            if sum > c {
                if alpha[j] > c {
                    alpha[j] = c;
                    alpha[i] = sum - c;
                }
            } else if alpha[i] < 0.0 {
                alpha[i] = 0.0;
                alpha[j] = sum;
            }
        }

        // ---- gradient update ----
        let (dai, daj) = (alpha[i] - old_ai, alpha[j] - old_aj);
        if dai != 0.0 || daj != 0.0 {
            for t in 0..n {
                grad[t] += y[t] * (y[i] * k_i[t] * dai + y[j] * k_j[t] * daj);
            }
        }
        iter += 1;
    }

    // ---- bias: ρ from the free SVs / bound midpoint (LIBSVM) ----
    let mut nr_free = 0usize;
    let mut sum_free = 0.0;
    let mut ub = f64::INFINITY;
    let mut lb = f64::NEG_INFINITY;
    for t in 0..n {
        let ygt = y[t] * grad[t];
        if alpha[t] >= c {
            if y[t] < 0.0 {
                ub = ub.min(ygt);
            } else {
                lb = lb.max(ygt);
            }
        } else if alpha[t] <= 0.0 {
            if y[t] > 0.0 {
                ub = ub.min(ygt);
            } else {
                lb = lb.max(ygt);
            }
        } else {
            nr_free += 1;
            sum_free += ygt;
        }
    }
    let rho = if nr_free > 0 { sum_free / nr_free as f64 } else { (ub + lb) / 2.0 };

    // ---- objective ½αᵀQα − eᵀα = ½ Σ α_i (G_i − 1) ----
    let objective: f64 =
        0.5 * alpha.iter().zip(&grad).map(|(&a, &g)| a * (g - 1.0)).sum::<f64>();

    // ---- assemble the model: coefficients α_i y_i, bias −ρ ----
    let mut model = SvmModel::new(ds.dim(), params.gamma);
    let mut n_sv = 0usize;
    let mut n_bsv = 0usize;
    for t in 0..n {
        if alpha[t] > 0.0 {
            n_sv += 1;
            if alpha[t] >= c {
                n_bsv += 1;
            }
            model.svs.push(ds.x.row(t), alpha[t] * y[t]);
        }
    }
    model.bias = -rho;
    model.meta = format!(
        "smo C={} gamma={} eps={} iters={iter} converged={converged}",
        params.c, params.gamma, params.eps
    );

    (
        model,
        SmoStats { iterations: iter, objective, n_sv, n_bounded_sv: n_bsv, converged },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{dataset, SynthSpec};
    use crate::data::{Dataset, DenseMatrix};

    fn xor_like() -> Dataset {
        // 2D four-cluster XOR — linearly inseparable, RBF-separable.
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for (cx, cy, l) in
            [(0.0, 0.0, 1.0), (1.0, 1.0, 1.0), (0.0, 1.0, -1.0), (1.0, 0.0, -1.0)]
        {
            for i in 0..25 {
                let (dx, dy) = ((i % 5) as f32 * 0.02, (i / 5) as f32 * 0.02);
                rows.push(vec![cx as f32 + dx, cy as f32 + dy]);
                labels.push(l);
            }
        }
        Dataset::new(DenseMatrix::from_rows(rows), labels, "xor")
    }

    #[test]
    fn solves_xor_exactly() {
        let ds = xor_like();
        let (model, stats) = train(&ds, &SmoParams { c: 10.0, gamma: 4.0, ..Default::default() });
        assert!(stats.converged, "did not converge in {} iters", stats.iterations);
        assert_eq!(model.accuracy(&ds), 1.0);
        assert!(stats.n_sv > 0 && stats.n_sv <= ds.len());
    }

    #[test]
    fn dual_feasibility_holds() {
        let ds = xor_like();
        let c = 5.0;
        let (model, _) = train(&ds, &SmoParams { c, gamma: 4.0, ..Default::default() });
        // every |coef| = α ≤ C and Σ coef = Σ α y ≈ 0
        let mut sum = 0.0;
        for j in 0..model.svs.len() {
            let a = model.svs.alpha(j);
            assert!(a.abs() <= c + 1e-9, "coef {a} above C");
            sum += a;
        }
        assert!(sum.abs() < 1e-6, "equality constraint violated: {sum}");
    }

    #[test]
    fn beats_bsgd_on_accuracy_tiny() {
        // The "exact" solver must match or beat a budgeted SGD run.
        let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
        let (model, stats) = train(
            &split.train,
            &SmoParams { c: 32.0, gamma: 2.0, ..Default::default() },
        );
        assert!(stats.converged);
        let acc = model.accuracy(&split.test);
        assert!(acc > 0.9, "SMO accuracy {acc}");
    }

    #[test]
    fn objective_decreases_with_more_freedom() {
        // Larger C must reach an equal-or-lower (more negative) dual
        // objective value on the same data.
        let ds = xor_like();
        let (_, s1) = train(&ds, &SmoParams { c: 0.1, gamma: 4.0, ..Default::default() });
        let (_, s2) = train(&ds, &SmoParams { c: 10.0, gamma: 4.0, ..Default::default() });
        assert!(s2.objective <= s1.objective + 1e-9);
    }

    #[test]
    fn respects_iteration_cap() {
        let ds = xor_like();
        let (_, stats) = train(
            &ds,
            &SmoParams { c: 10.0, gamma: 4.0, max_iter: 3, ..Default::default() },
        );
        assert!(stats.iterations <= 3);
    }
}

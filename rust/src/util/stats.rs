//! Small statistics helpers shared by benches, metrics and experiments.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation; 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on the sorted copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Extract the Pareto front of (cost, quality) points: a point survives if
/// no other point is both cheaper and at least as good (strictly better in
/// one dimension).  Used by the Figure 4 driver.  Returns indices sorted
/// by cost.
pub fn pareto_front(cost: &[f64], quality: &[f64]) -> Vec<usize> {
    assert_eq!(cost.len(), quality.len());
    let mut idx: Vec<usize> = (0..cost.len()).collect();
    idx.sort_by(|&a, &b| cost[a].partial_cmp(&cost[b]).unwrap());
    let mut front = Vec::new();
    let mut best_q = f64::NEG_INFINITY;
    for &i in &idx {
        if quality[i] > best_q {
            front.push(i);
            best_q = quality[i];
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn pareto() {
        // (cost, quality): points b and d dominate; a dominated by b.
        let cost = [2.0, 1.0, 3.0, 2.5];
        let qual = [0.8, 0.9, 0.7, 0.95];
        let front = pareto_front(&cost, &qual);
        assert_eq!(front, vec![1, 3]);
    }

    #[test]
    fn pareto_single_and_ties() {
        assert_eq!(pareto_front(&[1.0], &[1.0]), vec![0]);
        // Equal quality at higher cost is dominated.
        assert_eq!(pareto_front(&[1.0, 2.0], &[0.5, 0.5]), vec![0]);
    }
}

//! Deterministic fault injection for crash/recovery tests.
//!
//! A [`FaultPlan`] is a list of rules `site@N=kind[:arg]`, separated by
//! `;`: the `N`th time (1-based) execution passes the named injection
//! site, the given fault fires.  Sites are compile-time string
//! constants (see [`site`]); the kinds are:
//!
//! | kind          | effect at the site                               |
//! |---------------|--------------------------------------------------|
//! | `io`          | the operation fails with an injected IO error    |
//! | `truncate:K`  | a durable write is torn after `K` bytes          |
//! | `panic`       | the site panics (worker-pool containment tests)  |
//! | `stall:MS`    | the site sleeps `MS` milliseconds (slow peer)    |
//!
//! Example: `durable.write@2=truncate:64;libsvm.read@1=io` tears the
//! second durable write at byte 64 and fails the first LIBSVM read.
//!
//! Plans arrive via [`install`] (tests), the `MMBSGD_FAULT_PLAN`
//! environment variable, or a `[fault] plan = "..."` TOML section
//! handled by the CLI.  The whole machinery is gated behind the
//! `fault-inject` cargo feature: without it [`armed`] is an
//! `#[inline(always)]` `None`, so production binaries carry the call
//! sites but none of the bookkeeping.
//!
//! State is process-global (a mutex-guarded plan plus per-site hit
//! counters), so tests that install plans must serialize themselves —
//! `tests/fault_matrix.rs` shares one lock for this.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// Injection-site names. Each constant appears at exactly one hook in
/// the codebase; the doc comment says where.
pub mod site {
    /// [`crate::util::durable::write_atomic`]: fail or tear the write.
    pub const DURABLE_WRITE: &str = "durable.write";
    /// [`crate::data::libsvm::load`]: fail the file read or truncate
    /// the text before parsing.
    pub const LIBSVM_READ: &str = "libsvm.read";
    /// A `WorkerPool` job body: panic inside the pool's `catch_unwind`.
    pub const POOL_JOB: &str = "pool.job";
    /// The per-connection read loop in `serve/proto.rs`: stall the
    /// reader or drop the connection.
    pub const PROTO_READ: &str = "proto.read";
    /// The per-connection read loop in `serve/http.rs` (head and body
    /// accumulation): stall the reader or drop the connection, counted
    /// by `serve_http_read_errors_total`.
    pub const HTTP_READ: &str = "http.read";
    /// [`crate::util::durable::read_artifact_verified`]: fail the
    /// artifact read with `io` or tear the text at `truncate:K` before
    /// verification.  Covers both fleet bundle loads
    /// (`fleet::Artifact::load`) and the AOT registry manifest scan
    /// (`runtime::ArtifactRegistry::load`).
    pub const ARTIFACT_READ: &str = "artifact.read";
    /// The controller's artifact push in `fleet::control`: an `io`
    /// rule tears the push mid-payload (header + partial bytes, then
    /// the connection drops), so the replica must stay on last-good.
    pub const FLEET_PUSH: &str = "fleet.push";
    /// A pooled replica link in `fleet::router`: `io` breaks the link
    /// before any bytes move (the router must discard that one link,
    /// retry over a fresh one, and NOT mark the replica dead);
    /// `stall:MS` delays the exchange like a slow replica link.
    pub const ROUTER_LINK: &str = "router.link";
}

/// What happens when a rule fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation reports an injected IO error.
    Io,
    /// A durable write is torn after this many bytes.
    Truncate(usize),
    /// The site panics.
    Panic,
    /// The site sleeps this many milliseconds.
    Stall(u64),
}

#[derive(Clone, Debug, PartialEq, Eq)]
struct Rule {
    site: String,
    nth: u64,
    kind: FaultKind,
}

/// A parsed set of injection rules. Empty plans are valid and inert.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// Parse the `site@N=kind[:arg];...` grammar. Whitespace around
    /// rules and tokens is ignored; empty rules (trailing `;`) are
    /// skipped. Errors are human-readable strings naming the rule.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (lhs, rhs) =
                part.split_once('=').ok_or_else(|| format!("rule {part:?} lacks '='"))?;
            let (site, nth) = lhs
                .split_once('@')
                .ok_or_else(|| format!("rule {part:?} lacks a 'site@N' left side"))?;
            let nth: u64 = nth
                .trim()
                .parse()
                .map_err(|_| format!("rule {part:?}: bad occurrence number {:?}", nth.trim()))?;
            if nth == 0 {
                return Err(format!("rule {part:?}: occurrence numbers are 1-based"));
            }
            let (kind_name, arg) = match rhs.split_once(':') {
                Some((k, a)) => (k.trim(), Some(a.trim())),
                None => (rhs.trim(), None),
            };
            let kind = match (kind_name, arg) {
                ("io", None) => FaultKind::Io,
                ("panic", None) => FaultKind::Panic,
                ("truncate", Some(a)) => FaultKind::Truncate(
                    a.parse()
                        .map_err(|_| format!("rule {part:?}: bad truncate byte count {a:?}"))?,
                ),
                ("stall", Some(a)) => FaultKind::Stall(
                    a.parse()
                        .map_err(|_| format!("rule {part:?}: bad stall milliseconds {a:?}"))?,
                ),
                _ => {
                    return Err(format!(
                        "rule {part:?}: unknown kind {rhs:?} \
                         (want io | truncate:K | panic | stall:MS)"
                    ))
                }
            };
            rules.push(Rule { site: site.trim().to_string(), nth, kind });
        }
        Ok(FaultPlan { rules })
    }

    /// True when the plan has no rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

/// True when the binary was compiled with fault injection enabled.
/// Lets the CLI warn when a plan is supplied to a build that will
/// silently ignore it.
pub const ENABLED: bool = cfg!(feature = "fault-inject");

struct Active {
    plan: FaultPlan,
    counts: HashMap<String, u64>,
    fired: u64,
}

fn slot() -> &'static Mutex<Option<Active>> {
    static SLOT: OnceLock<Mutex<Option<Active>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install a plan, resetting all per-site counters. Overrides any
/// previously installed or env-derived plan.
pub fn install(plan: FaultPlan) {
    let mut g = slot().lock().unwrap_or_else(|p| p.into_inner());
    *g = Some(Active { plan, counts: HashMap::new(), fired: 0 });
}

/// Remove the active plan. The next [`armed`] call under the
/// `fault-inject` feature re-reads `MMBSGD_FAULT_PLAN` (usually unset
/// in tests, leaving injection off).
pub fn clear() {
    let mut g = slot().lock().unwrap_or_else(|p| p.into_inner());
    *g = None;
}

/// Number of rules that have fired since the plan was installed.
pub fn fired() -> u64 {
    let g = slot().lock().unwrap_or_else(|p| p.into_inner());
    g.as_ref().map(|a| a.fired).unwrap_or(0)
}

/// The hook every injection site calls: counts the visit and returns
/// the fault to apply, if a rule matches this site at this visit.
///
/// With the `fault-inject` feature off this is an inlined `None`; the
/// visit is not even counted.
#[cfg(feature = "fault-inject")]
pub fn armed(site_name: &str) -> Option<FaultKind> {
    let mut g = slot().lock().unwrap_or_else(|p| p.into_inner());
    if g.is_none() {
        let plan = match std::env::var("MMBSGD_FAULT_PLAN") {
            Ok(s) => FaultPlan::parse(&s).unwrap_or_else(|e| {
                eprintln!("[warn ] MMBSGD_FAULT_PLAN ignored: {e}");
                FaultPlan::default()
            }),
            Err(_) => FaultPlan::default(),
        };
        *g = Some(Active { plan, counts: HashMap::new(), fired: 0 });
    }
    let a = g.as_mut().expect("slot populated above");
    if a.plan.rules.is_empty() {
        return None;
    }
    let c = a.counts.entry(site_name.to_string()).or_insert(0);
    *c += 1;
    let visit = *c;
    let hit = a
        .plan
        .rules
        .iter()
        .find(|r| r.site == site_name && r.nth == visit)
        .map(|r| r.kind);
    if hit.is_some() {
        a.fired += 1;
    }
    hit
}

/// Feature-off stub: no counting, no locking, no fault.
#[cfg(not(feature = "fault-inject"))]
#[inline(always)]
pub fn armed(_site_name: &str) -> Option<FaultKind> {
    None
}

/// Convenience for sites whose only meaningful fault is a panic
/// (worker-pool jobs): panics iff a `panic` rule fires here.
pub fn fire_panic(site_name: &str) {
    if let Some(FaultKind::Panic) = armed(site_name) {
        panic!("injected fault: panic at {site_name}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_grammar_parses() {
        let p = FaultPlan::parse("durable.write@2=truncate:64; libsvm.read@1=io").unwrap();
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].site, "durable.write");
        assert_eq!(p.rules[0].nth, 2);
        assert_eq!(p.rules[0].kind, FaultKind::Truncate(64));
        assert_eq!(p.rules[1].kind, FaultKind::Io);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty());
        let p = FaultPlan::parse("pool.job@1=panic;proto.read@3=stall:250").unwrap();
        assert_eq!(p.rules[0].kind, FaultKind::Panic);
        assert_eq!(p.rules[1].kind, FaultKind::Stall(250));
    }

    #[test]
    fn plan_grammar_rejects_malformed() {
        for bad in [
            "durable.write",           // no '='
            "durable.write=io",        // no '@N'
            "durable.write@0=io",      // 0-based
            "durable.write@x=io",      // non-numeric N
            "durable.write@1=explode", // unknown kind
            "durable.write@1=truncate",   // missing arg
            "durable.write@1=truncate:x", // bad arg
            "proto.read@1=stall",         // missing arg
            "durable.write@1=io:5",       // io takes no arg
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn armed_fires_on_nth_visit_only() {
        // Serialized against other fault tests by virtue of living in
        // this module alone; integration tests use their own lock.
        install(FaultPlan::parse("t.site@2=io").unwrap());
        assert_eq!(armed("t.site"), None);
        assert_eq!(armed("t.other"), None);
        assert_eq!(armed("t.site"), Some(FaultKind::Io));
        assert_eq!(armed("t.site"), None);
        assert_eq!(fired(), 1);
        clear();
    }
}

//! Aligned console tables + CSV output for the experiment drivers.
//!
//! Every experiment driver (`exp/*`) prints its result twice: a
//! human-readable aligned table mirroring the paper's layout, and a CSV
//! file under `results/` for plotting.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Render with per-column alignment (numbers right, text left).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, cell) in row.iter().enumerate() {
                width[i] = width[i].max(cell.chars().count());
            }
        }
        let numeric: Vec<bool> = (0..ncol)
            .map(|i| {
                !self.rows.is_empty()
                    && self.rows.iter().all(|r| r[i].parse::<f64>().is_ok() || r[i] == "-")
            })
            .collect();
        let mut out = String::new();
        let fmt_row = |row: &[String], out: &mut String| {
            for (i, cell) in row.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let pad = width[i] - cell.chars().count();
                if numeric[i] {
                    let _ = write!(out, "{}{}", " ".repeat(pad), cell);
                } else {
                    let _ = write!(out, "{}{}", cell, " ".repeat(pad));
                }
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// CSV rendering (minimal quoting: fields containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let line = |row: &[String]| row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",");
        out.push_str(&line(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Write the CSV under `dir/name.csv`, creating `dir` if needed.
    pub fn save_csv(&self, dir: &Path, name: &str) -> io::Result<std::path::PathBuf> {
        fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format a float with fixed decimals, trimming "-0.000" to "0.000".
pub fn num(x: f64, decimals: usize) -> String {
    let s = format!("{:.*}", decimals, x);
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "val"]);
        t.row(vec!["abc".into(), "1.25".into()]);
        t.row(vec!["d".into(), "10.5".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // numeric column right-aligned
        assert!(lines[2].ends_with("1.25"));
        assert!(lines[3].ends_with("10.5"));
    }

    #[test]
    fn csv_quotes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"q\"\"q\"\n");
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn num_formats() {
        assert_eq!(num(1.23456, 2), "1.23");
        assert_eq!(num(-1e-12, 3), "0.000");
    }
}

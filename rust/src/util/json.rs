//! Minimal JSON parser + serializer.
//!
//! Purpose-built for the two JSON surfaces of this project: reading
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) and
//! writing experiment/metric dumps.  Supports the full JSON value model
//! (objects, arrays, strings with escapes, numbers, bools, null); numbers
//! are held as `f64`, which is exact for every integer the manifest
//! contains (shapes ≤ 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our
                            // producers; map lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Serialize with stable key order (objects are BTreeMaps).
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"artifacts": [{"name": "margins_b128_d32_n1",
            "args": [[128, 32], [128], [128], [1, 32], [1]],
            "b_pad": 128, "outputs": [[1]]}]}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "margins_b128_d32_n1");
        assert_eq!(arts[0].get("b_pad").unwrap().as_usize().unwrap(), 128);
        let re = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("line\n\"quoted\"\tüñí".into());
        let s = to_string(&v);
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": {"b": [1, [2, {"c": 3}]]}}"#).unwrap();
        let inner = v.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(inner[0], Json::Num(1.0));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(to_string(&Json::parse("[]").unwrap()), "[]");
    }
}

//! First-party utility substrates.
//!
//! The build image is offline and only the `xla` crate's dependency
//! closure is vendored, so the small infrastructure pieces that a
//! networked project would pull from crates.io are implemented here:
//!
//! * [`json`]  — minimal JSON parser/serializer (artifact manifest,
//!   experiment result dumps).
//! * [`table`] — aligned console tables + CSV writing for the experiment
//!   drivers (each paper table/figure prints both).
//! * [`timer`] — scoped wall-clock accounting used for the paper's
//!   merge-time-fraction measurements (Fig. 1).
//! * [`stats`] — mean/std/percentile helpers for benches and reports.
//! * [`durable`] — crash-safe atomic writes with checksum footers and
//!   a `.prev` last-good generation (models, checkpoints, manifests).
//! * [`fault`] — deterministic fault injection (`fault-inject`
//!   feature) so recovery paths are proved by tests, not assumed.

pub mod durable;
pub mod fault;
pub mod json;
pub mod stats;
pub mod table;
pub mod timer;

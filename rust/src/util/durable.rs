//! Crash-safe file IO: atomic replace, checksum footer, `.prev`
//! last-good generation.
//!
//! Every durable artifact in the repo (model text, training
//! checkpoints, the AOT artifact manifest) is written through
//! [`write_atomic`]:
//!
//! 1. payload + footer go to `<path>.tmp`, which is `fsync`ed;
//! 2. if `<path>` already exists it is renamed to `<path>.prev`,
//!    keeping the last good generation;
//! 3. `<path>.tmp` is renamed onto `<path>`;
//! 4. the parent directory is fsynced (best effort) so the renames
//!    survive power loss.
//!
//! The footer is a single trailing comment line,
//!
//! ```text
//! #mmbsgd-durable v1 len=<payload bytes> fnv=<16 hex digits>
//! ```
//!
//! where the digest is seeded FNV-1a with a SplitMix64 finalizer — the
//! same no-dependency idiom as `route_hash` in `serve/registry.rs`.
//! All existing text formats ignore trailing lines after their own
//! terminator, so footered files remain readable by the original
//! parsers, and files written before this footer existed ("legacy")
//! verify as clean pass-throughs.
//!
//! [`verify`] classifies a file: intact footer → checked payload;
//! no footer → legacy payload (structure-validating parsers are the
//! backstop for torn legacy files); malformed or mismatching footer →
//! [`DurableError::Corrupt`] naming the failing section and byte
//! offset, which readers use to fall back to `.prev`.

use std::fmt;
use std::path::{Path, PathBuf};

use super::fault;

/// Marker beginning the footer line. A `#` comment so every line
/// oriented parser in the repo skips past it.
pub const FOOTER_PREFIX: &str = "#mmbsgd-durable v1 ";

/// Domain-separation seed for the footer digest ("mmbsgdv1" in ASCII).
const CHECKSUM_SEED: u64 = 0x6d6d_6273_6764_7631;

/// Which on-disk generation a read was satisfied from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Generation {
    /// `<path>` itself.
    Primary,
    /// The `<path>.prev` last-good fallback.
    Prev,
}

/// Typed failure from the durable layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DurableError {
    /// The underlying filesystem operation failed (or an `io` fault
    /// was injected).
    Io { path: String, detail: String },
    /// The file exists but its footer or payload does not check out.
    /// `section` is `"footer"` or `"payload"`; `offset` is the byte
    /// position the check failed at.
    Corrupt { path: String, section: &'static str, offset: u64, detail: String },
}

impl fmt::Display for DurableError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DurableError::Io { path, detail } => write!(f, "durable io on {path}: {detail}"),
            DurableError::Corrupt { path, section, offset, detail } => {
                write!(f, "corrupt durable file {path}: {section} at byte {offset}: {detail}")
            }
        }
    }
}

impl std::error::Error for DurableError {}

/// Seeded FNV-1a over `bytes`, finished with the SplitMix64 mixer
/// (same constants as `route_hash`; reimplemented here because `util`
/// must not depend on `serve`).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ CHECKSUM_SEED.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut z = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The footer line (newline-terminated) for `payload`.
pub fn footer(payload: &str) -> String {
    format!("{FOOTER_PREFIX}len={} fnv={:016x}\n", payload.len(), checksum(payload.as_bytes()))
}

/// `<path>.prev` — the last-good generation kept beside every durable
/// file.
pub fn prev_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".prev");
    path.with_file_name(name)
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Best-effort directory fsync so the renames themselves are durable.
/// Ignored on platforms where opening a directory for sync fails.
fn sync_parent(path: &Path) {
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    if let Ok(dir) = std::fs::File::open(&parent) {
        let _ = dir.sync_all();
    }
}

/// Atomically replace `path` with `payload` + checksum footer, keeping
/// the previous generation at `<path>.prev`.
///
/// Injection site [`fault::site::DURABLE_WRITE`]: an `io` rule fails
/// the write before anything touches disk; a `truncate:K` rule tears
/// the byte stream at `K` but lets the rename pipeline complete, so
/// the final file is detectably corrupt — exactly what a power cut
/// between write and fsync produces.
pub fn write_atomic(path: &Path, payload: &str) -> Result<(), DurableError> {
    let io = |detail: String| DurableError::Io { path: path.display().to_string(), detail };

    let mut data = Vec::with_capacity(payload.len() + 64);
    data.extend_from_slice(payload.as_bytes());
    data.extend_from_slice(footer(payload).as_bytes());
    match fault::armed(fault::site::DURABLE_WRITE) {
        Some(fault::FaultKind::Io) => return Err(io("injected write fault".to_string())),
        Some(fault::FaultKind::Truncate(k)) => data.truncate(k.min(data.len())),
        _ => {}
    }

    let tmp = tmp_path(path);
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| io(format!("create {}: {e}", tmp.display())))?;
        f.write_all(&data).map_err(|e| io(format!("write {}: {e}", tmp.display())))?;
        f.sync_all().map_err(|e| io(format!("fsync {}: {e}", tmp.display())))?;
    }
    if path.exists() {
        std::fs::rename(path, prev_path(path))
            .map_err(|e| io(format!("rotate to .prev: {e}")))?;
    }
    std::fs::rename(&tmp, path).map_err(|e| io(format!("rename into place: {e}")))?;
    sync_parent(path);
    Ok(())
}

/// A verified read: the payload with the footer stripped, plus whether
/// a footer was present at all (legacy files have none).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Verified<'a> {
    pub payload: &'a str,
    pub had_footer: bool,
}

/// Locate the footer line: the last occurrence of [`FOOTER_PREFIX`]
/// that starts a line. Payload lines never start with `#`, so a
/// mid-line hit means the prefix is data, not a footer.
fn find_footer(text: &str) -> Option<usize> {
    let idx = text.rfind(FOOTER_PREFIX)?;
    if idx == 0 || text.as_bytes()[idx - 1] == b'\n' {
        Some(idx)
    } else {
        None
    }
}

/// Check `text` against its footer. `path` is only used for error
/// messages. No footer → legacy accept (whole text is the payload).
pub fn verify<'a>(text: &'a str, path: &Path) -> Result<Verified<'a>, DurableError> {
    let corrupt = |section: &'static str, offset: u64, detail: String| DurableError::Corrupt {
        path: path.display().to_string(),
        section,
        offset,
        detail,
    };
    let Some(idx) = find_footer(text) else {
        return Ok(Verified { payload: text, had_footer: false });
    };
    let footer_line = &text[idx..];
    let body = footer_line.strip_prefix(FOOTER_PREFIX).expect("found by prefix search");
    let body = match body.split_once('\n') {
        None => body, // torn before the terminating newline
        Some((first, rest)) if rest.is_empty() => first,
        Some(_) => {
            return Err(corrupt(
                "footer",
                idx as u64,
                "data after the footer line".to_string(),
            ))
        }
    };
    let mut len: Option<usize> = None;
    let mut fnv: Option<u64> = None;
    for tok in body.split_ascii_whitespace() {
        if let Some(v) = tok.strip_prefix("len=") {
            len = v.parse().ok();
        } else if let Some(v) = tok.strip_prefix("fnv=") {
            fnv = u64::from_str_radix(v, 16).ok();
        }
    }
    let (Some(len), Some(fnv)) = (len, fnv) else {
        return Err(corrupt("footer", idx as u64, format!("malformed footer {body:?}")));
    };
    let payload = &text[..idx];
    if payload.len() != len {
        return Err(corrupt(
            "payload",
            payload.len().min(len) as u64,
            format!("length mismatch: footer says {len} bytes, payload has {}", payload.len()),
        ));
    }
    let got = checksum(payload.as_bytes());
    if got != fnv {
        return Err(corrupt(
            "payload",
            idx as u64,
            format!("checksum mismatch: footer fnv={fnv:016x}, computed {got:016x}"),
        ));
    }
    Ok(Verified { payload, had_footer: true })
}

/// Read `path` and return its verified payload (footer stripped).
pub fn read_verified(path: &Path) -> Result<String, DurableError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| DurableError::Io { path: path.display().to_string(), detail: e.to_string() })?;
    let v = verify(&text, path)?;
    Ok(v.payload.to_string())
}

/// [`read_verified`] for artifact manifests — fleet model bundles and
/// the AOT registry manifest — with its own injection site so those
/// reads can be faulted independently of checkpoint loads.
///
/// Injection site [`fault::site::ARTIFACT_READ`]: an `io` rule fails
/// the read outright; a `truncate:K` rule tears the text at byte `K`
/// (snapped back to a char boundary) *before* verification, so the
/// footer check sees exactly what a torn read would produce.
pub fn read_artifact_verified(path: &Path) -> Result<String, DurableError> {
    let mut text = std::fs::read_to_string(path)
        .map_err(|e| DurableError::Io { path: path.display().to_string(), detail: e.to_string() })?;
    match fault::armed(fault::site::ARTIFACT_READ) {
        Some(fault::FaultKind::Io) => {
            return Err(DurableError::Io {
                path: path.display().to_string(),
                detail: "injected artifact read fault".to_string(),
            })
        }
        Some(fault::FaultKind::Truncate(k)) => {
            let mut k = k.min(text.len());
            while k > 0 && !text.is_char_boundary(k) {
                k -= 1;
            }
            text.truncate(k);
        }
        _ => {}
    }
    let v = verify(&text, path)?;
    Ok(v.payload.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mmbsgd_durable_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn footer_roundtrip_and_legacy_accept() {
        let payload = "mmbsgd-model v1\nnsv 0\n";
        let text = format!("{payload}{}", footer(payload));
        let v = verify(&text, Path::new("x")).unwrap();
        assert!(v.had_footer);
        assert_eq!(v.payload, payload);
        // legacy file, no footer
        let v = verify(payload, Path::new("x")).unwrap();
        assert!(!v.had_footer);
        assert_eq!(v.payload, payload);
    }

    #[test]
    fn verify_catches_flips_truncation_and_garbage_footers() {
        let payload = "header\n0.5 1 0\nend\n";
        let text = format!("{payload}{}", footer(payload));
        // single-byte flip in the payload
        let flipped = text.replacen("0.5", "0.7", 1);
        assert!(matches!(
            verify(&flipped, Path::new("x")),
            Err(DurableError::Corrupt { section: "payload", .. })
        ));
        // payload shortened under an intact-looking footer
        let shorter = format!("header\nend\n{}", &text[payload.len()..]);
        assert!(matches!(
            verify(&shorter, Path::new("x")),
            Err(DurableError::Corrupt { section: "payload", .. })
        ));
        // garbage after the footer line
        let trailing = format!("{text}junk\n");
        assert!(matches!(
            verify(&trailing, Path::new("x")),
            Err(DurableError::Corrupt { section: "footer", .. })
        ));
        // footer line torn mid-digest: still detected (checksum differs)
        let torn = &text[..text.len() - 5];
        assert!(verify(torn, Path::new("x")).is_err());
        // torn before the footer *prefix* completes: payload intact,
        // treated as legacy — the structural parser is the backstop
        let torn_early = &text[..payload.len() + 4];
        let v = verify(torn_early, Path::new("x")).unwrap();
        assert!(!v.had_footer);
    }

    #[test]
    fn write_atomic_rotates_prev_and_reads_back() {
        let dir = scratch_dir("rotate");
        let p = dir.join("model.txt");
        write_atomic(&p, "gen one\n").unwrap();
        assert_eq!(read_verified(&p).unwrap(), "gen one\n");
        assert!(!prev_path(&p).exists());
        write_atomic(&p, "gen two\n").unwrap();
        assert_eq!(read_verified(&p).unwrap(), "gen two\n");
        assert_eq!(read_verified(&prev_path(&p)).unwrap(), "gen one\n");
        write_atomic(&p, "gen three\n").unwrap();
        assert_eq!(read_verified(&prev_path(&p)).unwrap(), "gen two\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn read_verified_reports_missing_file_as_io() {
        let dir = scratch_dir("missing");
        assert!(matches!(
            read_verified(&dir.join("absent.txt")),
            Err(DurableError::Io { .. })
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_is_stable_and_input_sensitive() {
        let a = checksum(b"abc");
        assert_eq!(a, checksum(b"abc"), "must be deterministic");
        assert_ne!(a, checksum(b"abd"));
        assert_ne!(a, checksum(b"ab"));
        assert_ne!(checksum(b""), 0);
    }
}

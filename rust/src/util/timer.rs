//! Scoped wall-clock accounting.
//!
//! The paper's Figure 1 reports the *fraction of training time spent on
//! merging*; Table 1 and Figures 2-4 report absolute training times.
//! [`TimeBook`] accumulates named durations with negligible overhead
//! (one `Instant::now()` pair per scope) so the trainer can attribute
//! every hot-path nanosecond to a phase: `step`, `margin`, `select`,
//! `merge`, `maintenance`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates named wall-clock buckets.
#[derive(Default, Debug, Clone)]
pub struct TimeBook {
    buckets: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl TimeBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure into `name`.
    #[inline]
    pub fn scope<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed());
        out
    }

    #[inline]
    pub fn add(&mut self, name: &'static str, d: Duration) {
        *self.buckets.entry(name).or_default() += d;
        *self.counts.entry(name).or_default() += 1;
    }

    /// Merge a pre-aggregated bucket (`n` scopes totalling `d`) —
    /// checkpoint restore, where per-scope durations no longer exist.
    pub fn add_many(&mut self, name: &'static str, d: Duration, n: u64) {
        *self.buckets.entry(name).or_default() += d;
        *self.counts.entry(name).or_default() += n;
    }

    pub fn get(&self, name: &str) -> Duration {
        self.buckets.get(name).copied().unwrap_or_default()
    }

    pub fn count(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or_default()
    }

    /// Total across all buckets.
    pub fn total(&self) -> Duration {
        self.buckets.values().sum()
    }

    /// `buckets[name] / reference` as a fraction in [0, 1]; 0 if empty.
    pub fn fraction_of(&self, name: &str, reference: Duration) -> f64 {
        if reference.is_zero() {
            return 0.0;
        }
        self.get(name).as_secs_f64() / reference.as_secs_f64()
    }

    /// Merge another book into this one (used when joining worker threads).
    pub fn absorb(&mut self, other: &TimeBook) {
        for (k, v) in &other.buckets {
            *self.buckets.entry(k).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k).or_default() += *v;
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, Duration, u64)> + '_ {
        self.buckets
            .iter()
            .map(|(k, v)| (*k, *v, self.count(k)))
    }

    /// Render a compact one-line summary, e.g. for progress logs.
    pub fn summary(&self) -> String {
        let mut parts: Vec<String> = self
            .iter()
            .map(|(k, d, n)| format!("{k}={:.3}s/{n}", d.as_secs_f64()))
            .collect();
        parts.sort();
        parts.join(" ")
    }
}

/// RAII guard alternative for call-sites where a closure is awkward.
pub struct ScopeGuard<'a> {
    book: &'a mut TimeBook,
    name: &'static str,
    start: Instant,
}

impl<'a> ScopeGuard<'a> {
    pub fn new(book: &'a mut TimeBook, name: &'static str) -> Self {
        Self { book, name, start: Instant::now() }
    }
}

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        self.book.add(self.name, self.start.elapsed());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_counts() {
        let mut tb = TimeBook::new();
        for _ in 0..3 {
            tb.scope("a", || std::thread::sleep(Duration::from_millis(2)));
        }
        assert_eq!(tb.count("a"), 3);
        assert!(tb.get("a") >= Duration::from_millis(6));
    }

    #[test]
    fn fraction_and_total() {
        let mut tb = TimeBook::new();
        tb.add("merge", Duration::from_millis(30));
        tb.add("step", Duration::from_millis(70));
        let f = tb.fraction_of("merge", tb.total());
        assert!((f - 0.3).abs() < 1e-9);
    }

    #[test]
    fn absorb_merges_buckets() {
        let mut a = TimeBook::new();
        a.add("x", Duration::from_millis(5));
        let mut b = TimeBook::new();
        b.add("x", Duration::from_millis(7));
        b.add("y", Duration::from_millis(1));
        a.absorb(&b);
        assert_eq!(a.get("x"), Duration::from_millis(12));
        assert_eq!(a.count("x"), 2);
        assert_eq!(a.get("y"), Duration::from_millis(1));
    }

    #[test]
    fn guard_records_on_drop() {
        let mut tb = TimeBook::new();
        {
            let _g = ScopeGuard::new(&mut tb, "g");
        }
        assert_eq!(tb.count("g"), 1);
    }
}

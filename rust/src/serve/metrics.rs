//! The serving layer's one telemetry surface.
//!
//! Every signal the server used to scatter across `ProtoStats`
//! atomics, `BatchEngine` totals, and the drift [`Monitor`] is
//! registered here, on a single [`crate::telemetry::Registry`] that
//! `GET /metrics` renders.  Three publication styles:
//!
//! * **source counters** — connection policing and HTTP events
//!   increment their [`Counter`] at the site where they happen
//!   (connection threads, accept loops), lock-free;
//! * **mirrored totals** — the engine and monitor own their stats as
//!   plain fields on the engine thread; [`ServeMetrics::publish_engine`]
//!   / [`publish_drift`](ServeMetrics::publish_drift) republish them
//!   after every burst (`Counter::set_total` — a store, not a
//!   double-count);
//! * **latency histograms** — the HTTP front end observes every
//!   request's wall time into `serve_http_request_ns`.
//!
//! The legacy `stats` protocol line is now a *view* over the same
//! counters ([`ServeMetrics::proto_stats`]), so the line protocol and
//! the HTTP scrape can never disagree.

use super::batch::EngineStats;
use super::monitor::DriftReport;
use super::proto::ProtoStats;
use crate::telemetry::{Counter, Gauge, Histogram, Registry};
use std::sync::Arc;

/// Registered handles for every serving metric (see the module docs
/// for the three publication styles, and EXPERIMENTS.md §Serve for
/// the full name inventory).
pub(crate) struct ServeMetrics {
    /// The registry behind `GET /metrics`.
    pub registry: Arc<Registry>,

    // -- line-protocol connection policing (source counters) --
    pub connections: Arc<Counter>,
    pub idle_timeouts: Arc<Counter>,
    pub oversize_lines: Arc<Counter>,
    pub busy_rejected: Arc<Counter>,
    pub auth_failures: Arc<Counter>,

    // -- engine totals (mirrored after every burst) --
    pub engine_submitted: Arc<Counter>,
    pub engine_served: Arc<Counter>,
    pub engine_shed: Arc<Counter>,
    pub engine_expired: Arc<Counter>,
    pub engine_batches: Arc<Counter>,
    pub engine_rows: Arc<Counter>,
    pub queue_depth: Arc<Gauge>,
    pub queue_peak: Arc<Gauge>,

    // -- drift monitor (mirrored after every burst) --
    pub decisions: Arc<Counter>,
    pub feedback: Arc<Counter>,
    pub window_accuracy: Arc<Gauge>,
    pub low_margin_fraction: Arc<Gauge>,
    pub mean_abs_margin: Arc<Gauge>,

    // -- HTTP front end (source counters + latency histogram) --
    pub http_connections: Arc<Counter>,
    pub http_requests: Arc<Counter>,
    pub http_2xx: Arc<Counter>,
    pub http_4xx: Arc<Counter>,
    pub http_5xx: Arc<Counter>,
    pub http_read_errors: Arc<Counter>,
    pub http_idle_timeouts: Arc<Counter>,
    pub http_oversize: Arc<Counter>,
    pub http_busy: Arc<Counter>,
    pub http_request_ns: Arc<Histogram>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        let registry = Registry::new();
        Self {
            connections: registry.counter("serve_connections_total"),
            idle_timeouts: registry.counter("serve_idle_timeouts_total"),
            oversize_lines: registry.counter("serve_oversize_lines_total"),
            busy_rejected: registry.counter("serve_busy_rejected_total"),
            auth_failures: registry.counter("serve_auth_failures_total"),
            engine_submitted: registry.counter("serve_engine_submitted_total"),
            engine_served: registry.counter("serve_engine_served_total"),
            engine_shed: registry.counter("serve_engine_shed_total"),
            engine_expired: registry.counter("serve_engine_expired_total"),
            engine_batches: registry.counter("serve_engine_batches_total"),
            engine_rows: registry.counter("serve_engine_rows_total"),
            queue_depth: registry.gauge("serve_queue_depth"),
            queue_peak: registry.gauge("serve_queue_peak"),
            decisions: registry.counter("serve_decisions_total"),
            feedback: registry.counter("serve_feedback_total"),
            window_accuracy: registry.gauge("serve_window_accuracy"),
            low_margin_fraction: registry.gauge("serve_low_margin_fraction"),
            mean_abs_margin: registry.gauge("serve_mean_abs_margin"),
            http_connections: registry.counter("serve_http_connections_total"),
            http_requests: registry.counter("serve_http_requests_total"),
            http_2xx: registry.counter("serve_http_responses_2xx_total"),
            http_4xx: registry.counter("serve_http_responses_4xx_total"),
            http_5xx: registry.counter("serve_http_responses_5xx_total"),
            http_read_errors: registry.counter("serve_http_read_errors_total"),
            http_idle_timeouts: registry.counter("serve_http_idle_timeouts_total"),
            http_oversize: registry.counter("serve_http_oversize_total"),
            http_busy: registry.counter("serve_http_busy_total"),
            http_request_ns: registry.histogram("serve_http_request_ns"),
            registry,
        }
    }

    /// The `stats`-line view over the connection-policing counters
    /// (what [`super::proto::ServeReport`] reports as `proto`).
    pub fn proto_stats(&self) -> ProtoStats {
        ProtoStats {
            idle_timeouts: self.idle_timeouts.get(),
            oversize_lines: self.oversize_lines.get(),
            busy_rejected: self.busy_rejected.get(),
        }
    }

    /// Mirror the engine's totals (engine thread, after each burst).
    pub fn publish_engine(&self, s: &EngineStats, queued: usize) {
        self.engine_submitted.set_total(s.submitted);
        self.engine_served.set_total(s.served);
        self.engine_shed.set_total(s.shed);
        self.engine_expired.set_total(s.expired);
        self.engine_batches.set_total(s.batches);
        self.engine_rows.set_total(s.rows);
        self.queue_depth.set(queued as f64);
        self.queue_peak.set(s.queue_peak as f64);
    }

    /// Mirror the drift monitor's report (engine thread, after each
    /// burst).  `serve_window_accuracy` is `-1` until feedback exists.
    pub fn publish_drift(&self, r: &DriftReport) {
        self.decisions.set_total(r.served);
        self.feedback.set_total(r.feedback_seen);
        self.window_accuracy.set(r.window_accuracy.unwrap_or(-1.0));
        self.low_margin_fraction.set(r.low_margin_fraction);
        self.mean_abs_margin.set(r.mean_abs_margin);
    }

    /// Count one HTTP response by status class.
    pub fn http_response(&self, status: u16) {
        match status / 100 {
            2 => self.http_2xx.inc(),
            4 => self.http_4xx.inc(),
            _ => self.http_5xx.inc(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mirrors_land_in_the_registry() {
        let m = ServeMetrics::new();
        m.idle_timeouts.inc();
        let stats = EngineStats {
            submitted: 9,
            served: 7,
            shed: 2,
            batches: 3,
            rows: 7,
            queue_peak: 4,
            expired: 0,
        };
        m.publish_engine(&stats, 1);
        m.publish_drift(&DriftReport {
            served: 7,
            low_margin_fraction: 0.25,
            mean_abs_margin: 1.5,
            window_accuracy: None,
            feedback_seen: 0,
            degrade: Default::default(),
        });
        let snap = m.registry.snapshot();
        assert_eq!(snap.counters["serve_idle_timeouts_total"], 1);
        assert_eq!(snap.counters["serve_engine_served_total"], 7);
        assert_eq!(snap.gauges["serve_queue_peak"], 4.0);
        assert_eq!(snap.gauges["serve_window_accuracy"], -1.0, "na renders as -1");
        let proto = ProtoStats { idle_timeouts: 1, oversize_lines: 0, busy_rejected: 0 };
        assert_eq!(m.proto_stats(), proto);
        // republishing overwrites, never double-counts
        let stats = EngineStats {
            submitted: 10,
            served: 8,
            shed: 2,
            batches: 4,
            rows: 8,
            queue_peak: 4,
            expired: 0,
        };
        m.publish_engine(&stats, 0);
        assert_eq!(m.registry.snapshot().counters["serve_engine_served_total"], 8);
    }

    #[test]
    fn http_responses_count_by_class() {
        let m = ServeMetrics::new();
        m.http_response(200);
        m.http_response(404);
        m.http_response(503);
        m.http_response(504);
        let snap = m.registry.snapshot();
        assert_eq!(snap.counters["serve_http_responses_2xx_total"], 1);
        assert_eq!(snap.counters["serve_http_responses_4xx_total"], 1);
        assert_eq!(snap.counters["serve_http_responses_5xx_total"], 2);
    }
}

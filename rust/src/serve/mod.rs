//! Serving subsystem: from a trained model to live traffic.
//!
//! Four layers, each usable on its own:
//!
//! * [`Predictor`] — a single-model serving handle (model + backend,
//!   scale folded once, batched margins).  The deployment-side
//!   counterpart of [`crate::solver::session::TrainSession`].
//! * [`ModelRegistry`] — many named, versioned models over **one**
//!   shared backend + worker pool, with deterministic weighted A/B
//!   routing ([`RouteSpec`]: seeded hash on the request key, no `rand`,
//!   same key ⇒ same model on every run and every thread).
//! * [`BatchEngine`] — a micro-batcher: pending single-query requests
//!   coalesce into one [`crate::data::DenseMatrix`] per routed model and
//!   are answered by a single tiled [`crate::runtime::Backend::margins`]
//!   pass, with a bounded queue and an explicit load-shedding policy
//!   ([`ShedPolicy`]).  On the native backend (the serving default)
//!   batched answers are **bit-identical** to one-at-a-time
//!   [`Predictor::decision1`] calls — same ascending-SV accumulation as
//!   the tile engine (`rust/tests/serve_engine.rs`); backends that
//!   route big batches to AOT artifacts (hybrid/XLA) trade that
//!   load-invariant parity for artifact speed.
//! * [`proto`] — a std-only newline-delimited TCP protocol
//!   (`predict` / `decision` / `feedback` / `stats` / `swap-model` /
//!   `shutdown`) over `std::net::TcpListener` and scoped threads,
//!   driving the engine; `mmbsgd serve` is a thin CLI wrapper.
//!   [`serve_fleet`] is the same server with the fleet verbs enabled
//!   (`push-artifact` / `activate` / `rollback` / `fleet-status`),
//!   answered by a [`FleetHandler`] — see [`crate::fleet`] for the
//!   replica state, the artifact format, and the consistent-hash
//!   router that fronts a set of these servers.  [`serve_bound`] adds
//!   an optional [`http`] front end (`POST /predict|/decision`,
//!   `GET /metrics|/healthz`) feeding the same engine channel, so
//!   HTTP answers are bit-identical to line-protocol answers.
//!
//! [`Monitor`] watches served traffic for drift: a rolling
//! decision-margin histogram plus a label-feedback accuracy window that
//! feeds the same [`crate::solver::bsgd::EvalPoint`] history the
//! training loop records.
//!
//! Every request-path failure is a typed [`ServeError`] scoped to that
//! request — a malformed line or a mismatched dimension never takes
//! down the queue, the connection, or the process.
//!
//! ```
//! use mmbsgd::prelude::*;
//! use mmbsgd::serve::Predictor;
//!
//! let split = mmbsgd::data::synth::dataset(&SynthSpec::ijcnn_like(0.01), 1);
//! let cfg = TrainConfig { lambda: 1e-3, gamma: 2.0, budget: 32, ..TrainConfig::default() };
//! let out = bsgd::train(&split.train, &cfg).unwrap();
//!
//! let mut served = Predictor::native(out.model).unwrap();
//! let labels = served.predict_batch(&split.test.x).unwrap();
//! assert_eq!(labels.len(), split.test.len());
//! assert!(labels.iter().all(|&y| y == 1.0 || y == -1.0));
//! ```

mod batch;
pub mod http;
mod metrics;
mod monitor;
pub mod proto;
mod registry;

pub use batch::{BatchEngine, Decision, EngineStats, ShedPolicy};
pub use monitor::{DegradeTotals, DriftReport, Monitor, MARGIN_BINS};
pub use proto::{
    serve, serve_bound, serve_fleet, serve_fleet_bound, Command, FleetHandler, ProtoStats,
    ServeOptions, ServeReport,
};
pub use registry::{route_hash, ModelRegistry, ModelStatus, RouteArm, RouteSpec};

pub use crate::error::ServeError;

use crate::data::{Dataset, DenseMatrix};
use crate::error::TrainError;
use crate::model::SvmModel;
use crate::runtime::{margin1_bounded, Backend, NativeBackend, TileBounds};

/// Validate a model for serving (a loaded model file is user input) —
/// shared by [`Predictor`] and [`ModelRegistry`].
fn validate_model(model: &SvmModel) -> Result<(), TrainError> {
    if !(model.gamma > 0.0 && model.gamma.is_finite()) {
        return Err(TrainError::InvalidConfig {
            field: "gamma",
            message: format!("model gamma must be positive, got {}", model.gamma),
        });
    }
    Ok(())
}

/// A serving handle: model + backend, shape-checked batched inference.
pub struct Predictor {
    model: SvmModel,
    backend: Box<dyn Backend>,
    /// Per-tile far-skip bounds, built once — the store is frozen for
    /// the lifetime of the handle, so even single-query requests get
    /// the tile engine's far-skip without a per-call Θ(B) bound scan.
    bounds: TileBounds,
}

impl Predictor {
    /// Build a predictor over an explicit backend (native, XLA, or
    /// hybrid — see [`crate::coordinator::build_backend`]).
    ///
    /// Validates the model (γ must be positive and finite — a loaded
    /// model file is user input) and folds the lazy coefficient scale
    /// so request-time margins touch plain stored coefficients.
    pub fn new(mut model: SvmModel, backend: Box<dyn Backend>) -> Result<Self, TrainError> {
        validate_model(&model)?;
        model.svs.fold_scale();
        let bounds = TileBounds::of(&model.svs);
        Ok(Self { model, backend, bounds })
    }

    /// Convenience: serve through the pure-rust backend.
    pub fn native(model: SvmModel) -> Result<Self, TrainError> {
        Self::new(model, Box::new(NativeBackend::new()))
    }

    /// Worker threads for the batched request paths (the tile engine
    /// shards query rows; results are bit-identical for every thread
    /// count).  Returns the count in effect — backends without a pool
    /// report 1.
    pub fn set_threads(&mut self, threads: usize) -> usize {
        self.backend.set_threads(threads)
    }

    /// OS worker threads ever created by this predictor's backend pool
    /// — constant after [`Predictor::set_threads`]; request traffic
    /// reuses the parked workers (see `runtime::pool`).
    pub fn worker_spawns(&self) -> u64 {
        self.backend.worker_spawns()
    }

    /// The wrapped model (read-only; provenance, SV count, ...).
    pub fn model(&self) -> &SvmModel {
        &self.model
    }

    /// Support-vector count.
    pub fn n_svs(&self) -> usize {
        self.model.svs.len()
    }

    /// Feature dimension requests must match.
    pub fn dim(&self) -> usize {
        self.model.svs.dim()
    }

    fn check_dim(&self, got: usize) -> Result<(), TrainError> {
        if got != self.model.svs.dim() {
            return Err(TrainError::DimMismatch { expected: self.model.svs.dim(), got });
        }
        Ok(())
    }

    /// Decision values `f(x) = Σ α_j k(x_j, x) + b` for a batch of
    /// query rows, through the backend's batched margins over the
    /// bounds prebuilt at load time (the store is frozen, so no
    /// per-call bound rebuild).
    pub fn decision_batch(&mut self, queries: &DenseMatrix) -> Result<Vec<f64>, TrainError> {
        self.check_dim(queries.cols())?;
        let mut out = vec![0.0; queries.rows()];
        let (svs, gamma) = (&self.model.svs, self.model.gamma);
        self.backend.margins_bounded_into(svs, gamma, queries, &self.bounds, &mut out);
        for f in &mut out {
            *f += self.model.bias;
        }
        Ok(out)
    }

    /// Predicted ±1 labels for a batch of query rows.
    pub fn predict_batch(&mut self, queries: &DenseMatrix) -> Result<Vec<f32>, TrainError> {
        Ok(self
            .decision_batch(queries)?
            .into_iter()
            .map(|f| if f >= 0.0 { 1.0 } else { -1.0 })
            .collect())
    }

    /// Decision value for a single query — the tiled single-row path
    /// ([`margin1_bounded`] over the prebuilt bounds): bit-identical to
    /// a batch row, with the same per-tile far-skip, so single-query
    /// serving does not regress vs [`Predictor::decision_batch`] of
    /// size 1.
    pub fn decision1(&mut self, x: &[f32]) -> Result<f64, TrainError> {
        self.check_dim(x.len())?;
        Ok(margin1_bounded(&self.model.svs, self.model.gamma, x, &self.bounds) + self.model.bias)
    }

    /// Predicted ±1 label for a single query.
    pub fn predict1(&mut self, x: &[f32]) -> Result<f32, TrainError> {
        Ok(if self.decision1(x)? >= 0.0 { 1.0 } else { -1.0 })
    }

    /// Accuracy on a labelled dataset through the batched path.
    pub fn accuracy(&mut self, ds: &Dataset) -> Result<f64, TrainError> {
        if ds.is_empty() {
            return Err(TrainError::EmptyDataset);
        }
        let preds = self.predict_batch(&ds.x)?;
        let correct = preds.iter().zip(&ds.y).filter(|(p, y)| p == y).count();
        Ok(correct as f64 / ds.len() as f64)
    }

    /// Tear down into the owned model (e.g. to save it).
    pub fn into_model(self) -> SvmModel {
        self.model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::data::synth::{dataset, SynthSpec};
    use crate::solver::bsgd;

    fn trained() -> (SvmModel, crate::data::Split) {
        let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
        let cfg = TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget: 24,
            mergees: 3,
            seed: 5,
            ..TrainConfig::default()
        };
        (bsgd::train(&split.train, &cfg).unwrap().model, split)
    }

    #[test]
    fn batch_matches_model_decision() {
        let (model, split) = trained();
        let reference: Vec<f64> =
            (0..split.test.len()).map(|i| model.decision(split.test.sample(i).x)).collect();
        let mut p = Predictor::native(model).unwrap();
        let served = p.decision_batch(&split.test.x).unwrap();
        for (a, b) in served.iter().zip(&reference) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn decision1_bit_matches_batch_row() {
        // The tiled single-query path must agree with a batch of size 1
        // (and with the model's scalar decision) bit-for-bit.
        let (model, split) = trained();
        let mut p = Predictor::native(model).unwrap();
        for i in 0..split.test.len().min(32) {
            let x = split.test.sample(i).x;
            let single = p.decision1(x).unwrap();
            let row = DenseMatrix::from_rows(vec![x.to_vec()]);
            let batched = p.decision_batch(&row).unwrap()[0];
            assert_eq!(single.to_bits(), batched.to_bits(), "row {i}");
        }
    }

    #[test]
    fn accuracy_matches_model_accuracy() {
        let (model, split) = trained();
        let want = model.accuracy(&split.test);
        let mut p = Predictor::native(model).unwrap();
        let got = p.accuracy(&split.test).unwrap();
        assert!((want - got).abs() < 1e-12, "{want} vs {got}");
    }

    #[test]
    fn dim_mismatch_is_typed() {
        let (model, _) = trained();
        let d = model.svs.dim();
        let mut p = Predictor::native(model).unwrap();
        let wrong = DenseMatrix::zeros(3, d + 1);
        assert_eq!(
            p.decision_batch(&wrong).unwrap_err(),
            TrainError::DimMismatch { expected: d, got: d + 1 }
        );
        assert!(p.predict1(&vec![0.0; d + 2]).is_err());
    }

    #[test]
    fn bad_gamma_rejected_not_panicking() {
        let (mut model, _) = trained();
        model.gamma = f64::NAN;
        match Predictor::native(model) {
            Err(TrainError::InvalidConfig { field, .. }) => assert_eq!(field, "gamma"),
            _ => panic!("NaN gamma must be rejected"),
        }
    }

    #[test]
    fn roundtrips_through_model_text() {
        let (model, split) = trained();
        let text = model.to_text();
        let loaded = SvmModel::from_text(&text).unwrap();
        let mut a = Predictor::native(model).unwrap();
        let mut b = Predictor::native(loaded).unwrap();
        let fa = a.decision_batch(&split.test.x).unwrap();
        let fb = b.decision_batch(&split.test.x).unwrap();
        for (x, y) in fa.iter().zip(&fb) {
            assert!((x - y).abs() < 1e-6 * (1.0 + x.abs()));
        }
    }
}

//! Micro-batching: coalesce pending single-query requests into one
//! tiled margins pass per routed model.
//!
//! The tile engine made batched [`crate::runtime::Backend::margins`]
//! the fastest path in the codebase (EXPERIMENTS.md §Perf) — but live
//! traffic arrives one query at a time.  [`BatchEngine`] closes the
//! gap: requests are routed and admitted into a **bounded** queue as
//! they arrive ([`BatchEngine::submit`]), and a
//! [`BatchEngine::flush`] groups everything pending by routed model,
//! packs each group into one [`DenseMatrix`], and answers it with a
//! single [`crate::serve::ModelRegistry::decision_batch_into`] pass of
//! at most `batch_max` rows.
//!
//! **Overload is explicit, not emergent.**  When the queue holds
//! `queue_max` requests, [`ShedPolicy`] decides who loses:
//! [`ShedPolicy::Reject`] refuses the *new* request up front
//! ([`ServeError::QueueFull`] — tail drop: oldest waiters keep their
//! slot), while [`ShedPolicy::Oldest`] drops the *oldest* waiter with
//! [`ServeError::Shed`] (head drop: freshest traffic wins, the right
//! policy when stale answers are worthless).  Either way the failure is
//! a typed per-request error delivered through the normal reply path —
//! nothing panics, nothing blocks unboundedly.
//!
//! **Bit-parity.**  A batched answer is bit-identical to the
//! one-at-a-time [`crate::serve::Predictor::decision1`] for the same
//! model: both reduce to the tile engine's ascending-SV accumulation
//! plus the same final bias add (`rust/tests/serve_engine.rs` pins
//! B ∈ {1, 7, 64}).

use super::registry::ModelRegistry;
use crate::data::DenseMatrix;
use crate::error::ServeError;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// What to do with a request that finds the queue full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse the new request ([`ServeError::QueueFull`]); queued
    /// requests keep their slots (tail drop).
    Reject,
    /// Drop the oldest queued request ([`ServeError::Shed`]) and admit
    /// the new one (head drop).
    Oldest,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "reject" => Some(Self::Reject),
            "oldest" => Some(Self::Oldest),
            _ => None,
        }
    }

    pub fn describe(&self) -> &'static str {
        match self {
            Self::Reject => "reject",
            Self::Oldest => "oldest",
        }
    }
}

/// One answered request: the decision value and which model (at which
/// version) produced it — the provenance half of every reply.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    pub value: f64,
    pub model: String,
    pub version: u64,
}

/// A queued request, already routed at admission time (routing is a
/// pure hash; doing it in `submit` lets `flush` group by model without
/// re-touching the registry's route table mid-batch).
struct Pending {
    id: u64,
    model: String,
    x: Vec<f32>,
    /// When the request entered the queue; checked against the
    /// engine's per-request deadline at flush time.
    admitted: Instant,
}

/// Engine counters (reported by the `stats` protocol verb).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Requests admitted into the queue.
    pub submitted: u64,
    /// Requests answered with a decision value.
    pub served: u64,
    /// Requests dropped by [`ShedPolicy::Oldest`] or refused by
    /// [`ShedPolicy::Reject`].
    pub shed: u64,
    /// Margins passes run.
    pub batches: u64,
    /// Total rows across all passes (`rows / batches` = mean
    /// micro-batch size, the number that says whether coalescing is
    /// actually happening).
    pub rows: u64,
    /// High-water mark of the pending queue.
    pub queue_peak: usize,
    /// Requests expired at flush time by the per-request deadline
    /// (answered [`ServeError::Deadline`], never packed into a batch).
    pub expired: u64,
}

/// The micro-batcher; see the [module docs](self).
pub struct BatchEngine {
    batch_max: usize,
    queue_max: usize,
    shed: ShedPolicy,
    queue: VecDeque<Pending>,
    /// Requests resolved outside a flush (shed victims, parked submit
    /// failures), kept here so the next [`BatchEngine::flush`] delivers
    /// them through the same ordered reply path as computed answers.
    done: Vec<(u64, Result<Decision, ServeError>)>,
    /// Answer-buffer scratch, reused across flushes (the margins pass
    /// writes into it; per-request packing still owns its rows).
    ans: Vec<f64>,
    next_id: u64,
    stats: EngineStats,
    /// Per-request deadline; `None` = requests wait indefinitely.
    deadline: Option<Duration>,
}

impl BatchEngine {
    /// `batch_max` caps rows per margins pass (≥ 1); `queue_max` bounds
    /// admitted-but-unanswered requests (≥ 1).
    pub fn new(batch_max: usize, queue_max: usize, shed: ShedPolicy) -> Self {
        Self {
            batch_max: batch_max.max(1),
            queue_max: queue_max.max(1),
            shed,
            queue: VecDeque::new(),
            done: Vec::new(),
            ans: Vec::new(),
            next_id: 0,
            stats: EngineStats::default(),
            deadline: None,
        }
    }

    /// Set the per-request deadline: a request still queued after this
    /// long is answered [`ServeError::Deadline`] by the next flush
    /// instead of occupying a batch row.  `Duration::ZERO` disables
    /// the deadline (the default).
    pub fn set_deadline(&mut self, deadline: Duration) {
        self.deadline = if deadline.is_zero() { None } else { Some(deadline) };
    }

    /// Route and admit one query.  `key` drives the registry's
    /// deterministic A/B routing; unkeyed requests route on their
    /// request id (stable within a run).  Shape errors and
    /// [`ShedPolicy::Reject`] overflow fail *this* call; under
    /// [`ShedPolicy::Oldest`] overflow the displaced request's
    /// [`ServeError::Shed`] is delivered by the next flush.  Returns
    /// the request id whose answer the next flush will carry.
    pub fn submit(
        &mut self,
        registry: &ModelRegistry,
        key: Option<&str>,
        x: Vec<f32>,
    ) -> Result<u64, ServeError> {
        let id = self.next_id;
        let model = match key {
            Some(k) => registry.route_for(k.as_bytes())?,
            None => registry.route_for(&id.to_le_bytes())?,
        };
        let dim = registry.dim_of(&model)?;
        if x.len() != dim {
            return Err(crate::error::TrainError::DimMismatch { expected: dim, got: x.len() }
                .into());
        }
        if self.queue.len() >= self.queue_max {
            match self.shed {
                ShedPolicy::Reject => {
                    self.stats.shed += 1;
                    return Err(ServeError::QueueFull { limit: self.queue_max });
                }
                ShedPolicy::Oldest => {
                    // pop cannot fail: queue_max >= 1 and the queue is full
                    if let Some(old) = self.queue.pop_front() {
                        self.stats.shed += 1;
                        self.done.push((old.id, Err(ServeError::Shed)));
                    }
                }
            }
        }
        self.next_id += 1;
        self.queue.push_back(Pending { id, model, x, admitted: Instant::now() });
        self.stats.submitted += 1;
        self.stats.queue_peak = self.stats.queue_peak.max(self.queue.len());
        Ok(id)
    }

    /// Park a request-level failure as a completed result with its own
    /// request id, delivered by the next flush in submission order.
    /// The TCP server uses this for failed submits: replying out of
    /// band would reorder a pipelining client's replies relative to
    /// requests still waiting in the queue.
    pub fn park_error(&mut self, e: ServeError) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.done.push((id, Err(e)));
        id
    }

    /// Requests currently pending.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Answer everything pending: group the queue by routed model
    /// (first-appearance order), run one margins pass of at most
    /// `batch_max` rows per group chunk, and return every resolved
    /// request — computed answers and parked shed errors — sorted by
    /// request id, i.e. in submission order (what keeps per-connection
    /// replies FIFO).
    pub fn flush(
        &mut self,
        registry: &mut ModelRegistry,
    ) -> Vec<(u64, Result<Decision, ServeError>)> {
        let mut out = std::mem::take(&mut self.done);
        // One linear drain groups the queue by routed model in
        // first-appearance order (arrival order within each group);
        // the model count is small, so the inner find is cheap — and
        // nothing here is O(queue²) even when A/B traffic interleaves.
        let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
        for p in self.queue.drain(..) {
            // Expired waiters answer a typed error instead of taking a
            // batch row from requests that can still meet their SLO.
            if let Some(dl) = self.deadline {
                let waited = p.admitted.elapsed();
                if waited >= dl {
                    self.stats.expired += 1;
                    out.push((
                        p.id,
                        Err(ServeError::Deadline {
                            waited_ms: waited.as_millis() as u64,
                            deadline_ms: dl.as_millis() as u64,
                        }),
                    ));
                    continue;
                }
            }
            match groups.iter_mut().find(|(m, _)| *m == p.model) {
                Some((_, g)) => g.push(p),
                None => {
                    let model = p.model.clone();
                    groups.push((model, vec![p]));
                }
            }
        }
        for (model, group) in groups {
            let (version, dim) = match (registry.version_of(&model), registry.dim_of(&model)) {
                (Ok(v), Ok(d)) => (v, d),
                (Err(e), _) | (_, Err(e)) => {
                    // model evicted between submit and flush: fail the
                    // group's requests, not the engine
                    for p in group {
                        out.push((p.id, Err(e.clone())));
                    }
                    continue;
                }
            };
            // A swap may have changed the model's dimension since a
            // request was admitted: rows that no longer fit fail with
            // a typed error instead of poisoning (or panicking) the
            // packed matrix.
            let mut fitting: Vec<Pending> = Vec::with_capacity(group.len());
            for p in group {
                if p.x.len() == dim {
                    fitting.push(p);
                } else {
                    let e = crate::error::TrainError::DimMismatch { expected: dim, got: p.x.len() };
                    out.push((p.id, Err(e.into())));
                }
            }
            for chunk in fitting.chunks(self.batch_max) {
                let mut flat: Vec<f32> = Vec::with_capacity(chunk.len() * dim);
                for p in chunk {
                    flat.extend_from_slice(&p.x);
                }
                let queries = DenseMatrix::from_vec(flat, chunk.len(), dim);
                self.ans.clear();
                self.ans.resize(chunk.len(), 0.0);
                match registry.decision_batch_into(&model, &queries, &mut self.ans) {
                    Ok(()) => {
                        self.stats.batches += 1;
                        self.stats.rows += chunk.len() as u64;
                        self.stats.served += chunk.len() as u64;
                        for (p, &value) in chunk.iter().zip(self.ans.iter()) {
                            let d = Decision { value, model: model.clone(), version };
                            out.push((p.id, Ok(d)));
                        }
                    }
                    Err(e) => {
                        for p in chunk {
                            out.push((p.id, Err(e.clone())));
                        }
                    }
                }
            }
        }
        out.sort_by_key(|(id, _)| *id);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SvmModel;
    use crate::runtime::NativeBackend;
    use crate::serve::RouteSpec;

    fn registry(names: &[&str]) -> ModelRegistry {
        let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 3);
        for (i, name) in names.iter().enumerate() {
            let mut rng = crate::rng::Xoshiro256::new(i as u64 + 11);
            let mut m = SvmModel::new(3, 1.1);
            for _ in 0..12 {
                let x: Vec<f32> = (0..3).map(|_| rng.next_gaussian() as f32).collect();
                m.svs.push(&x, rng.next_f64() - 0.5);
            }
            m.bias = 0.02;
            reg.insert(name, m).unwrap();
        }
        reg
    }

    fn q(v: f32) -> Vec<f32> {
        vec![v, -v, 0.5 * v]
    }

    #[test]
    fn flush_answers_in_submission_order() {
        let mut reg = registry(&["a", "b"]);
        let mut eng = BatchEngine::new(8, 64, ShedPolicy::Reject);
        let ids: Vec<u64> = (0..10)
            .map(|k| eng.submit(&reg, Some(&format!("key-{k}")), q(k as f32 * 0.1)).unwrap())
            .collect();
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 10);
        let got: Vec<u64> = res.iter().map(|(id, _)| *id).collect();
        assert_eq!(got, ids);
        for (_, r) in &res {
            let d = r.as_ref().unwrap();
            assert!(d.value.is_finite());
            assert!(d.model == "a" || d.model == "b");
            assert_eq!(d.version, 1);
        }
        assert_eq!(eng.queued(), 0);
        let s = eng.stats();
        assert_eq!(s.served, 10);
        assert_eq!(s.rows, 10);
        assert!(s.batches >= 2, "two models => at least two passes, got {}", s.batches);
    }

    #[test]
    fn batch_max_splits_oversized_groups() {
        let mut reg = registry(&["solo"]);
        reg.set_route(RouteSpec::single("solo")).unwrap();
        let mut eng = BatchEngine::new(4, 64, ShedPolicy::Reject);
        for k in 0..10 {
            eng.submit(&reg, None, q(k as f32)).unwrap();
        }
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 10);
        // 10 rows at batch_max=4 => 3 passes (4+4+2)
        assert_eq!(eng.stats().batches, 3);
        assert_eq!(eng.stats().rows, 10);
    }

    #[test]
    fn reject_policy_refuses_new_requests() {
        let mut reg = registry(&["solo"]);
        let mut eng = BatchEngine::new(8, 3, ShedPolicy::Reject);
        for k in 0..3 {
            eng.submit(&reg, None, q(k as f32)).unwrap();
        }
        assert_eq!(
            eng.submit(&reg, None, q(9.0)).unwrap_err(),
            ServeError::QueueFull { limit: 3 }
        );
        assert_eq!(eng.stats().shed, 1);
        // earlier requests kept their slots and all get answers
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 3);
        assert!(res.iter().all(|(_, r)| r.is_ok()));
    }

    #[test]
    fn oldest_policy_sheds_the_head() {
        let mut reg = registry(&["solo"]);
        let mut eng = BatchEngine::new(8, 3, ShedPolicy::Oldest);
        let first = eng.submit(&reg, None, q(0.0)).unwrap();
        for k in 1..3 {
            eng.submit(&reg, None, q(k as f32)).unwrap();
        }
        let newest = eng.submit(&reg, None, q(3.0)).unwrap();
        assert_eq!(eng.queued(), 3);
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 4);
        // submission order preserved, oldest carries the shed error
        assert_eq!(res[0].0, first);
        assert_eq!(res[0].1, Err(ServeError::Shed));
        assert!(res.iter().skip(1).all(|(_, r)| r.is_ok()));
        assert_eq!(res[3].0, newest);
        assert_eq!(eng.stats().shed, 1);
        assert_eq!(eng.stats().served, 3);
    }

    #[test]
    fn dim_change_via_swap_fails_typed_not_panicking() {
        let mut reg = registry(&["solo"]);
        let mut eng = BatchEngine::new(8, 8, ShedPolicy::Reject);
        eng.submit(&reg, None, q(1.0)).unwrap(); // validated against dim 3
        // hot-swap to a 5-dimensional model while the request is queued:
        // rejected at swap time, so the queued request stays answerable
        let mut m5 = SvmModel::new(5, 1.1);
        m5.svs.push(&[0.1, 0.2, 0.3, 0.4, 0.5], 0.4);
        assert_eq!(
            reg.swap("solo", m5.clone()).unwrap_err(),
            ServeError::DimMismatch { name: "solo".into(), serving: 3, incoming: 5 }
        );
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 1);
        assert!(res[0].1.is_ok(), "{:?}", res[0].1);
        // force the dimension change through insert (the intentional
        // path, which swap's gate does not cover): the per-flush check
        // is the backstop, failing only the stale request — typed
        eng.submit(&reg, None, q(2.0)).unwrap();
        reg.insert("solo", m5).unwrap();
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 1);
        assert!(matches!(
            res[0].1,
            Err(ServeError::Model(crate::error::TrainError::DimMismatch {
                expected: 5,
                got: 3
            }))
        ));
    }

    #[test]
    fn park_error_keeps_submission_order() {
        let mut reg = registry(&["solo"]);
        let mut eng = BatchEngine::new(8, 8, ShedPolicy::Reject);
        let a = eng.submit(&reg, None, q(1.0)).unwrap();
        let b = eng.park_error(ServeError::BadRequest("nope".into()));
        let c = eng.submit(&reg, None, q(2.0)).unwrap();
        let res = eng.flush(&mut reg);
        let ids: Vec<u64> = res.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![a, b, c]);
        assert!(res[0].1.is_ok());
        assert!(matches!(res[1].1, Err(ServeError::BadRequest(_))));
        assert!(res[2].1.is_ok());
    }

    #[test]
    fn deadline_expires_stale_requests_typed() {
        let mut reg = registry(&["solo"]);
        let mut eng = BatchEngine::new(8, 8, ShedPolicy::Reject);
        // 1ns deadline: anything queued is already expired by flush
        eng.set_deadline(Duration::from_nanos(1));
        for k in 0..3 {
            eng.submit(&reg, None, q(k as f32)).unwrap();
        }
        std::thread::sleep(Duration::from_millis(2));
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 3);
        for (_, r) in &res {
            assert!(matches!(r, Err(ServeError::Deadline { .. })), "{r:?}");
        }
        assert_eq!(eng.stats().expired, 3);
        assert_eq!(eng.stats().served, 0);
        // generous deadline: requests serve normally again
        eng.set_deadline(Duration::from_secs(60));
        eng.submit(&reg, None, q(1.0)).unwrap();
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 1);
        assert!(res[0].1.is_ok());
        // zero disables entirely
        eng.set_deadline(Duration::ZERO);
        eng.submit(&reg, None, q(1.0)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
        assert!(eng.flush(&mut reg)[0].1.is_ok());
        assert_eq!(eng.stats().expired, 3);
    }

    #[test]
    fn dim_mismatch_fails_only_that_request() {
        let mut reg = registry(&["solo"]);
        let mut eng = BatchEngine::new(8, 8, ShedPolicy::Reject);
        eng.submit(&reg, None, q(1.0)).unwrap();
        assert!(matches!(
            eng.submit(&reg, None, vec![0.0; 7]).unwrap_err(),
            ServeError::Model(crate::error::TrainError::DimMismatch { expected: 3, got: 7 })
        ));
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), 1);
        assert!(res[0].1.is_ok());
    }
}

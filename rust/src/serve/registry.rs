//! Multi-model serving: named, versioned models over one shared
//! backend, with deterministic weighted A/B routing.
//!
//! A [`ModelRegistry`] is the serving-side answer to "one backend, many
//! models": every loaded [`SvmModel`] shares the registry's single
//! [`Backend`] (and therefore its worker pool and tile scratch), so
//! serving M variants costs one pool, not M.  Each model carries a
//! monotonically increasing **version** (bumped on every
//! [`ModelRegistry::swap`]) and prebuilt [`TileBounds`], so both the
//! batched and the single-query request paths get the tile engine's
//! far-skip treatment.
//!
//! Routing is deterministic by construction: a [`RouteSpec`] assigns
//! integer weights to model names, and a request key is hashed with a
//! seeded FNV-1a/SplitMix64 combination ([`route_hash`]) — no `rand`,
//! no per-thread state — so the same key maps to the same model on
//! every run, every thread, and every replica started with the same
//! seed.  This is what makes A/B assignments reproducible and
//! debuggable ("which model answered this user?" has one answer).

use super::validate_model;
use crate::data::DenseMatrix;
use crate::error::{ServeError, TrainError};
use crate::model::SvmModel;
use crate::runtime::{margin1_bounded, Backend, TileBounds};
use std::collections::BTreeMap;

/// One weighted arm of a [`RouteSpec`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteArm {
    pub name: String,
    pub weight: u32,
}

/// A weighted routing table over model names.  Weights are integers
/// (e.g. `champion:9, challenger:1` for a 90/10 split); a key routes to
/// the arm whose cumulative-weight interval contains
/// `route_hash(seed, key) % total_weight`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteSpec {
    arms: Vec<RouteArm>,
    /// Σ weights; ≥ 1 by construction ([`RouteSpec::new`] rejects empty
    /// specs and zero weights), so the routing modulus never divides by
    /// zero.
    total: u64,
}

impl RouteSpec {
    /// Build a spec from `(name, weight)` pairs.  Rejects empty specs,
    /// zero weights, and duplicate names (each would make routing
    /// ambiguous or degenerate).
    pub fn new(arms: Vec<(String, u32)>) -> Result<Self, ServeError> {
        if arms.is_empty() {
            return Err(ServeError::BadRoute("route needs at least one arm".into()));
        }
        let mut total = 0u64;
        let mut out = Vec::with_capacity(arms.len());
        for (name, weight) in arms {
            if weight == 0 {
                return Err(ServeError::BadRoute(format!("arm {name:?} has zero weight")));
            }
            if out.iter().any(|a: &RouteArm| a.name == name) {
                return Err(ServeError::BadRoute(format!("duplicate arm {name:?}")));
            }
            total += u64::from(weight);
            out.push(RouteArm { name, weight });
        }
        Ok(Self { arms: out, total })
    }

    /// A single-arm spec (all traffic to one model).
    pub fn single(name: &str) -> Self {
        Self { arms: vec![RouteArm { name: name.into(), weight: 1 }], total: 1 }
    }

    pub fn arms(&self) -> &[RouteArm] {
        &self.arms
    }

    /// The arm a hash ticket lands on.
    fn pick(&self, hash: u64) -> &str {
        debug_assert!(self.total > 0);
        let mut ticket = hash % self.total;
        for arm in &self.arms {
            let w = u64::from(arm.weight);
            if ticket < w {
                return &arm.name;
            }
            ticket -= w;
        }
        // unreachable by construction (ticket < total = Σ weights)
        &self.arms[self.arms.len() - 1].name
    }
}

/// Seeded deterministic key hash for routing: FNV-1a 64 over the key
/// bytes (with the seed folded into the offset basis) followed by a
/// SplitMix64 finalizer — FNV alone mixes the high bits poorly, and the
/// routing modulus needs all 64 of them.  Pure function of `(seed,
/// key)`: no process, thread, or time dependence.
pub fn route_hash(seed: u64, key: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for &b in key {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut z = h.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One loaded model: scale folded, far-skip bounds prebuilt, versioned.
struct ModelEntry {
    model: SvmModel,
    bounds: TileBounds,
    version: u64,
    served: u64,
}

/// A read-only snapshot of one registry entry (for `stats` replies and
/// operator tooling).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelStatus {
    pub name: String,
    pub version: u64,
    pub n_svs: usize,
    pub dim: usize,
    pub served: u64,
}

/// Named, versioned models over one shared backend; see the
/// [module docs](self).
pub struct ModelRegistry {
    backend: Box<dyn Backend>,
    models: BTreeMap<String, ModelEntry>,
    route: Option<RouteSpec>,
    seed: u64,
}

impl ModelRegistry {
    /// An empty registry over `backend`; `seed` fixes the routing hash
    /// (replicas that should agree on A/B assignment share a seed).
    pub fn new(backend: Box<dyn Backend>, seed: u64) -> Self {
        Self { backend, models: BTreeMap::new(), route: None, seed }
    }

    /// Worker threads for the shared backend's batch paths; returns the
    /// count in effect.
    pub fn set_threads(&mut self, threads: usize) -> usize {
        self.backend.set_threads(threads)
    }

    /// OS worker threads ever created by the shared backend's pool
    /// (the `pool_reuse` accounting: one persistent pool serves every
    /// model and every micro-batch — request traffic must leave this
    /// flat, which `rust/tests/serve_engine.rs` pins).
    pub fn worker_spawns(&self) -> u64 {
        self.backend.worker_spawns()
    }

    /// Load `model` under `name`: validates, folds the coefficient
    /// scale, prebuilds tile bounds.  A fresh name starts at version 1;
    /// re-inserting an existing name replaces the model and bumps its
    /// version.  Returns the version now serving.
    pub fn insert(&mut self, name: &str, mut model: SvmModel) -> Result<u64, ServeError> {
        validate_model(&model)?;
        model.svs.fold_scale();
        let bounds = TileBounds::of(&model.svs);
        let version = self.models.get(name).map_or(1, |e| e.version + 1);
        self.models
            .insert(name.to_string(), ModelEntry { model, bounds, version, served: 0 });
        Ok(version)
    }

    /// Replace an **existing** model (the `swap-model` / fleet
    /// `activate` verb): like [`ModelRegistry::insert`] but a typo'd
    /// name is an error instead of a silently created, never-routed
    /// entry, and the incoming model's feature dimension must match
    /// the version currently serving — requests queued by the
    /// micro-batcher were shape-validated at submit time against the
    /// old dimension, so a dimension-changing swap would turn every
    /// in-flight request into a flush-time error.  Rejected here with
    /// a typed [`ServeError::DimMismatch`]; the registry keeps serving
    /// the current version.  (To intentionally change a name's
    /// dimension, [`ModelRegistry::evict`] then
    /// [`ModelRegistry::insert`].)
    pub fn swap(&mut self, name: &str, model: SvmModel) -> Result<u64, ServeError> {
        let Some(entry) = self.models.get(name) else {
            return Err(ServeError::UnknownModel(name.into()));
        };
        let serving = entry.model.svs.dim();
        if model.svs.dim() != serving {
            return Err(ServeError::DimMismatch {
                name: name.into(),
                serving,
                incoming: model.svs.dim(),
            });
        }
        self.insert(name, model)
    }

    /// Remove a model.  Refuses while an explicit route still names it
    /// — evicting a live arm would turn a slice of traffic into
    /// per-request errors.
    pub fn evict(&mut self, name: &str) -> Result<(), ServeError> {
        if !self.models.contains_key(name) {
            return Err(ServeError::UnknownModel(name.into()));
        }
        if let Some(route) = &self.route {
            if route.arms().iter().any(|a| a.name == name) {
                return Err(ServeError::BadRoute(format!(
                    "model {name:?} is a live route arm; set a new route first"
                )));
            }
        }
        self.models.remove(name);
        Ok(())
    }

    /// Install an explicit routing table.  Every arm must name a loaded
    /// model.
    pub fn set_route(&mut self, spec: RouteSpec) -> Result<(), ServeError> {
        for arm in spec.arms() {
            if !self.models.contains_key(&arm.name) {
                return Err(ServeError::UnknownModel(arm.name.clone()));
            }
        }
        self.route = Some(spec);
        Ok(())
    }

    /// The model name `key` routes to.  Deterministic: same key (and
    /// seed, and route) ⇒ same model, across runs and threads.  With no
    /// explicit route the pick is uniform over every loaded model (name
    /// order — equally deterministic).
    pub fn route_for(&self, key: &[u8]) -> Result<String, ServeError> {
        let ticket = route_hash(self.seed, key);
        if let Some(r) = &self.route {
            return Ok(r.pick(ticket).to_string());
        }
        if self.models.is_empty() {
            return Err(ServeError::BadRoute("no models loaded".into()));
        }
        let arm = ticket as usize % self.models.len();
        Ok(self.models.keys().nth(arm).expect("index < len").clone())
    }

    /// Number of loaded models.
    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Snapshot of every entry, in name order.
    pub fn status(&self) -> Vec<ModelStatus> {
        self.models
            .iter()
            .map(|(name, e)| ModelStatus {
                name: name.clone(),
                version: e.version,
                n_svs: e.model.svs.len(),
                dim: e.model.svs.dim(),
                served: e.served,
            })
            .collect()
    }

    /// Feature dimension of a named model (request shape pre-check).
    pub fn dim_of(&self, name: &str) -> Result<usize, ServeError> {
        Ok(self.entry(name)?.model.svs.dim())
    }

    /// Version of a named model.
    pub fn version_of(&self, name: &str) -> Result<u64, ServeError> {
        Ok(self.entry(name)?.version)
    }

    /// SV count of a named model.
    pub fn n_svs_of(&self, name: &str) -> Result<usize, ServeError> {
        Ok(self.entry(name)?.model.svs.len())
    }

    fn entry(&self, name: &str) -> Result<&ModelEntry, ServeError> {
        self.models.get(name).ok_or_else(|| ServeError::UnknownModel(name.into()))
    }

    /// Decision value for a single query through `name` — the tiled
    /// single-row path over the entry's prebuilt bounds, bit-identical
    /// to a batch row.
    pub fn decision1(&mut self, name: &str, x: &[f32]) -> Result<f64, ServeError> {
        let e = self
            .models
            .get_mut(name)
            .ok_or_else(|| ServeError::UnknownModel(name.into()))?;
        if x.len() != e.model.svs.dim() {
            return Err(TrainError::DimMismatch { expected: e.model.svs.dim(), got: x.len() }
                .into());
        }
        e.served += 1;
        Ok(margin1_bounded(&e.model.svs, e.model.gamma, x, &e.bounds) + e.model.bias)
    }

    /// Decision values for a batch of query rows through `name`, via
    /// **one** tiled [`Backend::margins_bounded_into`] pass over the
    /// entry's prebuilt bounds into the caller's answer buffer
    /// (`out.len() == queries.rows()`) — the micro-batcher's hot path,
    /// with no per-batch Θ(B) bound rebuild.  On the native backend
    /// (the serve default) this is bit-identical per row to
    /// [`ModelRegistry::decision1`] regardless of batch size; backends
    /// that route big batches to AOT artifacts (hybrid/XLA) trade that
    /// load-invariant parity for artifact speed.
    pub fn decision_batch_into(
        &mut self,
        name: &str,
        queries: &DenseMatrix,
        out: &mut [f64],
    ) -> Result<(), ServeError> {
        debug_assert_eq!(out.len(), queries.rows());
        let e = self
            .models
            .get_mut(name)
            .ok_or_else(|| ServeError::UnknownModel(name.into()))?;
        if queries.cols() != e.model.svs.dim() {
            return Err(TrainError::DimMismatch {
                expected: e.model.svs.dim(),
                got: queries.cols(),
            }
            .into());
        }
        self.backend.margins_bounded_into(&e.model.svs, e.model.gamma, queries, &e.bounds, out);
        for f in out.iter_mut() {
            *f += e.model.bias;
        }
        e.served += queries.rows() as u64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn toy_model(seed: u64, n: usize, d: usize) -> SvmModel {
        let mut rng = crate::rng::Xoshiro256::new(seed);
        let mut m = SvmModel::new(d, 0.8);
        for _ in 0..n {
            let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
            m.svs.push(&x, rng.next_f64() - 0.5);
        }
        m.bias = 0.05;
        m
    }

    fn registry_with(names: &[&str]) -> ModelRegistry {
        let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
        for (i, name) in names.iter().enumerate() {
            reg.insert(name, toy_model(i as u64 + 1, 20, 4)).unwrap();
        }
        reg
    }

    #[test]
    fn insert_versions_and_swap() {
        let mut reg = registry_with(&["a"]);
        assert_eq!(reg.version_of("a").unwrap(), 1);
        assert_eq!(reg.insert("a", toy_model(9, 10, 4)).unwrap(), 2);
        assert_eq!(reg.swap("a", toy_model(10, 10, 4)).unwrap(), 3);
        assert_eq!(
            reg.swap("typo", toy_model(11, 10, 4)).unwrap_err(),
            ServeError::UnknownModel("typo".into())
        );
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn swap_rejects_dimension_change_typed() {
        let mut reg = registry_with(&["a"]);
        assert_eq!(
            reg.swap("a", toy_model(9, 10, 6)).unwrap_err(),
            ServeError::DimMismatch { name: "a".into(), serving: 4, incoming: 6 }
        );
        // the rejected swap left the serving entry untouched
        assert_eq!(reg.version_of("a").unwrap(), 1);
        assert_eq!(reg.dim_of("a").unwrap(), 4);
        // insert (not swap) is the intentional dimension-change path
        assert_eq!(reg.insert("a", toy_model(9, 10, 6)).unwrap(), 2);
        assert_eq!(reg.dim_of("a").unwrap(), 6);
    }

    #[test]
    fn evict_guards_live_route_arms() {
        let mut reg = registry_with(&["a", "b"]);
        reg.set_route(RouteSpec::new(vec![("a".into(), 1), ("b".into(), 1)]).unwrap()).unwrap();
        assert!(matches!(reg.evict("a"), Err(ServeError::BadRoute(_))));
        reg.set_route(RouteSpec::single("b")).unwrap();
        reg.evict("a").unwrap();
        assert_eq!(reg.evict("a").unwrap_err(), ServeError::UnknownModel("a".into()));
    }

    #[test]
    fn route_spec_rejects_degenerate_tables() {
        assert!(matches!(RouteSpec::new(vec![]), Err(ServeError::BadRoute(_))));
        assert!(matches!(
            RouteSpec::new(vec![("a".into(), 0)]),
            Err(ServeError::BadRoute(_))
        ));
        assert!(matches!(
            RouteSpec::new(vec![("a".into(), 1), ("a".into(), 2)]),
            Err(ServeError::BadRoute(_))
        ));
        let mut reg = registry_with(&["a"]);
        assert_eq!(
            reg.set_route(RouteSpec::single("ghost")).unwrap_err(),
            ServeError::UnknownModel("ghost".into())
        );
    }

    #[test]
    fn routing_is_deterministic_and_weighted() {
        let mut reg = registry_with(&["a", "b"]);
        let mut reg2 = registry_with(&["a", "b"]);
        let spec = RouteSpec::new(vec![("a".into(), 3), ("b".into(), 1)]).unwrap();
        reg.set_route(spec.clone()).unwrap();
        reg2.set_route(spec).unwrap();
        let mut to_a = 0usize;
        for k in 0..2000u32 {
            let key = format!("user-{k}");
            let m1 = reg.route_for(key.as_bytes()).unwrap();
            // identically-seeded registries agree key by key
            assert_eq!(m1, reg2.route_for(key.as_bytes()).unwrap());
            // and repeated lookups are stable
            assert_eq!(m1, reg.route_for(key.as_bytes()).unwrap());
            if m1 == "a" {
                to_a += 1;
            }
        }
        // 3:1 weighting: expect ~1500 of 2000 on arm a (loose bounds)
        assert!((1350..=1650).contains(&to_a), "a got {to_a} of 2000");
        let _ = reg.decision1("a", &[0.0; 4]).unwrap();
    }

    #[test]
    fn batch_bit_matches_single_queries() {
        let mut reg = registry_with(&["m"]);
        let mut rng = crate::rng::Xoshiro256::new(42);
        let rows: Vec<Vec<f32>> = (0..9)
            .map(|_| (0..4).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let q = DenseMatrix::from_rows(rows.clone());
        let mut out = vec![0.0; q.rows()];
        reg.decision_batch_into("m", &q, &mut out).unwrap();
        for (r, row) in rows.iter().enumerate() {
            let single = reg.decision1("m", row).unwrap();
            assert_eq!(out[r].to_bits(), single.to_bits(), "row {r}");
        }
    }

    #[test]
    fn request_errors_are_typed_per_request() {
        let mut reg = registry_with(&["m"]);
        assert_eq!(
            reg.decision1("ghost", &[0.0; 4]).unwrap_err(),
            ServeError::UnknownModel("ghost".into())
        );
        assert!(matches!(
            reg.decision1("m", &[0.0; 5]).unwrap_err(),
            ServeError::Model(TrainError::DimMismatch { expected: 4, got: 5 })
        ));
        let empty = ModelRegistry::new(Box::new(NativeBackend::new()), 1);
        assert!(matches!(empty.route_for(b"k"), Err(ServeError::BadRoute(_))));
    }
}
